"""Commit offload + group commit through the async storage I/O pipeline.

Runs the same 200 small workflows through one ``WorkflowPool`` twice on a
DynamoDB-like simulated engine:

* **sync** — the pre-pipeline path: every commit blocks its caller on
  ``put_batch(versions)`` then ``put(commit_record)``;
* **pipelined** — ``commit_offload=True`` (the default): commits ride the
  node's ``StorageIOPipeline``; concurrent transactions' version writes
  coalesce into shared BatchWriteItem-style flushes and the ticket resolves
  when the commit future lands.

Then audits exactly-once: every workflow has exactly ONE commit record and
its effects are readable, and prints the pipeline gauges (coalesce ratio =
transactions sharing each flush).

Run:  PYTHONPATH=src python examples/workflow_async_commit.py
"""

import time

from repro.core import AftCluster, AftNodeConfig, ClusterConfig
from repro.core.records import COMMIT_PREFIX, TransactionRecord
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.storage.simulated import dynamodb_like
from repro.workflow import PoolConfig, TxnScope, WorkflowPool, WorkflowSpec

N = 200
TS = 0.3  # latency compression (see storage/simulated.py)


def build_spec(i: int) -> WorkflowSpec:
    spec = WorkflowSpec(f"order-{i}")

    def reserve(ctx):
        ctx.put(f"orders/{i}/reserved", b"2")
        return 2

    def charge(ctx):
        ctx.put(f"orders/{i}/charged", str(ctx.inputs["reserve"] * 5).encode())
        return ctx.inputs["reserve"] * 5

    spec.step("reserve", reserve)
    spec.step("charge", charge, deps=("reserve",))
    return spec


def run_once(offload: bool):
    store = dynamodb_like(time_scale=TS, seed=7)
    cluster = AftCluster(store, ClusterConfig(
        num_nodes=1,
        node=AftNodeConfig(enable_io_pipeline=offload, io_workers=8,
                           flush_concurrency=4),
        start_background_threads=False,
    ))
    platform = LambdaPlatform(FaasConfig(time_scale=TS, max_workers=8))
    cfg = PoolConfig(scope=TxnScope.WORKFLOW, commit_offload=offload,
                     batch_max_steps=16, declare_finished=False)
    t0 = time.perf_counter()
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [pool.submit(build_spec(i)) for i in range(N)]
        results = [t.result(timeout=120) for t in tickets]
    wall = time.perf_counter() - t0
    node = cluster.live_nodes()[0]
    snap = node.stats()

    # exactly-once audit: one commit record per workflow, effects readable
    by_uuid = {}
    for key in store.list_keys(COMMIT_PREFIX):
        u = TransactionRecord.decode(store.get(key)).tid.uuid
        by_uuid[u] = by_uuid.get(u, 0) + 1
    dupes = sum(c - 1 for c in by_uuid.values())
    missing = sum(1 for r in results if by_uuid.get(r.workflow_uuid, 0) != 1)
    client = cluster.client()
    tx = client.start_transaction()
    bad = sum(
        1 for i in range(N)
        if client.get(tx, f"orders/{i}/charged") != b"10"
    )
    client.abort_transaction(tx)

    mode = "pipelined" if offload else "sync"
    print(f"{mode:9s}: {N} workflows in {wall:.2f}s "
          f"({N / wall:.0f} wf/s), duplicates={dupes}, "
          f"missing={missing}, bad_reads={bad}")
    if offload:
        print(f"           coalesce ratio {snap['io_coalesce_ratio']:.1f} "
              f"txns/flush, {snap['io_flushes']:.0f} flushes of mean "
              f"{snap['io_mean_flush_items']:.1f} items "
              f"(offloaded commits: {snap['async_commits']:.0f})")
    assert dupes == 0 and missing == 0 and bad == 0, "exactly-once violated!"
    platform.shutdown()
    cluster.stop()
    return N / wall


if __name__ == "__main__":
    sync_rate = run_once(offload=False)
    piped_rate = run_once(offload=True)
    print(f"group commit speedup: {piped_rate / sync_rate:.2f}x "
          f"(workflows/s, same DAGs, same engine)")
