"""Workflow-driven atomic weight refresh: publish DAG vs. concurrent reader.

A publisher repeatedly pushes new "weight sets" (4 shards, produced by
parallel FaaS steps that crash 10% of the time) through a publish workflow —
one AFT transaction per publish, with a deterministic UUID per (run, step)
so re-driven publishes commit exactly once.  A concurrent reader assembles
the weight set in one read transaction and must NEVER observe a torn set
(shards from different steps), even while publishes crash and retry.

  PYTHONPATH=src python examples/workflow_atomic_refresh.py
"""

import threading

from repro.core import AftCluster, ClusterConfig, ReadAbortError
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.serve import publish_weights, read_weight_set
from repro.storage.memory import MemoryStorage
from repro.workflow import TxnScope, WorkflowConfig, WorkflowExecutor

SHARDS = [f"layer{i}" for i in range(4)]
STEPS = 8


def main() -> None:
    cluster = AftCluster(
        MemoryStorage(), ClusterConfig(num_nodes=1, start_background_threads=False)
    )
    platform = LambdaPlatform(FaasConfig(time_scale=0.0, failure_rate=0.1, seed=3))
    executor = WorkflowExecutor(
        platform,
        cluster=cluster,
        config=WorkflowConfig(scope=TxnScope.WORKFLOW, max_attempts=30),
    )

    def produce(shard: str, step: int) -> bytes:
        # stand-in for quantize/re-shard/fetch; bytes encode their version
        return f"{shard}@step{step}".encode() * 8

    torn = []
    observed = set()
    aborts = [0]
    stop = threading.Event()

    def reader() -> None:
        client = cluster.client()
        while not stop.is_set():
            try:
                got = read_weight_set(client, run_id="demo")
            except ReadAbortError:
                aborts[0] += 1  # §3.6 staleness abort: retry, not torn
                continue
            if got is None:
                continue
            step, shards = got
            versions = {data.decode().split("@")[1][: len(f"step{step}")]
                        for data in shards.values()}
            if len(versions) != 1:
                torn.append((step, versions))
            observed.add(step)

    t = threading.Thread(target=reader, daemon=True)
    t.start()

    for step in range(STEPS):
        result = publish_weights(
            executor, SHARDS, produce, run_id="demo", step=step
        )
        print(f"published step {step}: attempts={result.attempts} "
              f"resumed={result.steps_memoized}")

    stop.set()
    t.join(timeout=5)
    print(f"reader observed steps {sorted(observed)} "
          f"(read aborts: {aborts[0]}); "
          f"crashes injected: {platform.failures_injected}")
    assert observed, "reader never assembled a weight set"
    assert not torn, f"torn weight sets observed: {torn}"
    print("no torn weight set ever observed — every refresh was atomic.")
    cluster.stop()


if __name__ == "__main__":
    main()
