"""Quickstart: a fan-out/fan-in workflow with failure injection.

Eight parallel branches each read-modify-write their own key; a fan-in step
summarizes them.  The whole DAG is ONE AFT transaction: branches crash at
random (8% per failure point), the workflow retries under the same UUID,
completed steps resume from their memoized results (§3.3.1 extended to
DAGs), and the commit lands exactly once.

  PYTHONPATH=src python examples/workflow_fanout.py
"""

import json

from repro.core import AftCluster, ClusterConfig
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.storage.memory import MemoryStorage
from repro.workflow import TxnScope, WorkflowConfig, WorkflowExecutor, WorkflowSpec

BRANCHES = 8
ROUNDS = 5


def build_spec(epoch: int) -> WorkflowSpec:
    spec = WorkflowSpec(f"fanout-round{epoch}")

    def branch_fn(ctx) -> int:
        key = f"counter{ctx.branch}"
        raw = ctx.get(key)
        count = json.loads(raw)["count"] if raw else 0
        ctx.maybe_fail()  # a branch may die right here, mid-flight
        ctx.put(key, json.dumps({"count": count + 1, "epoch": epoch}).encode())
        return count + 1

    names = spec.fan_out("branch", branch_fn, BRANCHES)

    def summarize(ctx) -> int:
        total = sum(ctx.inputs[n] for n in names)
        ctx.put("summary", json.dumps({"epoch": epoch, "total": total}).encode())
        return total

    spec.fan_in("summary", summarize, names)
    # conditional edge: only fires once every counter has reached ROUNDS
    spec.step(
        "celebrate",
        lambda ctx: "all branches done",
        deps=["summary"],
        when=lambda results: results["summary"] >= BRANCHES * ROUNDS,
    )
    return spec


def main() -> None:
    cluster = AftCluster(
        MemoryStorage(), ClusterConfig(num_nodes=1, start_background_threads=False)
    )
    platform = LambdaPlatform(
        FaasConfig(time_scale=0.0, failure_rate=0.08, seed=7)
    )
    executor = WorkflowExecutor(
        platform,
        cluster=cluster,
        config=WorkflowConfig(scope=TxnScope.WORKFLOW, max_attempts=25),
    )

    for epoch in range(ROUNDS):
        result = executor.run(build_spec(epoch))
        print(
            f"round {epoch}: total={result.results['summary']} "
            f"attempts={result.attempts} resumed_steps={result.steps_memoized} "
            f"skipped={list(result.skipped)}"
        )

    # exactly-once despite every injected crash: each counter == ROUNDS
    node = cluster.live_nodes()[0]
    tx = node.start_transaction()
    counts = [
        json.loads(node.get(tx, f"counter{i}"))["count"] for i in range(BRANCHES)
    ]
    node.abort_transaction(tx)
    print(f"final counters: {counts} (crashes injected: "
          f"{platform.failures_injected})")
    assert counts == [ROUNDS] * BRANCHES, "effects were not exactly-once!"
    print("every branch incremented exactly once per round — exactly-once holds.")
    cluster.stop()


if __name__ == "__main__":
    main()
