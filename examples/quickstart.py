"""Quickstart: AFT's Table-1 API in 60 lines.

Starts an in-process AFT cluster over an (eventually-consistent, simulated)
DynamoDB-like engine, runs two transactions that demonstrate atomic
visibility + read-your-writes, then shows what goes wrong WITHOUT the shim.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import AftCluster, ClusterConfig
from repro.storage.simulated import make_engine


def main() -> None:
    storage = make_engine("dynamodb", time_scale=0.02)
    cluster = AftCluster(storage, ClusterConfig(num_nodes=2))
    cluster.start()
    client = cluster.client()

    # -- transaction 1: write two keys atomically ---------------------------
    t1 = client.start_transaction()
    client.put(t1, "account/alice", b"100")
    client.put(t1, "account/bob", b"0")
    client.commit_transaction(t1)
    print("T1 committed {alice: 100, bob: 0}")

    # -- transaction 2: a transfer that ABORTS leaves nothing behind --------
    t2 = client.start_transaction()
    client.put(t2, "account/alice", b"50")
    client.put(t2, "account/bob", b"50")
    client.abort_transaction(t2)
    print("T2 aborted — its writes must be invisible")

    # -- transaction 3: read-atomic view ------------------------------------
    t3 = client.start_transaction()
    alice = client.get(t3, "account/alice")
    bob = client.get(t3, "account/bob")
    client.put(t3, "account/alice", b"75")
    # read-your-writes: we see our own uncommitted update...
    assert client.get(t3, "account/alice") == b"75"
    client.abort_transaction(t3)
    print(f"T3 read {{alice: {alice.decode()}, bob: {bob.decode()}}} "
          f"(atomic snapshot; RYW verified)")
    assert (alice, bob) == (b"100", b"0")

    # -- the counterfactual: direct writes leak partial state ---------------
    # write two keys non-transactionally; a concurrent reader can see the
    # first without the second — exactly the fractured read AFT prevents.
    storage.put("raw/k", b"new")
    # (second write 'raw/l' still in flight...)
    partial = storage.get("raw/k"), storage.get("raw/l")
    print(f"without AFT: reader observed partial state {partial} "
          f"(fractured!)")
    storage.put("raw/l", b"new")

    cluster.stop()
    print("OK")


if __name__ == "__main__":
    main()
