"""End-to-end fault-tolerant training with AFT-transactional checkpoints.

Trains a reduced tinyllama on the synthetic grammar corpus, kills the
process state mid-run (injected), restarts, and verifies the resumed run
produces the bit-identical final loss of an uninterrupted run — the
exactly-once guarantee in action.

  PYTHONPATH=src python examples/train_checkpointed.py
"""

from repro.checkpoint import AftCheckpointer
from repro.core import AftCluster
from repro.models import Model, get_config
from repro.storage.memory import MemoryStorage
from repro.train import get_optimizer
from repro.train.data import data_for_model
from repro.train.loop import CrashInjected, Trainer, TrainerConfig


def trainer(model, data, ck, **kw):
    return Trainer(model, get_optimizer("adamw", lr=1e-2), data, ck,
                   TrainerConfig(ckpt_every=5, log_every=5, **kw))


def main() -> None:
    cfg = get_config("tinyllama-1.1b").reduced(pattern_repeats=2)
    model = Model(cfg)
    data = data_for_model(cfg, global_batch=4, seq_len=32)
    cluster = AftCluster(MemoryStorage())

    # reference: uninterrupted 20 steps
    ck_ref = AftCheckpointer(cluster.client(), run_id="ref")
    ref = trainer(model, data, ck_ref, total_steps=20).run()
    print(f"reference run:  final loss {ref[-1]['loss']:.6f}")

    # crashy run: dies after step 12, restarted once
    ck = AftCheckpointer(cluster.client(), run_id="crashy")
    try:
        trainer(model, data, ck, total_steps=20, crash_after_step=12).run()
    except CrashInjected as e:
        print(f"crash injected: {e} (last committed step: "
              f"{ck.latest_step()})")
    hist = trainer(model, data, ck, total_steps=20).run()
    print(f"resumed run:    final loss {hist[-1]['loss']:.6f} "
          f"(resumed from step {hist[0]['step']})")

    assert hist[-1]["loss"] == ref[-1]["loss"], "exactly-once violated!"
    print("exactly-once verified: resumed loss is bit-identical.")
    cluster.stop()


if __name__ == "__main__":
    main()
