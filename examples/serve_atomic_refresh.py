"""Serving with read-atomic weight hot-swap under a concurrent trainer.

A trainer commits new checkpoints every few steps while a serving engine
refreshes weights in the background and keeps generating.  The engine can
never assemble a torn weight set: each refresh is one read-atomic AFT
transaction.

  PYTHONPATH=src python examples/serve_atomic_refresh.py
"""

import threading
import time

from repro.checkpoint import AftCheckpointer
from repro.core import AftCluster
from repro.models import Model, get_config
from repro.serve import ServeConfig, ServeEngine
from repro.storage.memory import MemoryStorage
from repro.train import get_optimizer
from repro.train.data import data_for_model
from repro.train.loop import Trainer, TrainerConfig


def main() -> None:
    cfg = get_config("qwen2-0.5b").reduced(pattern_repeats=2)
    model = Model(cfg)
    data = data_for_model(cfg, global_batch=4, seq_len=32)
    cluster = AftCluster(MemoryStorage())
    ck_w = AftCheckpointer(cluster.client(), run_id="live")
    ck_r = AftCheckpointer(cluster.client(), run_id="live")

    # train the first few steps so the server has weights
    t = Trainer(model, get_optimizer("adamw", lr=1e-2), data, ck_w,
                TrainerConfig(total_steps=6, ckpt_every=3, log_every=3))
    t.run()

    eng = ServeEngine(model, ck_r, ServeConfig(max_len=64,
                                               refresh_every_s=0.2))
    assert eng.refresh_weights()
    print(f"serving weights @ step {eng.weights_step}")
    eng.start_refresher()

    # trainer keeps going in the background
    def train_more():
        t2 = Trainer(model, get_optimizer("adamw", lr=1e-2), data, ck_w,
                     TrainerConfig(total_steps=18, ckpt_every=3,
                                   log_every=6))
        t2.run()

    bg = threading.Thread(target=train_more)
    bg.start()

    seen = {eng.weights_step}
    for i in range(6):
        out = eng.generate([[1, 2, 3, 4], [9, 8, 7, 6]], max_new=4)
        seen.add(eng.weights_step)
        print(f"gen round {i}: weights step {eng.weights_step}, "
              f"tokens {out[0]}")
        time.sleep(0.4)
    bg.join()
    eng.refresh_weights()
    seen.add(eng.weights_step)
    eng.stop()
    print(f"weight versions observed while serving: {sorted(seen)}")
    assert eng.weights_step == 17
    print(f"final weights @ step {eng.weights_step}; every swap was atomic.")
    cluster.stop()


if __name__ == "__main__":
    main()
