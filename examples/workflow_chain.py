"""Cross-workflow chaining: a 3-stage pipeline with kill-mid-handoff.

``ingest → transform → publish`` as THREE separate workflows, each
triggered by its predecessor's commit through the durable ``q/`` trigger
queue (workflow/chain.py).  Every handoff is killed at least once — the
consumer dies between claiming a trigger and starting its child — and the
replay still runs each stage exactly once:

* the trigger entry rides the parent's commit record (no commit → no
  trigger, retried commit → same entry);
* the claim is a deterministic-UUID transaction (§3.3.1: racing or
  replayed claimants collapse into one);
* the child's UUID *is* the queue entry, so a double-driven child
  recommits instead of re-firing.

  PYTHONPATH=src python examples/workflow_chain.py
"""

import json

from repro.core import AftCluster, ClusterConfig
from repro.core.gc import LocalGcAgent
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.storage.memory import MemoryStorage
from repro.workflow import (
    ChainConsumerConfig,
    Trigger,
    WorkflowPool,
    WorkflowSpec,
)

RECORDS = 10


def build_ingest() -> WorkflowSpec:
    spec = WorkflowSpec("ingest")

    def body(ctx):
        rows = [{"id": i, "value": i * i} for i in range(RECORDS)]
        ctx.put("pipe/raw", json.dumps(rows).encode())
        return {"rows": len(rows)}

    spec.step("pull", body)
    spec.trigger(Trigger("transform", args_from="pull"))
    return spec


def build_transform() -> WorkflowSpec:
    spec = WorkflowSpec("transform")

    def body(ctx):
        rows = json.loads(ctx.get("pipe/raw"))
        total = sum(r["value"] for r in rows)
        # read-modify-write: the exactly-once probe — a double-fired
        # transform would double this counter
        raw = ctx.get("pipe/transform-runs")
        runs = int(raw) if raw else 0
        ctx.put("pipe/transform-runs", str(runs + 1).encode())
        ctx.put("pipe/aggregate", json.dumps({"total": total}).encode())
        return {"total": total}

    spec.step("aggregate", body)
    spec.trigger(Trigger("publish", args_from="aggregate"))
    return spec


def build_publish() -> WorkflowSpec:
    spec = WorkflowSpec("publish")

    def body(ctx):
        agg = json.loads(ctx.get("pipe/aggregate"))
        ctx.put("pipe/published", json.dumps(
            {"total": agg["total"], "records": RECORDS}).encode())
        return agg["total"]

    spec.step("announce", body)
    return spec


def main() -> None:
    cluster = AftCluster(
        MemoryStorage(), ClusterConfig(num_nodes=1,
                                       start_background_threads=False)
    )
    # every handoff dies while the rate is 1.0 at the handoff site; dropping
    # it to 0 afterwards plays the part of the replacement consumer process
    platform = LambdaPlatform(FaasConfig(
        time_scale=0.0, failure_rate=1.0, failure_sites=("chain:handoff",),
        seed=3,
    ))
    registry = {
        "transform": build_transform(),
        "publish": build_publish(),
    }
    with WorkflowPool(platform, cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            registry, ChainConsumerConfig(reclaim_after_s=0.0), start=False
        )
        pool.submit(build_ingest()).result(timeout=30)

        crashed_passes = 0
        while consumer.step() == 0 and crashed_passes < 2:
            crashed_passes += 1  # claimed, then killed mid-handoff
        print(f"handoff crashes survived so far: "
              f"{consumer.stats['handoff_crashes']}")
        # the 'restarted' consumer process: injection off, replay drains
        platform.config.failure_rate = 0.0
        assert consumer.drain(timeout_s=30), "chain did not quiesce"

        stats = consumer.stats
        print(f"children started: {stats['children_started']}, "
              f"completed: {stats['children_completed']}, "
              f"claims taken over: {stats['claims_taken_over']}")

    node = cluster.live_nodes()[0]
    tx = node.start_transaction()
    published = json.loads(node.get(tx, "pipe/published"))
    runs = int(node.get(tx, "pipe/transform-runs"))
    node.abort_transaction(tx)
    print(f"published: {published}, transform executions: {runs}")
    assert published["total"] == sum(i * i for i in range(RECORDS))
    assert runs == 1, "transform fired more than once!"

    # GC: once children are finished, their consumed queue entries are
    # reclaimed with their memo records by the same w/-marker sweep
    before = len(cluster.storage.list_keys("d/q/"))
    LocalGcAgent(node).step()
    after = len(cluster.storage.list_keys("d/q/"))
    print(f"queue storage keys: {before} before GC sweep → {after} after")
    assert after == 0

    print("3-stage chain survived kill-mid-handoff with exactly-once "
          "stages — durable triggers hold.")
    cluster.stop()


if __name__ == "__main__":
    main()
