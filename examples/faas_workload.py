"""The paper's evaluation workload as a runnable example.

Two-function transactions (2 reads + 1 write each) on a simulated Lambda
platform over simulated DynamoDB — with and without AFT — reporting latency
percentiles and the anomaly counts of Table 2.

  PYTHONPATH=src python examples/faas_workload.py [--clients 10] [--txns 100]
"""

import argparse
import json

from repro.core import AftCluster, AftNodeConfig, ClusterConfig
from repro.faas.platform import FaasConfig
from repro.faas.workload import WorkloadConfig, run_workload
from repro.storage.simulated import make_engine

TIME_SCALE = 0.03


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--txns", type=int, default=100)
    ap.add_argument("--zipf", type=float, default=1.0)
    args = ap.parse_args()

    cfg = WorkloadConfig(zipf=args.zipf,
                         faas=FaasConfig(time_scale=TIME_SCALE))

    print("— plain DynamoDB (no shim) —")
    res = run_workload("plain", cfg=cfg, clients=args.clients,
                       txns_per_client=args.txns,
                       storage=make_engine("dynamodb",
                                           time_scale=TIME_SCALE))
    print(json.dumps(res.summary(), indent=1))

    print("— AFT over the same engine —")
    cluster = AftCluster(
        make_engine("dynamodb", time_scale=TIME_SCALE),
        ClusterConfig(num_nodes=2,
                      node=AftNodeConfig(multicast_interval_s=0.05)))
    cluster.start()
    res = run_workload("aft", cfg=cfg, clients=args.clients,
                       txns_per_client=args.txns, cluster=cluster)
    print(json.dumps(res.summary(), indent=1))
    cluster.stop()
    assert res.anomalies.get("ryw_anomalies", 0) == 0
    assert res.anomalies.get("fr_anomalies", 0) == 0
    print("AFT: zero anomalies, as guaranteed.")


if __name__ == "__main__":
    main()
