"""Placement-aware routing on a 3-node cluster: sharded pool + node kill.

A `WorkflowPool` shards an entity-skewed workflow stream across three AFT
nodes through the `consistent_hash` routing policy (`core/routing.py`):
every workflow carries a placement hint (its entity's keys), so all
workflows of one entity land on the entity's ring owner and re-hit its
caches.  Mid-stream one node is hard-killed — the ring resyncs, affected
workflows retry onto live nodes with memoized resume, and every counter
still lands exactly once.

  PYTHONPATH=src python examples/workflow_routing.py
"""

import json
from collections import Counter

from repro.core import AftCluster, ClusterConfig, ConsistentHashRouter
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.storage.memory import MemoryStorage
from repro.workflow import PoolConfig, TxnScope, WorkflowPool, WorkflowSpec

NODES = 3
ENTITIES = 12
# waves: each wave runs ONE workflow per entity — entities are concurrent
# with each other, but each entity's counter chain is sequential (AFT
# guarantees read atomicity, not serializability: two *concurrent* RMWs of
# the same counter could both read the same base and lose an update)
ROUNDS_BEFORE_KILL = 3
ROUNDS_AFTER_KILL = 2
WORKFLOWS = ENTITIES * (ROUNDS_BEFORE_KILL + ROUNDS_AFTER_KILL)


def build_spec(wf: int, entity: int) -> WorkflowSpec:
    """Bump the entity's counter and refresh its rollup — one atomic txn."""
    spec = WorkflowSpec(f"entity-{entity}-wf{wf}")
    keys = (f"ent/{entity}/counter", f"ent/{entity}/rollup")

    def bump(ctx) -> int:
        raw = ctx.get(keys[0])
        count = json.loads(raw)["count"] if raw else 0
        ctx.put(keys[0], json.dumps({"count": count + 1}).encode())
        return count + 1

    def rollup(ctx) -> int:
        ctx.put(keys[1], json.dumps({"upto": ctx.inputs["bump"]}).encode())
        return ctx.inputs["bump"]

    spec.step("bump", bump, reads=keys)
    spec.step("rollup", rollup, deps=["bump"], reads=keys)
    return spec


def main() -> None:
    router = ConsistentHashRouter()
    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(
            num_nodes=NODES, standby_nodes=1,
            start_background_threads=False, routing=router,
        ),
    )
    platform = LambdaPlatform(FaasConfig(time_scale=0.0, seed=3))

    with WorkflowPool(
        platform, cluster=cluster,
        config=PoolConfig(scope=TxnScope.WORKFLOW, max_attempts=10),
    ) as pool:

        def run_wave(round_no: int):
            tickets = [
                pool.submit(build_spec(round_no * ENTITIES + e, e))
                for e in range(ENTITIES)
            ]
            return [t.result(timeout=60) for t in tickets]

        # first rounds of the stream on the healthy 3-node ring
        results = []
        for r in range(ROUNDS_BEFORE_KILL):
            results += run_wave(r)

        placement = Counter(
            router.owner_id(f"ent/{e}/counter") for e in range(ENTITIES)
        )
        print(f"placement across ring (healthy): {dict(placement)}")
        cluster.step_all()  # one multicast round: peers learn the commits

        # hard-kill a node mid-stream; the ring resyncs around the corpse
        dead = cluster.kill_node(1)
        print(f"killed {dead.node_id}; live = {cluster.live_node_ids()}")
        # fault manager: §4.2 commit-set scan recovers the dead node's
        # commits for everyone, §6.7 promotes the standby into the ring
        cluster.fault_manager.step()
        print(f"after fault manager: live = {cluster.live_node_ids()}")

        for r in range(ROUNDS_BEFORE_KILL,
                       ROUNDS_BEFORE_KILL + ROUNDS_AFTER_KILL):
            results += run_wave(r)

    retried = sum(1 for r in results if r.attempts > 1)
    print(f"completed {len(results)}/{WORKFLOWS} workflows "
          f"({retried} retried after the kill)")

    # exactly-once audit from the durable source of truth: a fresh node
    # bootstrapped from the Commit Set (not any live node's cache)
    from repro.core import AftNode, AftNodeConfig

    node = AftNode(cluster.storage, AftNodeConfig(node_id="audit"))
    tx = node.start_transaction()
    per_entity = [
        json.loads(node.get(tx, f"ent/{e}/counter"))["count"]
        for e in range(ENTITIES)
    ]
    node.abort_transaction(tx)
    expected = ROUNDS_BEFORE_KILL + ROUNDS_AFTER_KILL
    print(f"entity counters: {per_entity}")
    assert per_entity == [expected] * ENTITIES, "effects were not exactly-once!"
    print(f"every entity counter == {expected} despite the node kill — "
          "rerouting preserved exactly-once.")
    cluster.stop()


if __name__ == "__main__":
    main()
