"""Continuous-batching engine: equivalence, compile-once, refresh spans.

The continuous engine must produce exactly the tokens the static reference
produces (greedy, float32 KV cache), while compiling its jitted
prefill/decode pair at most once regardless of prompt-length / batch mix —
and ``install_weights`` must span every swap with the publishing
transaction's UUID for the offline checker."""

import dataclasses
import warnings

import pytest

jax = pytest.importorskip("jax")

from repro.models import Model  # noqa: E402
from repro.models.config import get_config  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.serve.engine import (  # noqa: E402
    ContinuousEngine,
    ServeConfig,
    ServeEngine,
)


@pytest.fixture(scope="module")
def setup():
    # float32 KV cache so chunked and full prefill agree bit-for-bit
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b").reduced(pattern_repeats=2),
        kv_cache_dtype="float32")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    return model, params


PROMPTS = [
    ([5, 6, 7], 5),
    ([11, 12, 13, 14, 15], 2),
    ([21, 22, 23, 24, 25, 26, 27, 28, 29], 7),
    ([31, 32, 33, 34, 35, 36, 37, 38, 39, 40, 41, 42], 3),
    ([51, 52, 53, 54, 55, 56], 4),
]


def drive(engine, tickets):
    while not all(t.done() for t in tickets):
        assert engine.step(), "engine stalled with work pending"
    return [t.result(timeout=0) for t in tickets]


def test_matches_static_reference(setup):
    model, params = setup
    scfg = ServeConfig(max_len=48, slots=4, prefill_chunk=4)
    ref = ServeEngine(model, None, scfg, params=params)
    eng = ContinuousEngine(model, None, scfg, params=params)

    expect = [ref.generate([p], n)[0] for p, n in PROMPTS]
    tickets = [eng.submit(p, n) for p, n in PROMPTS]
    got = drive(eng, tickets)
    assert got == expect
    assert eng.stats["completed"] == len(PROMPTS)


def test_compiles_exactly_once(setup):
    """The tentpole claim: mixed lengths, overlapping lifetimes, join/
    leave mid-flight — one compiled prefill, one compiled decode."""
    model, params = setup
    scfg = ServeConfig(max_len=48, slots=4, prefill_chunk=4)
    eng = ContinuousEngine(model, None, scfg, params=params)
    drive(eng, [eng.submit(p, n) for p, n in PROMPTS])
    # second wave with fresh length mix re-uses both compilations
    drive(eng, [eng.submit([9] * 7, 6), eng.submit([3], 1)])
    assert eng.compile_counts() == {"prefill": 1, "decode": 1}


def test_footprint_guard(setup):
    model, params = setup
    eng = ContinuousEngine(
        model, None, ServeConfig(max_len=16, slots=2, prefill_chunk=8),
        params=params)
    with pytest.raises(AssertionError):
        eng.submit(list(range(1, 18)), 1)   # padded prefill exceeds cache
    with pytest.raises(AssertionError):
        eng.submit(list(range(1, 10)), 12)  # prompt + max_new exceeds cache


def test_weight_swap_between_iterations(setup):
    """A swap mid-stream changes tokens only from the next iteration on,
    and the monotonic step guard rejects stale installs."""
    model, params = setup
    params2 = jax.tree.map(lambda x: x * 1.05, params)
    scfg = ServeConfig(max_len=48, slots=2, prefill_chunk=4)
    eng = ContinuousEngine(model, None, scfg, params=params)
    assert eng.install_weights(params, 1)
    t = eng.submit([5, 6, 7, 8], 6)
    eng.step()
    assert eng.install_weights(params2, 2)
    assert not eng.install_weights(params, 1)  # stale: rejected
    drive(eng, [t])
    assert eng.weights_step == 2
    assert len(t.result(timeout=0)) == 6


def test_fresh_default_config():
    """Engines built without a config must not share one mutable default."""
    cfg = get_config("tinyllama-1.1b").reduced(pattern_repeats=2)
    model = Model(cfg)
    a = ServeEngine(model, None)
    b = ServeEngine(model, None)
    assert a.config is not b.config
    a.config.max_len = 7
    assert b.config.max_len != 7


def test_stats_shim_and_registry(setup):
    model, params = setup
    eng = ContinuousEngine(
        model, None, ServeConfig(max_len=48, slots=2, prefill_chunk=4),
        params=params)
    drive(eng, [eng.submit([5, 6, 7], 2)])
    # dict surface still live
    assert eng.stats["tokens_out"] == 2
    assert eng.stats["completed"] == 1
    # registry carries the same counters (plus histograms/gauges)
    snap = eng.registry.snapshot()
    assert snap["tokens_out"] == 2
    # the callable shim warns once and returns the registry snapshot
    import repro.serve.engine as engine_mod
    engine_mod._stats_deprecation_warned = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        via_call = eng.stats()
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert via_call["tokens_out"] == 2


def test_refresh_span_carries_publish_uuid(setup):
    model, params = setup
    eng = ContinuousEngine(
        model, None, ServeConfig(max_len=48, slots=2, prefill_chunk=4),
        params=params)
    prev = obs_trace.get_tracer()
    tracer = obs_trace.enable(capacity=1000)
    try:
        eng.install_weights(params, 3, publish_uuid="publish.run.3")
    finally:
        obs_trace.set_tracer(prev)
        tracer.close()
    spans = [e for e in tracer.events()
             if e.get("ev") == "span" and e.get("name") == "weight_refresh"]
    assert len(spans) == 1
    assert spans[0]["publish_uuid"] == "publish.run.3"
    assert spans[0]["step"] == 3
    assert spans[0]["trace"] == obs_trace.txn_trace_id("publish.run.3")
