"""Property test (hypothesis): the gossip-fed read fast path is safe.

Under ARBITRARY multicast fault schedules — seeded drop / delay / reorder /
duplicate — interleaved with concurrent writers, agent rounds, and reads:

* **read-atomic audits report zero anomalies**: every pair-write commits
  both keys of a cowritten pair with identical payloads, so a reader that
  observes two different payloads inside one (read-only) transaction has
  witnessed a fractured read (Definition 1, §3.4) — whatever the bus did;
* **snapshot reads never lie**: a served bounded-staleness read returns a
  version at or below its watermark, and never *misses* a committed
  version at or below the watermark (the watermark is a completeness
  promise — losing an announcement must stall it, fail-safe, not let a
  newer covered commit go unseen).

The oracle is the writers' own synchronous commit log: an entry is added
only after ``commit_transaction`` returned, so every oracle entry with
timestamp ≤ a later snapshot's watermark was durable before that read.
"""

import pytest

from repro.core import (
    AftCluster,
    AftNodeConfig,
    BusFaults,
    ClusterConfig,
    SnapshotUnavailable,
)
from repro.storage import MemoryStorage

pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402

PAIRS = [("a1", "a2"), ("b1", "b2"), ("c1", "c2")]


def make_cluster(n=3):
    cfg = ClusterConfig(
        num_nodes=n,
        node=AftNodeConfig(),
        start_background_threads=False,
    )
    return AftCluster(MemoryStorage(), cfg)


ops_strategy = st.lists(
    st.one_of(
        st.tuples(st.just("write"), st.integers(0, 1), st.integers(0, 2)),
        st.tuples(st.just("step")),
        st.tuples(st.just("read"), st.integers(0, 2)),
        st.tuples(st.just("snap"), st.integers(0, 5)),
    ),
    min_size=6,
    max_size=40,
)


@settings(
    max_examples=30, deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    ops=ops_strategy,
    drop=st.sampled_from([0.0, 0.15, 0.5]),
    delay=st.sampled_from([0.0, 0.3]),
    delay_rounds=st.integers(min_value=1, max_value=3),
    reorder=st.sampled_from([0.0, 0.3]),
    duplicate=st.sampled_from([0.0, 0.3]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_read_path_safe_under_bus_faults(
    ops, drop, delay, delay_rounds, reorder, duplicate, seed
):
    cluster = make_cluster(3)
    cluster.bus.set_faults(BusFaults(
        drop_rate=drop, delay_rate=delay, delay_rounds=delay_rounds,
        reorder_rate=reorder, duplicate_rate=duplicate, seed=seed,
    ))
    writers = [cluster.nodes[0], cluster.nodes[1]]
    reader = cluster.nodes[2]
    # oracle: key → [(commit timestamp, payload)], appended only after the
    # synchronous commit returned (so entries are durably committed)
    oracle = {k: [] for pair in PAIRS for k in pair}
    counter = 0
    anomalies = []

    for op in ops:
        if op[0] == "write":
            _, w, p = op
            counter += 1
            payload = f"{w}:{counter}".encode()
            node = writers[w]
            tx = node.start_transaction()
            for key in PAIRS[p]:
                node.put(tx, key, payload)
            tid = node.commit_transaction(tx)
            for key in PAIRS[p]:
                oracle[key].append((tid.timestamp, payload))
        elif op[0] == "step":
            cluster.step_all()
        elif op[0] == "read":
            _, p = op
            k1, k2 = PAIRS[p]
            tx = reader.start_transaction(read_only=True)
            v1 = reader.get(tx, k1)
            v2 = reader.get(tx, k2)
            reader.commit_transaction(tx)
            # both keys of a pair are only ever written together with
            # identical payloads: two different non-NULL payloads is a
            # fractured read (a NULL beside a value mirrors Algorithm 1's
            # dynamic read sets — stale-but-atomic, not a fracture)
            if v1 is not None and v2 is not None and v1 != v2:
                anomalies.append((k1, v1, k2, v2))
        elif op[0] == "snap":
            _, i = op
            key = [k for pair in PAIRS for k in pair][i]
            try:
                snap = reader.snapshot_read(key, max_staleness_s=3600.0)
            except SnapshotUnavailable:
                continue  # fail-safe degradation is always legal
            wm = snap.watermark_ns
            got_ts = snap.tid.timestamp if snap.tid is not None else -1
            # (a) never serve a version from beyond the watermark
            assert got_ts <= wm, (key, got_ts, wm)
            # (b) never miss a committed version covered by the watermark
            missed = [(ts, v) for ts, v in oracle[key] if got_ts < ts <= wm]
            assert not missed, (key, got_ts, wm, missed)

    assert anomalies == [], anomalies
    # heal the bus and let anti-entropy converge: the reader must end up
    # seeing every pair at its newest committed payload
    cluster.bus.set_faults(None)
    agent = cluster.agents[reader.node_id]
    for _ in range(agent.gap_repair_rounds + 2):
        cluster.step_all()
    for pair in PAIRS:
        k1, k2 = pair
        if not oracle[k1]:
            continue
        tx = reader.start_transaction(read_only=True)
        v1 = reader.get(tx, k1)
        v2 = reader.get(tx, k2)
        reader.commit_transaction(tx)
        newest = max(oracle[k1])[1]
        assert v1 == newest and v2 == newest, (pair, v1, v2, newest)


@settings(
    max_examples=20, deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    writes=st.integers(min_value=1, max_value=8),
    drop_first=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_snapshot_watermark_stalls_never_lies(writes, drop_first, seed):
    """Losing announcements may only make snapshots UNAVAILABLE or more
    stale-but-honest — never wrong.  With the bus silenced entirely the
    reader's watermark cannot cover any of the lost commits."""
    cluster = make_cluster(2)
    n0, reader = cluster.nodes
    cluster.step_all()  # establish contact so the watermark can advance
    if drop_first:
        cluster.bus.set_faults(BusFaults(drop_rate=1.0, seed=seed))
    tids = []
    for i in range(writes):
        tx = n0.start_transaction()
        n0.put(tx, "k", f"v{i}".encode())
        tids.append(n0.commit_transaction(tx))
    cluster.step_all()
    try:
        snap = reader.snapshot_read("k", max_staleness_s=3600.0)
    except SnapshotUnavailable:
        return
    wm = snap.watermark_ns
    if drop_first:
        # every announcement since contact was dropped: the watermark must
        # sit below ALL the unheard commits (fail-safe stall)
        assert wm < tids[0].timestamp
        assert snap.tid is None or snap.tid.timestamp <= wm
    else:
        assert snap.tid == tids[-1]
        assert snap.value == f"v{writes - 1}".encode()
