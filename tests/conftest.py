"""Skip jax-dependent test modules when jax is unavailable.

CI installs only the ``dev`` extras; the AFT core, faas, and workflow
suites are framework-free and run everywhere, while the model/serving/
checkpoint/kernel suites need the ``jax`` extra.
"""

import importlib.util

collect_ignore = []
if importlib.util.find_spec("jax") is None:
    collect_ignore = [
        "test_arch_smoke.py",
        "test_checkpoint.py",
        "test_kernels.py",
        "test_models_blocks.py",
        "test_property_ckpt.py",
        "test_serve_continuous.py",
        "test_serve_lane.py",
        "test_trainer_serve.py",
    ]
