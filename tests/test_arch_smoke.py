"""Per-architecture smoke tests on reduced configs (CPU).

Every assigned arch: forward shapes + finiteness, one train step (loss
decreases over a few steps on the synthetic grammar), and the
prefill→decode consistency invariant — the logits for the next token after
a prompt must agree between the full forward pass and the incremental
decode path (KV caches / SSM states / xLSTM states all exercised).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, get_config, list_configs
from repro.models.model import padded_vocab
from repro.train import get_optimizer
from repro.train.data import data_for_model

ARCHS = list(list_configs())


def _frontend(cfg, batch, key):
    if cfg.is_encoder_decoder:
        return jax.random.normal(key, (batch, cfg.encoder_seq, cfg.d_model),
                                 jnp.float32)
    if cfg.vision_seq:
        return jax.random.normal(key, (batch, cfg.vision_seq, cfg.d_model),
                                 jnp.float32)
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    fe = _frontend(cfg, B, jax.random.key(2))
    logits, aux = model.forward(params, tokens, fe)
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    # padded vocab entries must be masked
    if padded_vocab(cfg) != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e29

    batch = {"tokens": tokens, "labels": tokens}
    if fe is not None:
        batch["frontend"] = fe
    opt = get_optimizer("adamw", lr=5e-3, warmup_steps=1)
    state = opt.init(params)
    loss0, _ = model.loss_fn(params, batch)

    @jax.jit
    def step(p, s, i):
        (l, m), g = jax.value_and_grad(model.loss_fn, has_aux=True)(p, batch)
        p, s = opt.update(g, s, p, i)
        return p, s, l

    for i in range(4):
        params, state, loss = step(params, state, jnp.int32(i))
    assert bool(jnp.isfinite(loss))
    assert float(loss) < float(loss0), f"{arch}: loss did not decrease"


@pytest.mark.flaky(reruns=2)
@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    # MoE: capacity is enforced over the *visible* tokens, so a token the
    # full-prompt prefill drops may route fine in single-token decode —
    # a real (documented) semantic of capacity-based MoE.  The consistency
    # invariant is exact only in the drop-free regime: raise the capacity.
    cfg = get_config(arch).reduced(capacity_factor=8.0)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                cfg.vocab_size)
    fe = _frontend(cfg, B, jax.random.key(2))

    # ground truth: full forward over S+1 tokens; logits at position S
    logits_full, _ = model.forward(params, tokens, fe)
    want = logits_full[:, S, :]

    # incremental: prefill S tokens, then decode token S at position S
    _, state = model.prefill(params, tokens[:, :S], S + 4, fe)
    got, _ = model.decode_step(params, state, tokens[:, S:S + 1],
                               jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(got[:, 0, :cfg.vocab_size], np.float32),
        np.asarray(want[:, :cfg.vocab_size], np.float32),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_chain_finite(arch):
    """A few chained decode steps stay finite and update the state."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    fe = _frontend(cfg, B, jax.random.key(2))
    logits, state = model.prefill(params, tokens, S + 8, fe)
    cur = jnp.argmax(logits[:, -1:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    for i in range(4):
        logits, state = model.decode_step(params, state, cur,
                                          jnp.int32(S + i))
        assert bool(jnp.isfinite(logits).all()), f"{arch} step {i}"
        cur = jnp.argmax(logits[:, -1:, :cfg.vocab_size],
                         axis=-1).astype(jnp.int32)


def test_prefill_decode_consistency_int8_kv():
    """int8 KV-cache decode stays close to the full-precision forward."""
    cfg = get_config("tinyllama-1.1b").reduced(kv_cache_dtype="int8")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                cfg.vocab_size)
    logits_full, _ = model.forward(params, tokens)
    want = logits_full[:, S, :cfg.vocab_size]
    _, state = model.prefill(params, tokens[:, :S], S + 4)
    # int8 state carries quantization scales
    leaf_paths = {p for p, _ in
                  __import__("repro.checkpoint.serializer",
                             fromlist=["tree_paths"]).tree_paths(state)}
    assert any(p.endswith("/ks") for p in leaf_paths)
    got, _ = model.decode_step(params, state, tokens[:, S:S + 1],
                               jnp.int32(S))
    err = float(jnp.abs(got[:, 0, :cfg.vocab_size] - want).max())
    assert err < 0.3, f"int8 decode drift too large: {err}"


def test_data_pipeline_is_deterministic():
    cfg = get_config("tinyllama-1.1b").reduced()
    d1 = data_for_model(cfg, 4, 16, seed=7)
    d2 = data_for_model(cfg, 4, 16, seed=7)
    b1, b2 = d1.batch_at(123), d2.batch_at(123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch_at(124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
