"""Property test (hypothesis): the group-commit ordering invariant.

Under ARBITRARY coalescing and flush interleavings — page size, flush
concurrency, worker count, linger, submission timing — no commit record may
ever become durable in storage before ALL of its version keys and its ``u/``
uuid-index entry.  This is §3.3's write-ordering protocol lifted to the
cross-transaction group commit of ``storage/pipeline.py``: the barrier is
per transaction (the record is chained behind its own version group's
future), never per flush, and this suite searches the schedule space for a
coalescing pattern that breaks it.
"""

import time

import pytest

from repro.core import AftNode, AftNodeConfig
from repro.core.records import COMMIT_PREFIX

from test_pipeline import RecordingStorage, assert_record_ordering

pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402


@settings(
    max_examples=30, deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    txns=st.lists(
        st.lists(
            st.integers(min_value=0, max_value=40),
            min_size=1, max_size=6, unique=True,
        ),
        min_size=2, max_size=10,
    ),
    flush_max=st.integers(min_value=1, max_value=12),
    flush_conc=st.integers(min_value=1, max_value=4),
    workers=st.integers(min_value=1, max_value=4),
    linger_ms=st.sampled_from([0.0, 0.5, 3.0]),
    stagger=st.booleans(),
)
def test_group_commit_ordering_invariant(
    txns, flush_max, flush_conc, workers, linger_ms, stagger
):
    store = RecordingStorage()
    node = AftNode(
        store,
        AftNodeConfig(
            node_id="n0", io_workers=workers, flush_max_items=flush_max,
            flush_linger_ms=linger_ms, flush_concurrency=flush_conc,
        ),
    )
    futures = []
    for i, keys in enumerate(txns):
        tx = node.start_transaction()
        for k in keys:
            node.put(tx, f"pk/{k}", f"{i}".encode())
        futures.append(node.commit_transaction_async(tx))
        if stagger and i % 2:
            time.sleep(0.0005)  # vary arrival phase vs the flusher
    for f in futures:
        assert f.result(20) is not None
    # overlapping write sets mean a later commit can share keys with an
    # earlier one, but every uuid commits exactly once
    assert len(store.list_keys(COMMIT_PREFIX)) == len(txns)
    assert_record_ordering(store)
    node.close_pipeline()
