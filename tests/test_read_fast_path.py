"""Read fast path: the read-only transaction lane and bounded-staleness
snapshot reads (core/node.py), plus their workflow-layer plumbing
(Step.read_only through executor and pool)."""

import pytest

from repro.core import (
    AftCluster,
    AftNode,
    AftNodeConfig,
    ClusterConfig,
    ReadOnlyTransaction,
    SnapshotUnavailable,
)
from repro.core.records import COMMIT_PREFIX
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.storage import MemoryStorage
from repro.workflow import (
    TxnScope,
    WorkflowConfig,
    WorkflowExecutor,
    WorkflowSpec,
)


@pytest.fixture
def node():
    return AftNode(MemoryStorage(), AftNodeConfig(node_id="n0"))


def make_cluster(n=2, **node_kw):
    cfg = ClusterConfig(
        num_nodes=n,
        node=AftNodeConfig(**node_kw),
        start_background_threads=False,
    )
    return AftCluster(MemoryStorage(), cfg)


def put_commit(node, items, uuid=None):
    tx = node.start_transaction(uuid)
    for k, v in items.items():
        node.put(tx, k, v)
    return node.commit_transaction(tx)


# ------------------------------------------------------- read-only lane
def test_read_only_txn_reads_and_commits(node):
    put_commit(node, {"k": b"v"})
    tx = node.start_transaction(read_only=True)
    assert node.get(tx, "k") == b"v"
    tid = node.commit_transaction(tx)
    assert tid is not None
    # idempotent re-commit of the same scope returns the same tid
    assert node.commit_transaction(tx) == tid


def test_read_only_txn_rejects_writes(node):
    tx = node.start_transaction(read_only=True)
    with pytest.raises(ReadOnlyTransaction):
        node.put(tx, "k", b"v")
    # the scope is still usable for reads and commits after the rejection
    assert node.get(tx, "k") is None
    node.commit_transaction(tx)


def test_read_only_commit_writes_nothing_durable():
    storage = MemoryStorage()
    node = AftNode(storage, AftNodeConfig(node_id="n0"))
    put_commit(node, {"k": b"v"})
    before = sorted(storage.list_keys(""))
    tx = node.start_transaction(read_only=True)
    node.get(tx, "k")
    node.commit_transaction(tx)
    assert sorted(storage.list_keys("")) == before  # no record, no u/ index


def test_read_only_commit_does_not_poison_retry_probe(node):
    """A read-only commit must NOT enter the §3.3.1 committed-uuid set: a
    later non-read-only retry of the same uuid would find the probe
    satisfied and skip its writes."""
    tx = node.start_transaction("wf-uuid", read_only=True)
    node.commit_transaction(tx)
    assert not list(node.storage.list_keys(COMMIT_PREFIX))
    # the same uuid re-driven as a writing transaction commits for real
    tx2 = node.start_transaction("wf-uuid")
    node.put(tx2, "k", b"v")
    node.commit_transaction(tx2)
    tx3 = node.start_transaction()
    assert node.get(tx3, "k") == b"v"


def test_read_only_async_commit_delegates(node):
    put_commit(node, {"k": b"v"})
    tx = node.start_transaction(read_only=True)
    assert node.get(tx, "k") == b"v"
    fut = node.commit_transaction_async(tx)
    tid = fut.result()
    assert tid is not None
    assert node.commit_transaction(tx) == tid


def test_read_only_through_client():
    cluster = make_cluster(2)
    from repro.core import AftClient

    client = AftClient(cluster)
    n0 = cluster.nodes[0]
    put_commit(n0, {"k": b"v"})
    cluster.step_all()
    tx = client.start_transaction(read_only=True)
    with pytest.raises(ReadOnlyTransaction):
        client.put(tx, "k", b"x")
    client.commit_transaction(tx)


# ------------------------------------------------------- snapshot reads
def test_snapshot_read_single_node_serves_latest(node):
    tid = put_commit(node, {"k": b"v"})
    snap = node.snapshot_read("k", max_staleness_s=5.0)
    assert snap.value == b"v"
    assert snap.tid == tid
    assert snap.watermark_ns >= tid.timestamp
    assert node.stats["snapshot_reads"] == 1


def test_snapshot_read_missing_key_is_null(node):
    snap = node.snapshot_read("ghost", max_staleness_s=5.0)
    assert snap.value is None and snap.tid is None


def test_snapshot_read_ignores_versions_above_watermark(node):
    """A version committed after the watermark was taken is invisible to
    the snapshot — pin the watermark via the provider hook."""
    t1 = put_commit(node, {"k": b"v1"})
    wm = node.read_watermark_ns()
    node.set_watermark_provider(lambda: wm)
    put_commit(node, {"k": b"v2"})  # newer than the pinned watermark
    snap = node.snapshot_read("k", max_staleness_s=3600.0)
    assert snap.tid == t1
    assert snap.value == b"v1"


def test_snapshot_unavailable_when_lag_exceeds_bound(node):
    node.set_watermark_provider(lambda: 0)  # hopelessly stale floor
    put_commit(node, {"k": b"v"})
    with pytest.raises(SnapshotUnavailable):
        node.snapshot_read("k", max_staleness_s=0.001)
    assert node.stats["snapshot_unavailable"] == 1


def test_snapshot_read_cluster_waits_for_gossip():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    tid = put_commit(n0, {"k": b"v"})
    # before any gossip round n1's watermark floors at -1: fail-safe
    with pytest.raises(SnapshotUnavailable):
        n1.snapshot_read("k", max_staleness_s=1.0)
    cluster.step_all()
    snap = n1.snapshot_read("k", max_staleness_s=3600.0)
    assert snap.value == b"v"
    assert snap.tid == tid
    assert snap.lag_ns >= 0


def test_phase1_confirmation_tombstones_unknown_records(node):
    """Global GC phase 1 on a node that never learned the commit must still
    tombstone the write-set keys: confirming licenses storage erasure, after
    which the snapshot lane can no longer prove completeness below any
    watermark covering the erased version."""
    from repro.core.records import TransactionRecord
    from repro.core.ids import TxnId

    ghost = TransactionRecord(tid=TxnId(1234, "never-seen"),
                              write_set=("p", "q"))
    confirmed = node.confirm_locally_deleted([ghost])
    assert confirmed == [ghost.tid]
    assert node.cache.pruned_max_ts("p") == 1234
    assert node.cache.pruned_max_ts("q") == 1234
    assert node.cache.get(ghost.tid) is None  # tombstone only, not indexed


def test_snapshot_fails_safe_when_global_gc_erased_unlearned_version():
    """A dropped announcement + immediate supersedence + global GC: the
    reader never learns the old version, storage forgets it, yet the
    reader's watermark comes to cover its timestamp.  The snapshot lane
    must refuse to serve (it would otherwise return NULL/stale and silently
    miss a covered commit) — and must recover once the watermark passes the
    superseding version."""
    cluster = make_cluster(2)
    n0, reader = cluster.nodes
    cluster.step_all()  # contact + seq baseline

    from repro.core import BusFaults

    cluster.bus.set_faults(BusFaults(drop_rate=1.0))
    t_old = put_commit(n0, {"a1": b"old", "a2": b"old"})  # announcement lost
    cluster.bus.set_faults(None)
    t_new = put_commit(n0, {"a1": b"new", "a2": b"new"})  # supersedes t_old

    # global GC erases the superseded commit before the reader's gap repair
    # can rescan storage; phase 1 tombstones it on the reader
    fm = cluster.fault_manager
    fm.scan_commit_set()
    assert fm.gc_round() == 1
    assert reader.cache.pruned_max_ts("a2") == t_old.timestamp

    # let gap repair learn the superseding version, then pin the peer floor
    # inside [t_old, t_new): the watermark covers the erased version but not
    # its successor — exactly the covered-but-unservable window
    agent = cluster.agents[reader.node_id]
    for _ in range(agent.gap_repair_rounds + 1):
        cluster.step_all()
    assert reader.cache.latest_version_of("a2") == t_new
    live_provider = reader._watermark_provider
    reader.set_watermark_provider(lambda: t_new.timestamp - 1)
    with pytest.raises(SnapshotUnavailable):
        reader.snapshot_read("a2", max_staleness_s=3600.0)

    # once the watermark covers the superseding version the lane self-heals
    reader.set_watermark_provider(live_provider)
    assert reader.read_watermark_ns() >= t_new.timestamp
    snap = reader.snapshot_read("a2", max_staleness_s=3600.0)
    assert snap.value == b"new"
    assert snap.tid == t_new


def test_client_snapshot_read_routes():
    cluster = make_cluster(2)
    from repro.core import AftClient

    client = AftClient(cluster)
    put_commit(cluster.nodes[0], {"k": b"v"})
    cluster.step_all()
    snap = client.snapshot_read("k", max_staleness_s=3600.0)
    assert snap.value == b"v"


# --------------------------------------------- workflow-layer plumbing
def run_wf(spec, *, config):
    platform = LambdaPlatform(FaasConfig(warm_latency_ms=0.0))
    cluster = make_cluster(1)
    ex = WorkflowExecutor(platform, cluster=cluster, config=config)
    return ex, ex.run(spec)


def ro_spec(body=None):
    spec = WorkflowSpec("ro")
    spec.step("write", lambda ctx: ctx.put("k", b"v") or "w")
    spec.step(
        "read",
        body or (lambda ctx: (ctx.get("k") or b"").decode()),
        deps=("write",),
        reads=("k",),
        read_only=True,
    )
    spec.validate()
    return spec


def test_read_only_step_runs_on_fast_lane():
    cfg = WorkflowConfig(scope=TxnScope.STEP, memoize=True)
    ex, res = run_wf(ro_spec(), config=cfg)
    assert res.results["read"] == "v"
    node = ex.cluster.nodes[0]
    # exactly two commit records would mean the read step wrote one; the
    # fast lane leaves only the write step's record (+ its memo commit)
    records = list(node.storage.list_keys(COMMIT_PREFIX))
    uuids = {k for k in records if "read" in k}
    assert not uuids  # no commit record for the read-only step


def test_read_only_step_write_attempt_fails_step():
    cfg = WorkflowConfig(scope=TxnScope.STEP, max_attempts=1)
    spec = ro_spec(body=lambda ctx: ctx.put("x", b"boom"))
    platform = LambdaPlatform(FaasConfig(warm_latency_ms=0.0))
    cluster = make_cluster(1)
    ex = WorkflowExecutor(platform, cluster=cluster, config=cfg)
    with pytest.raises(Exception) as ei:
        ex.run(spec)
    step_failure = ei.value.__cause__
    assert isinstance(step_failure.cause, ReadOnlyTransaction)


def test_read_only_lane_can_be_disabled():
    cfg = WorkflowConfig(scope=TxnScope.STEP, read_only_lane=False)
    spec = ro_spec(body=lambda ctx: ctx.put("x", b"ok") or "wrote")
    ex, res = run_wf(spec, config=cfg)
    # with the lane off, read_only is advisory: the write goes through
    assert res.results["read"] == "wrote"
