"""End-to-end FaaS workload (§6.1.2 shape): AFT prevents anomalies that plain
storage exhibits; retries with failure injection stay exactly-once."""

import pytest

from repro.core import AftCluster, AftNodeConfig, ClusterConfig
from repro.faas import FaasConfig, WorkloadConfig, run_workload
from repro.faas.workload import ZipfSampler, build_txn_spec
from repro.storage import MemoryStorage, dynamodb_like


def fast_faas(**kw):
    return FaasConfig(warm_latency_ms=0.1, time_scale=0.05, **kw)


def small_cfg(**kw):
    base = dict(
        num_keys=40,
        zipf=1.0,
        functions_per_txn=2,
        reads_per_function=2,
        writes_per_function=1,
        value_bytes=128,
        faas=fast_faas(),
    )
    base.update(kw)
    return WorkloadConfig(**base)


def test_zipf_sampler_skew():
    s = ZipfSampler(100, 2.0, seed=1)
    draws = [s.sample() for _ in range(2000)]
    assert min(draws) == 0
    # heavily skewed: top key dominates
    assert draws.count(0) > 2000 * 0.4


def test_txn_spec_shape():
    cfg = small_cfg()
    spec = build_txn_spec(cfg, ZipfSampler(10, 1.0))
    assert len(spec.functions) == 2
    assert all(len(ops) == 3 for ops in spec.functions)


def test_aft_workload_zero_anomalies():
    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(
            num_nodes=2,
            node=AftNodeConfig(multicast_interval_s=0.02, gc_interval_s=0.05),
        ),
    )
    try:
        res = run_workload(
            "aft", cfg=small_cfg(), clients=8, txns_per_client=15, cluster=cluster
        )
    finally:
        cluster.stop()
    assert res.committed == 8 * 15
    assert res.anomalies["ryw_anomalies"] == 0
    assert res.anomalies["fr_anomalies"] == 0


def test_plain_workload_exhibits_anomalies():
    # eventually-consistent engine + in-place overwrites + contention
    storage = dynamodb_like(time_scale=0.05)
    res = run_workload(
        "plain",
        cfg=small_cfg(num_keys=10, zipf=1.5),
        clients=12,
        txns_per_client=15,
        storage=storage,
    )
    assert res.committed == 12 * 15
    total = res.anomalies["ryw_anomalies"] + res.anomalies["fr_anomalies"]
    assert total > 0, "plain mode should leak anomalies under contention"


def test_dynamo_txn_mode_avoids_ryw_but_not_fr():
    storage = dynamodb_like(time_scale=0.05)
    res = run_workload(
        "dynamo_txn",
        cfg=small_cfg(num_keys=10, zipf=1.5),
        clients=12,
        txns_per_client=15,
        storage=storage,
    )
    assert res.anomalies["ryw_anomalies"] == 0  # single atomic write batch


def test_exactly_once_under_failure_injection():
    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(
            num_nodes=1,
            node=AftNodeConfig(multicast_interval_s=0.02),
        ),
    )
    cfg = small_cfg(faas=fast_faas(failure_rate=0.15, max_retries=25))
    try:
        res = run_workload(
            "aft", cfg=cfg, clients=4, txns_per_client=10, cluster=cluster
        )
        node_commits = sum(n.stats["commits"] for n in cluster.all_nodes())
    finally:
        cluster.stop()
    assert res.committed == 40
    assert res.anomalies["ryw_anomalies"] == 0
    assert res.anomalies["fr_anomalies"] == 0
    # exactly-once: every logical request commits exactly one transaction,
    # no matter how many times its functions were retried
    assert node_commits == 40
    assert res.retries > 0, "failure injection should have caused retries"
