"""Property test (hypothesis): elastic membership is safe (ISSUE 9).

Under ARBITRARY schedules of join / drain / ramp / hot-arc-split events,
interleaved with writers, agent rounds, reads, and snapshot reads — all on
a faulty multicast bus (seeded drop / delay / reorder / duplicate):

* **read-atomic audits report zero anomalies**: every pair-write commits
  both keys of a cowritten pair with identical payloads, so observing two
  different payloads inside one read-only transaction is a fractured read
  (Definition 1, §3.4) — no matter how membership churned;
* **snapshot reads stay "unavailable, never wrong" across arc handoffs**:
  a served bounded-staleness read returns a version at or below its
  watermark and never misses a committed version covered by it.  Losing a
  node mid-migration may stall watermarks (fail-safe), never lie.

The oracle is the writers' own synchronous commit log, exactly as in
``test_property_read_path.py`` — membership churn must not weaken it.
"""

import random

import pytest

from repro.core import (
    AftCluster,
    AftNodeConfig,
    BusFaults,
    ClusterConfig,
    NodeLifecycle,
    SnapshotUnavailable,
)
from repro.storage import MemoryStorage

try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # seeded fallback below still runs
    HAVE_HYPOTHESIS = False

PAIRS = [("a1", "a2"), ("b1", "b2"), ("c1", "c2")]
ALL_KEYS = [k for pair in PAIRS for k in pair]
MAX_NODES = 5
OP_KINDS = ("write", "step", "read", "snap", "join", "drain", "ramp", "split")


def make_cluster(n=3):
    cfg = ClusterConfig(
        num_nodes=n,
        node=AftNodeConfig(),
        start_background_threads=False,
        routing="consistent_hash",
        drain_timeout_s=0.2,
    )
    return AftCluster(MemoryStorage(), cfg)


def run_elastic_schedule(ops, drop, delay, reorder, duplicate, seed):
    """Drive one randomized join/drain/split schedule and assert the two
    elastic-safety properties (shared by the hypothesis sweep and the
    seeded fallback)."""
    cluster = make_cluster(3)
    cluster.bus.set_faults(BusFaults(
        drop_rate=drop, delay_rate=delay, delay_rounds=2,
        reorder_rate=reorder, duplicate_rate=duplicate, seed=seed,
    ))
    # oracle: key → [(commit timestamp, payload)], appended only after the
    # synchronous commit returned
    oracle = {k: [] for k in ALL_KEYS}
    counter = 0
    anomalies = []

    def routable():
        return cluster.routable_nodes()

    for op in ops:
        kind = op[0]
        if kind == "write":
            counter += 1
            payload = f"w:{counter}".encode()
            node = routable()[counter % len(routable())]
            tx = node.start_transaction()
            for key in PAIRS[op[1]]:
                node.put(tx, key, payload)
            tid = node.commit_transaction(tx)
            node.release_transaction(tx)
            for key in PAIRS[op[1]]:
                oracle[key].append((tid.timestamp, payload))
        elif kind == "step":
            cluster.step_all()
        elif kind == "read":
            k1, k2 = PAIRS[op[1]]
            reader = routable()[0]
            tx = reader.start_transaction(read_only=True)
            v1 = reader.get(tx, k1)
            v2 = reader.get(tx, k2)
            reader.commit_transaction(tx)
            if v1 is not None and v2 is not None and v1 != v2:
                anomalies.append((k1, v1, k2, v2))
        elif kind == "snap":
            key = ALL_KEYS[op[1]]
            reader = routable()[-1]
            try:
                snap = reader.snapshot_read(key, max_staleness_s=3600.0)
            except SnapshotUnavailable:
                continue  # fail-safe degradation is always legal
            wm = snap.watermark_ns
            got_ts = snap.tid.timestamp if snap.tid is not None else -1
            # (a) never serve from beyond the watermark
            assert got_ts <= wm, (key, got_ts, wm)
            # (b) never miss a committed version covered by the watermark
            missed = [(ts, v) for ts, v in oracle[key] if got_ts < ts <= wm]
            assert not missed, (key, got_ts, wm, missed)
        elif kind == "join":
            if len(cluster.live_nodes()) < MAX_NODES:
                cluster.join_node(ramp=True)
        elif kind == "drain":
            candidates = [
                n for n in cluster.live_nodes()
                if cluster.lifecycle_of(n) is NodeLifecycle.LIVE
            ]
            if len(candidates) > 1:
                cluster.drain_node(candidates[-1], wait=False)
        elif kind == "ramp":
            cluster.advance_lifecycle()
        elif kind == "split":
            targets = routable()
            if len(targets) > 1:
                cluster.router.split_hot_arc(
                    targets[0].node_id, min_ratio=2.0
                )

    assert anomalies == [], anomalies

    # heal the bus, settle all migrations, and let anti-entropy converge:
    # whatever membership we ended at, a reader sees every pair at its
    # newest committed payload
    cluster.bus.set_faults(None)
    for _ in range(6):
        cluster.step_all()
    reader = cluster.routable_nodes()[0]
    agent = cluster.agents[reader.node_id]
    for _ in range(agent.gap_repair_rounds + 2):
        cluster.step_all()
    for k1, k2 in PAIRS:
        if not oracle[k1]:
            continue
        tx = reader.start_transaction(read_only=True)
        v1 = reader.get(tx, k1)
        v2 = reader.get(tx, k2)
        reader.commit_transaction(tx)
        newest = max(oracle[k1])[1]
        assert v1 == newest and v2 == newest, ((k1, k2), v1, v2, newest)
    cluster.stop()


def run_kill_during_migration(writes, kill_donor):
    """A node dying mid-handoff (the kill-during-migration arm): the join
    completes from the survivors, committed data is never lost, and the
    §3.3.1 uuid index keeps retried commits exactly-once on the joiner."""
    cluster = make_cluster(3)
    donor = cluster.live_nodes()[0]
    uuids = []
    for i in range(writes):
        tx = donor.start_transaction()
        donor.put(tx, f"mk{i}", str(i).encode())
        donor.commit_transaction(tx)
        uuids.append(tx)
        donor.release_transaction(tx)
    cluster.step_all()  # commits multicast to the other members
    if kill_donor:
        cluster.fault_manager.on_node_failure = None  # no auto-replace
        cluster.kill_node(0)
    joiner = cluster.join_node(ramp=True)
    for _ in range(4):
        cluster.advance_lifecycle()
    assert cluster.lifecycle_of(joiner) is NodeLifecycle.LIVE
    # warm-up handoff only streams the arcs the joiner now owns; commits on
    # other arcs reach it through gossip anti-entropy, so give the repair
    # protocol its full round budget before auditing visibility
    joiner_agent = cluster.agents[joiner.node_id]
    for _ in range(joiner_agent.gap_repair_rounds + 4):
        cluster.step_all()
    # every committed write is durable and visible from the joiner
    for i in range(writes):
        tx = joiner.start_transaction()
        assert joiner.get(tx, f"mk{i}") == str(i).encode()
        joiner.commit_transaction(tx)
        joiner.release_transaction(tx)
    # idempotence metadata survived the migration: a re-drive of the same
    # uuid resolves to the original commit (no duplicate effects)
    client = cluster.client()
    for u in uuids:
        assert client.committed_tid_for_uuid(u) is not None
    cluster.stop()


def _random_ops(rng, size):
    ops = []
    for _ in range(size):
        kind = rng.choice(OP_KINDS)
        if kind == "write" or kind == "read":
            ops.append((kind, rng.randrange(len(PAIRS))))
        elif kind == "snap":
            ops.append((kind, rng.randrange(len(ALL_KEYS))))
        else:
            ops.append((kind,))
    return ops


# --------------------------------------------------------------- seeded sweep
# Always runs, even where hypothesis isn't installed: fixed seeds, same
# properties.  The hypothesis tests below widen the search when available.

@pytest.mark.parametrize("seed", [7, 23, 401, 2026])
def test_elastic_schedules_safe_seeded(seed):
    rng = random.Random(seed)
    ops = _random_ops(rng, rng.randint(16, 40))
    faults = rng.choice([
        (0.0, 0.0, 0.0, 0.0),
        (0.15, 0.3, 0.0, 0.3),
        (0.4, 0.0, 0.3, 0.0),
    ])
    run_elastic_schedule(ops, *faults, seed=seed)


@pytest.mark.parametrize("kill_donor", [False, True])
def test_kill_during_migration_seeded(kill_donor):
    run_kill_during_migration(writes=4, kill_donor=kill_donor)


# ---------------------------------------------------------- hypothesis sweep
if HAVE_HYPOTHESIS:
    ops_strategy = st.lists(
        st.one_of(
            st.tuples(st.just("write"), st.integers(0, 2)),
            st.tuples(st.just("step")),
            st.tuples(st.just("read"), st.integers(0, 2)),
            st.tuples(st.just("snap"), st.integers(0, 5)),
            st.tuples(st.just("join")),
            st.tuples(st.just("drain")),
            st.tuples(st.just("ramp")),
            st.tuples(st.just("split")),
        ),
        min_size=8,
        max_size=40,
    )

    @settings(
        max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        ops=ops_strategy,
        drop=st.sampled_from([0.0, 0.15, 0.4]),
        delay=st.sampled_from([0.0, 0.3]),
        reorder=st.sampled_from([0.0, 0.3]),
        duplicate=st.sampled_from([0.0, 0.3]),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_elastic_schedules_safe_under_bus_faults(
        ops, drop, delay, reorder, duplicate, seed
    ):
        run_elastic_schedule(ops, drop, delay, reorder, duplicate, seed)

    @settings(
        max_examples=15, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        writes=st.integers(min_value=1, max_value=6),
        kill_donor=st.booleans(),
    )
    def test_kill_during_migration_never_duplicates(writes, kill_donor):
        run_kill_during_migration(writes, kill_donor)
