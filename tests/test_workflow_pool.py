"""WorkflowPool: batched scheduling of many concurrent workflows —
multiplexing, fairness windows, backpressure, exactly-once under injected
crashes, adaptive batch sizing, node-kill rerouting, and finish-marker
handoff to GC."""

import json
import threading

import pytest

from repro.core import AftCluster, ClusterConfig
from repro.core.records import WF_FINISH_PREFIX
from repro.faas.platform import FaasConfig, FunctionFailure, LambdaPlatform
from repro.storage.memory import MemoryStorage
from repro.workflow import (
    AdaptiveBatcher,
    PoolClosed,
    PoolConfig,
    TxnScope,
    WorkflowError,
    WorkflowPool,
    WorkflowSpec,
)


def make_cluster(nodes: int = 1, routing=None) -> AftCluster:
    return AftCluster(
        MemoryStorage(),
        ClusterConfig(
            num_nodes=nodes, start_background_threads=False, routing=routing
        ),
    )


def fast_platform(**kw) -> LambdaPlatform:
    return LambdaPlatform(FaasConfig(time_scale=0.0, **kw))


def chain_spec(i: int, length: int = 3) -> WorkflowSpec:
    """A small linear workflow: each step doubles the previous result."""
    spec = WorkflowSpec(f"chain{i}")

    def first(ctx):
        ctx.maybe_fail()
        ctx.put(f"c/{i}/0", str(i).encode())
        return i

    prev = spec.step("s0", first)
    for j in range(1, length):
        def body(ctx, j=j):
            val = ctx.inputs[f"s{j-1}"] * 2
            ctx.put(f"c/{i}/{j}", str(val).encode())
            return val
        prev = spec.step(f"s{j}", body, deps=[prev])
    return spec


def counter_spec(i: int) -> WorkflowSpec:
    """Read-modify-write of a per-workflow counter — the exactly-once probe:
    any double-applied attempt shows up as count > 1."""
    spec = WorkflowSpec(f"count{i}")

    def bump(ctx):
        raw = ctx.get(f"cnt/{i}")
        count = json.loads(raw)["count"] if raw else 0
        ctx.maybe_fail()
        ctx.put(f"cnt/{i}", json.dumps({"count": count + 1}).encode())
        return count + 1

    spec.step("bump", bump)
    return spec


# ---------------------------------------------------------------------------
# basic multiplexing + batching
# ---------------------------------------------------------------------------

def test_pool_runs_many_concurrent_workflows():
    cluster = make_cluster()
    platform = fast_platform()
    with WorkflowPool(platform, cluster=cluster) as pool:
        tickets = [pool.submit(chain_spec(i)) for i in range(300)]
        results = [t.result(timeout=60) for t in tickets]
    for i, r in enumerate(results):
        assert r.results["s2"] == i * 4
        assert r.attempts == 1
    cluster.stop()


def test_pool_batches_steps_into_shared_invocations():
    """The whole point of the pool: far fewer platform invocations than
    steps, because compatible ready steps share one warm start."""
    cluster = make_cluster()
    platform = fast_platform()
    n = 200
    with WorkflowPool(
        platform, cluster=cluster, config=PoolConfig(batch_max_steps=16)
    ) as pool:
        results = pool.run_all([chain_spec(i) for i in range(n)], timeout=60)
    steps = sum(r.steps_run for r in results)
    assert steps == n * 3
    assert platform.batched_invocations == platform.invocations
    assert platform.batched_steps == steps
    # amortization: strictly fewer invocations than steps (usually ~steps/16)
    assert platform.invocations < steps / 2
    cluster.stop()


def test_pool_exactly_once_under_injected_crashes():
    cluster = make_cluster()
    platform = fast_platform(failure_rate=0.15, seed=13)
    n = 120
    with WorkflowPool(
        platform, cluster=cluster, config=PoolConfig(max_attempts=25)
    ) as pool:
        results = pool.run_all([counter_spec(i) for i in range(n)], timeout=120)
    assert platform.failures_injected > 0  # the hazard actually fired
    assert any(r.attempts > 1 for r in results)
    # each workflow's counter incremented exactly once despite retries
    node = cluster.live_nodes()[0]
    tx = node.start_transaction()
    for i in range(n):
        assert json.loads(node.get(tx, f"cnt/{i}"))["count"] == 1
    node.abort_transaction(tx)
    cluster.stop()


def test_pool_step_scope_and_unscoped_modes():
    cluster = make_cluster()
    with WorkflowPool(
        fast_platform(), cluster=cluster,
        config=PoolConfig(scope=TxnScope.STEP),
    ) as pool:
        results = pool.run_all([chain_spec(i) for i in range(20)], timeout=60)
    assert all(r.results["s2"] == i * 4 for i, r in enumerate(results))
    storage = MemoryStorage()
    with WorkflowPool(
        fast_platform(), storage=storage,
        config=PoolConfig(scope=TxnScope.NONE),
    ) as pool:
        results = pool.run_all([chain_spec(i) for i in range(20)], timeout=60)
    assert all(r.results["s2"] == i * 4 for i, r in enumerate(results))
    cluster.stop()


def test_pool_conditional_skips_match_executor_semantics():
    cluster = make_cluster()
    spec = WorkflowSpec("cond")
    spec.step("root", lambda ctx: 1)
    spec.step("taken", lambda ctx: 2, deps=["root"],
              when=lambda r: r["root"] == 1)
    spec.step("not_taken", lambda ctx: 3, deps=["root"],
              when=lambda r: r["root"] == 99)
    spec.step("downstream", lambda ctx: 4, deps=["not_taken"])  # skip ripples
    spec.fan_in("agg", lambda ctx: sorted(ctx.inputs), ["taken", "not_taken"])
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        r = pool.submit(spec).result(timeout=30)
    assert r.results["agg"] == ["taken"]
    assert set(r.skipped) == {"not_taken", "downstream"}
    cluster.stop()


# ---------------------------------------------------------------------------
# windows, fairness, backpressure
# ---------------------------------------------------------------------------

def test_pool_respects_global_inflight_window():
    cluster = make_cluster()
    platform = fast_platform()
    peak = 0
    active = 0
    lock = threading.Lock()

    def body(ctx):
        nonlocal peak, active
        with lock:
            active += 1
            peak = max(peak, active)
        try:
            return 1
        finally:
            with lock:
                active -= 1

    def spec(i):
        s = WorkflowSpec(f"w{i}")
        s.step("only", body)
        return s

    with WorkflowPool(
        platform, cluster=cluster,
        config=PoolConfig(max_inflight_steps=8, batch_max_steps=4),
    ) as pool:
        pool.run_all([spec(i) for i in range(100)], timeout=60)
    assert peak <= 8
    cluster.stop()


def test_pool_per_workflow_window_preserves_fairness():
    """A 32-branch fan-out workflow must not monopolize the pool: with a
    per-workflow cap of 2, singleton workflows submitted after it still
    finish long before the wide DAG's last branch."""
    cluster = make_cluster()
    order = []
    lock = threading.Lock()

    wide = WorkflowSpec("wide")
    def branch(ctx):
        with lock:
            order.append("wide")
        return ctx.branch
    names = wide.fan_out("b", branch, 32)
    wide.fan_in("agg", lambda ctx: len(ctx.inputs), names)

    def small(i):
        s = WorkflowSpec(f"small{i}")
        def body(ctx):
            with lock:
                order.append(f"small{i}")
            return i
        s.step("only", body)
        return s

    with WorkflowPool(
        fast_platform(), cluster=cluster,
        config=PoolConfig(
            max_inflight_per_workflow=2, batch_max_steps=4,
            max_inflight_steps=8,
        ),
    ) as pool:
        t_wide = pool.submit(wide)
        t_small = [pool.submit(small(i)) for i in range(8)]
        for t in t_small:
            t.result(timeout=60)
        t_wide.result(timeout=60)
    assert order.count("wide") == 32
    # round-robin + per-workflow cap: every singleton body ran before the
    # wide DAG's last branch — the wide workflow could not starve them
    last_small = max(i for i, x in enumerate(order) if x.startswith("small"))
    last_wide = max(i for i, x in enumerate(order) if x == "wide")
    assert last_small < last_wide
    cluster.stop()


def test_pool_backpressure_blocks_submit():
    cluster = make_cluster()
    gate = threading.Event()

    def spec(i):
        s = WorkflowSpec(f"g{i}")
        def body(ctx):
            gate.wait(timeout=30)
            return i
        s.step("only", body)
        return s

    pool = WorkflowPool(
        fast_platform(), cluster=cluster,
        config=PoolConfig(max_admitted_workflows=4),
    )
    tickets = [pool.submit(spec(i)) for i in range(4)]  # fills the window

    blocked_done = threading.Event()
    extra = {}

    def submitter():
        extra["t"] = pool.submit(spec(99))
        blocked_done.set()

    th = threading.Thread(target=submitter, daemon=True)
    th.start()
    assert not blocked_done.wait(timeout=0.3)  # admission window full
    gate.set()  # drain the pool
    assert blocked_done.wait(timeout=30)
    for t in tickets + [extra["t"]]:
        t.result(timeout=30)
    pool.close()
    cluster.stop()


def test_pool_submit_after_close_raises():
    cluster = make_cluster()
    pool = WorkflowPool(fast_platform(), cluster=cluster)
    pool.close()
    with pytest.raises(PoolClosed):
        pool.submit(chain_spec(0))
    cluster.stop()


# ---------------------------------------------------------------------------
# failure exhaustion + resume
# ---------------------------------------------------------------------------

def test_pool_exhausted_attempts_fail_only_that_ticket():
    cluster = make_cluster()
    doomed = WorkflowSpec("doomed")

    def dies(ctx):
        raise FunctionFailure("always")

    doomed.step("a", dies)
    with WorkflowPool(
        fast_platform(), cluster=cluster, config=PoolConfig(max_attempts=3)
    ) as pool:
        bad = pool.submit(doomed)
        good = [pool.submit(chain_spec(i)) for i in range(10)]
        with pytest.raises(WorkflowError):
            bad.result(timeout=30)
        for i, t in enumerate(good):
            assert t.result(timeout=30).results["s2"] == i * 4
    cluster.stop()


def test_pool_resumes_cross_process_redrive_from_memos():
    """Same contract as the executor: an explicit UUID consults memos on the
    first attempt, so a re-driven workflow does not re-run bodies."""
    cluster = make_cluster()
    ran = []

    def build():
        spec = WorkflowSpec("redrive")
        def a(ctx):
            ran.append(1)
            return 7
        spec.step("a", a)
        return spec

    cfg = PoolConfig(declare_finished=False)  # keep memos for the re-drive
    with WorkflowPool(fast_platform(), cluster=cluster, config=cfg) as pool:
        r1 = pool.submit(build(), uuid="pool-redrive").result(timeout=30)
    with WorkflowPool(fast_platform(), cluster=cluster, config=cfg) as pool:
        r2 = pool.submit(build(), uuid="pool-redrive").result(timeout=30)
    assert len(ran) == 1
    assert r1.results == r2.results == {"a": 7}
    assert r2.steps_memoized == 1
    assert r1.committed_tid == r2.committed_tid
    cluster.stop()


def test_pool_reroutes_retry_after_node_kill_with_memoized_resume():
    """A node dies mid-workflow: the retry must route to a live node, replay
    the memoized first step (not re-run it), and commit exactly once."""
    cluster = make_cluster(nodes=2, routing="consistent_hash")
    ran = {"a": 0, "b": 0}
    lock = threading.Lock()
    killed = threading.Event()

    spec = WorkflowSpec("kill-mid")

    def step_a(ctx):
        with lock:
            ran["a"] += 1
        raw = ctx.get("km/cnt")
        count = json.loads(raw)["count"] if raw else 0
        ctx.put("km/cnt", json.dumps({"count": count + 1}).encode())
        return count + 1

    def step_b(ctx):
        with lock:
            ran["b"] += 1
        if not killed.is_set():
            killed.set()
            # hard-kill whichever node serves this workflow's session
            for node in cluster.all_nodes():
                if node.active_transaction_count() > 0:
                    node.fail()
            cluster._sync_router()
            raise FunctionFailure("node died under this step")
        return ctx.inputs["a"] * 10

    spec.step("a", step_a)
    spec.step("b", step_b, deps=["a"])

    with WorkflowPool(
        fast_platform(), cluster=cluster,
        config=PoolConfig(scope=TxnScope.STEP, max_attempts=6),
    ) as pool:
        result = pool.submit(spec, uuid="kill-mid-wf").result(timeout=60)

    assert result.attempts == 2
    assert result.results == {"a": 1, "b": 10}
    assert ran["a"] == 1  # memoized resume: step a's body never re-ran
    assert result.steps_memoized == 1
    # exactly-once effect despite the reroute: counter bumped once, read
    # from durable state via the surviving node
    node = next(n for n in cluster.live_nodes())
    tx = node.start_transaction()
    assert json.loads(node.get(tx, "km/cnt"))["count"] == 1
    node.abort_transaction(tx)
    cluster.stop()


def test_pool_place_steps_spreads_and_preserves_dataflow():
    """STEP scope + place_steps: steps of one workflow land on different
    nodes by their declared reads, yet a dependent still observes its
    upstream's committed write (eager record merge)."""
    cluster = make_cluster(nodes=3, routing="consistent_hash")
    spec = WorkflowSpec("spread")

    def writer(ctx):
        ctx.put("ps/x", b"41")
        return 41

    def reader(ctx):
        raw = ctx.get("ps/x")
        assert raw == b"41", f"dependent lost upstream write: {raw!r}"
        return int(raw) + 1

    spec.step("w", writer, reads=("ps/seed",))
    spec.step("r", reader, deps=["w"], reads=("ps/x",))

    with WorkflowPool(
        fast_platform(), cluster=cluster,
        config=PoolConfig(scope=TxnScope.STEP, place_steps=True),
    ) as pool:
        results = pool.run_all(
            [spec] + [chain_spec(i) for i in range(20)], timeout=60
        )
    assert results[0].results == {"w": 41, "r": 42}
    # placement actually used more than one node for step transactions
    assert sum(1 for n in cluster.live_nodes() if n.stats["commits"] > 0) >= 2
    cluster.stop()


def test_adaptive_batcher_sizes_from_overhead_vs_step_latency():
    cfg = PoolConfig()  # batch_max_steps=None ⇒ adaptive
    b = AdaptiveBatcher(cfg)
    assert b.cap == 8  # historical default until measurements arrive
    # expensive invocations + cheap steps ⇒ batch big (clamped at max)
    for _ in range(20):
        b.observe(body_s=0.001, lead_s=0.1)
    assert b.cap == cfg.adaptive_batch_max
    # cheap invocations + slow steps ⇒ batch small (clamped at min)
    for _ in range(40):
        b.observe(body_s=0.1, lead_s=0.0001)
    assert b.cap == cfg.adaptive_batch_min
    # mid ground: 10ms overhead, 5ms steps, 25% tolerated share ⇒ b = 8
    b2 = AdaptiveBatcher(cfg)
    for _ in range(40):
        b2.observe(body_s=0.005, lead_s=0.010)
    assert b2.cap == 8


def test_adaptive_batcher_never_exceeds_inflight_window():
    """A target above max_inflight_steps would deadlock the full-batch
    dispatch gates; the cap clamps to the window."""
    cfg = PoolConfig(max_inflight_steps=8)
    b = AdaptiveBatcher(cfg)
    for _ in range(20):
        b.observe(body_s=0.001, lead_s=0.5)  # raw target ≫ window
    assert b.cap == 8


def test_adaptive_batcher_static_override_never_moves():
    cfg = PoolConfig(batch_max_steps=16)
    b = AdaptiveBatcher(cfg)
    for _ in range(20):
        b.observe(body_s=0.1, lead_s=0.0001)  # would shrink if adaptive
    assert b.cap == 16


def test_pool_adaptive_default_reports_batch_target_gauge():
    cluster = make_cluster()
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        pool.run_all([chain_spec(i) for i in range(50)], timeout=60)
        cfg = pool.config
        assert (
            cfg.adaptive_batch_min
            <= pool.stats["batch_target"]
            <= cfg.adaptive_batch_max
        )
    cluster.stop()


def test_pool_declares_finished_workflows():
    cluster = make_cluster()
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        results = pool.run_all([chain_spec(i) for i in range(5)], timeout=30)
    markers = cluster.storage.list_keys(WF_FINISH_PREFIX)
    assert len(markers) == 5
    uuids = {m[len(WF_FINISH_PREFIX):] for m in markers}
    assert uuids == {r.workflow_uuid for r in results}
    cluster.stop()


# ---------------------------------------------------------------------------
# late memo hits (rival re-drives) must not pollute the adaptive batcher
# ---------------------------------------------------------------------------

def test_late_rival_memo_skips_body_and_counts_as_memoized():
    """A rival attempt (e.g. a replayed chain trigger) commits a step's memo
    AFTER this attempt's load_all: the dispatch-time probe must replay the
    memo instead of re-running the body."""
    import time as _time

    from repro.workflow import MemoStore
    from repro.workflow.txn import encode_memo

    cluster = make_cluster()
    memo_store = MemoStore(cluster)
    ran = {"a": 0, "b": 0}

    spec = WorkflowSpec("rival")

    def step_a(ctx):
        ran["a"] += 1
        # the rival lands b's memo while a is still executing — after this
        # run's load_all, before b's dispatch
        memo_store.save(
            ctx.workflow_uuid, "b", encode_memo("rival-result", {})
        )
        return "a"

    def step_b(ctx):
        ran["b"] += 1
        return "local-result"

    spec.step("a", step_a)
    spec.step("b", step_b, deps=["a"])

    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        # an explicit uuid marks the run resume-eligible (re-drives race)
        r = pool.submit(spec, uuid="rival-wf").result(timeout=30)
    assert ran == {"a": 1, "b": 0}          # b's body never ran
    assert r.results["b"] == "rival-result"  # the rival's result fed through
    assert r.steps_memoized == 1
    assert pool.stats["late_memo_hits"] == 1
    cluster.stop()


def test_batch_target_survives_memo_hit_resume_burst():
    """Regression: memo-hit 'steps' return in microseconds; feeding them
    into the step-latency EWMA during a resume burst drags the modeled
    latency toward zero and pins batch_target at adaptive_batch_max.  With
    the guard, the gauge tracks the REAL bodies (slow here → small target)."""
    import time as _time

    from repro.workflow import MemoStore
    from repro.workflow.txn import encode_memo

    cluster = make_cluster()
    memo_store = MemoStore(cluster)
    # measurable invoke overhead vs. slow bodies ⇒ the model wants SMALL
    # batches; 30+ near-zero memo-hit samples would say the opposite
    platform = LambdaPlatform(
        FaasConfig(time_scale=0.02, warm_latency_ms=50.0, latency_sigma=0.0)
    )

    def burst_spec(i):
        spec = WorkflowSpec(f"burst{i}")

        def real(ctx):
            # rival-memoize every downstream step while the real body runs
            for name in ("m1", "m2", "m3"):
                memo_store.save(
                    ctx.workflow_uuid, name, encode_memo(name, {})
                )
            _time.sleep(0.002)
            return "real"

        prev = spec.step("real", real)
        for name in ("m1", "m2", "m3"):
            def body(ctx):
                return "never-runs"
            prev = spec.step(name, body, deps=[prev])
        return spec

    cfg = PoolConfig(max_inflight_steps=64)
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [
            pool.submit(burst_spec(i), uuid=f"burst-{i}") for i in range(10)
        ]
        results = [t.result(timeout=60) for t in tickets]
    assert sum(r.steps_memoized for r in results) == 30  # the burst was real
    # the gauge reflects the 2ms real bodies against ~1ms overhead (target
    # ≈ 2), not the microsecond memo hits (which would clamp it to max)
    assert pool.stats["batch_target"] <= 8
    assert pool.stats["batch_target"] < cfg.adaptive_batch_max
    cluster.stop()


# ---------------------------------------------------------------------------
# site-scoped fault injection inside batched invocations
# ---------------------------------------------------------------------------

def test_invoke_batch_evaluates_injection_per_thunk():
    """Regression: batched execution used to dodge invocation-level
    injection entirely.  Each thunk is its own failure candidate, and a
    killed thunk doesn't take down the rest of the batch."""
    platform = fast_platform(
        failure_rate=1.0, failure_sites=("invoke:batch",)
    )
    ran = []
    thunks = [lambda i=i: ran.append(i) or i for i in range(4)]
    out = platform.invoke_batch(thunks)
    assert ran == []                       # every slot died before its body
    assert platform.failures_injected == 4  # per-thunk, counted accurately
    assert all(isinstance(x, FunctionFailure) for x in out)

    # partial injection: survivors still run, in order
    platform2 = fast_platform(
        failure_rate=0.5, failure_sites=("invoke:batch",), seed=3
    )
    ran2 = []
    out2 = platform2.invoke_batch([lambda i=i: ran2.append(i) or i
                                   for i in range(20)])
    survivors = [x for x in out2 if not isinstance(x, FunctionFailure)]
    assert 0 < len(survivors) < 20
    assert ran2 == survivors


def test_pool_exactly_once_under_invoke_batch_injection():
    """The pool under invocation-level kills: steps die before their bodies
    run, workflows retry, effects land exactly once, and the platform's
    injection counters prove batched mode no longer dodges the hazard."""
    cluster = make_cluster()
    platform = fast_platform(
        failure_rate=0.2, failure_sites=("invoke:batch",), seed=7
    )
    n = 60
    with WorkflowPool(
        platform, cluster=cluster, config=PoolConfig(max_attempts=30)
    ) as pool:
        results = pool.run_all([counter_spec(i) for i in range(n)],
                               timeout=120)
    assert platform.failures_injected > 0   # the hazard actually fired
    assert any(r.attempts > 1 for r in results)
    assert pool.stats["workflow_retries"] > 0
    node = cluster.live_nodes()[0]
    tx = node.start_transaction()
    for i in range(n):
        assert json.loads(node.get(tx, f"cnt/{i}"))["count"] == 1
    node.abort_transaction(tx)
    cluster.stop()


def test_executor_submit_path_respects_invoke_site():
    """The unbatched path exposes the matching invoke:single site."""
    platform = fast_platform(
        failure_rate=1.0, failure_sites=("invoke:single",)
    )
    import pytest as _pytest
    with _pytest.raises(FunctionFailure):
        platform.invoke(lambda: 1)
    assert platform.failures_injected == 1
