"""Workflow executor: parallel branches, transaction scoping, exactly-once
resume under injected mid-branch crashes."""

import json
import threading

import pytest

from repro.core import AftCluster, ClusterConfig
from repro.core.records import COMMIT_PREFIX, extract_metadata
from repro.faas.platform import FaasConfig, FunctionFailure, LambdaPlatform
from repro.storage.memory import MemoryStorage
from repro.workflow import (
    TxnScope,
    WorkflowConfig,
    WorkflowError,
    WorkflowExecutor,
    WorkflowSpec,
)

BRANCHES = 8


def make_cluster(nodes: int = 1) -> AftCluster:
    return AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=nodes, start_background_threads=False),
    )


def fast_platform(**kw) -> LambdaPlatform:
    return LambdaPlatform(FaasConfig(time_scale=0.0, **kw))


def fanout_spec(epoch: int = 0) -> WorkflowSpec:
    spec = WorkflowSpec("fanout")

    def branch_fn(ctx):
        key = f"k{ctx.branch}"
        raw = ctx.get(key)
        count = json.loads(raw)["count"] if raw else 0
        ctx.maybe_fail()
        ctx.put(key, json.dumps({"count": count + 1, "epoch": epoch}).encode())
        return count + 1

    names = spec.fan_out("branch", branch_fn, BRANCHES)

    def summarize(ctx):
        total = sum(ctx.inputs[n] for n in names)
        ctx.put("summary", str(total).encode())
        return total

    spec.fan_in("summary", summarize, names)
    return spec


def read_all(cluster, keys):
    node = cluster.live_nodes()[0]
    tx = node.start_transaction()
    out = {k: node.get(tx, k) for k in keys}
    node.abort_transaction(tx)
    return out


# ---------------------------------------------------------------------------
# happy path
# ---------------------------------------------------------------------------

def test_parallel_branches_commit_atomically():
    cluster = make_cluster()
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(scope=TxnScope.WORKFLOW),
    )
    res = ex.run(fanout_spec())
    assert res.attempts == 1
    assert res.results["summary"] == BRANCHES
    assert res.committed_tid is not None
    values = read_all(cluster, [f"k{i}" for i in range(BRANCHES)] + ["summary"])
    assert all(v is not None for v in values.values())
    assert values["summary"] == str(BRANCHES).encode()


def test_branches_actually_run_in_parallel():
    """All fan-out branches must be in flight simultaneously."""
    cluster = make_cluster()
    barrier = threading.Barrier(BRANCHES, timeout=10)
    spec = WorkflowSpec("sync")

    def branch_fn(ctx):
        barrier.wait()  # deadlocks unless every branch runs concurrently
        return ctx.branch

    spec.fan_out("branch", branch_fn, BRANCHES)
    ex = WorkflowExecutor(fast_platform(), cluster=cluster)
    res = ex.run(spec)
    assert res.steps_run == BRANCHES


def test_conditional_edges_and_skip_propagation():
    cluster = make_cluster()
    spec = WorkflowSpec("cond")
    spec.step("a", lambda ctx: 1)
    spec.step("never", lambda ctx: 2, deps=["a"], when=lambda r: r["a"] > 100)
    spec.step("downstream", lambda ctx: 3, deps=["never"])  # skip propagates
    spec.step(
        "tolerant",
        lambda ctx: sorted(ctx.inputs),
        deps=["a", "never"],
        allow_skipped_deps=True,
    )
    ex = WorkflowExecutor(fast_platform(), cluster=cluster)
    res = ex.run(spec)
    assert set(res.skipped) == {"never", "downstream"}
    assert res.results["tolerant"] == ["a"]  # sees only non-skipped inputs


def test_inputs_flow_along_edges():
    cluster = make_cluster()
    spec = WorkflowSpec("flow")
    spec.step("a", lambda ctx: {"x": 2})
    spec.step("b", lambda ctx: ctx.inputs["a"]["x"] * 21, deps=["a"])
    ex = WorkflowExecutor(fast_platform(), cluster=cluster)
    assert ex.run(spec).results["b"] == 42


# ---------------------------------------------------------------------------
# failure injection + retry + memoized resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scope", [TxnScope.WORKFLOW, TxnScope.STEP])
def test_exactly_once_under_injected_crashes(scope):
    cluster = make_cluster(nodes=2 if scope is TxnScope.STEP else 1)
    platform = fast_platform(failure_rate=0.25, seed=5)
    ex = WorkflowExecutor(
        platform, cluster=cluster,
        config=WorkflowConfig(scope=scope, max_attempts=40),
    )
    rounds = 3
    for epoch in range(rounds):
        res = ex.run(fanout_spec(epoch))
        assert res.results["summary"] == BRANCHES * (epoch + 1)
        # WITHIN a workflow, resume recovery closes the multicast window;
        # ACROSS workflows visibility is eventual (§4) — deliver one
        # deterministic multicast round so the next epoch reads fresh counts
        cluster.step_all()
    assert platform.failures_injected > 0  # the hazard actually fired
    # exactly-once effects: each branch counter incremented once per round
    values = read_all(cluster, [f"k{i}" for i in range(BRANCHES)])
    counts = [json.loads(v)["count"] for v in values.values()]
    assert counts == [rounds] * BRANCHES


def test_memoized_steps_not_rerun_on_retry():
    cluster = make_cluster()
    ran = []
    spec = WorkflowSpec("once")

    def a(ctx):
        ran.append("a")
        ctx.put("ka", b"va")
        return "A"

    crashes = [True]

    def b(ctx):
        ran.append("b")
        if crashes:
            crashes.pop()
            raise FunctionFailure("deliberate crash after a completed")
        return ctx.inputs["a"] + "B"

    spec.step("a", a)
    spec.step("b", b, deps=["a"])
    ex = WorkflowExecutor(fast_platform(), cluster=cluster)
    res = ex.run(spec)
    assert res.attempts == 2
    assert res.results["b"] == "AB"
    assert ran == ["a", "b", "b"]  # a ran exactly once, b retried
    assert res.steps_memoized >= 1
    # a's write still committed despite being replayed from the memo
    assert read_all(cluster, ["ka"])["ka"] == b"va"


def test_workflow_scope_never_persists_fractured_updates():
    """Crash mid-DAG: either ALL the workflow's keys commit or none do."""
    cluster = make_cluster()
    platform = fast_platform(failure_rate=0.4, seed=9)
    ex = WorkflowExecutor(
        platform, cluster=cluster,
        config=WorkflowConfig(scope=TxnScope.WORKFLOW, max_attempts=2),
    )
    keys = [f"k{i}" for i in range(BRANCHES)] + ["summary"]
    for epoch in range(4):
        try:
            ex.run(fanout_spec(epoch))
        except WorkflowError:
            pass
        values = read_all(cluster, keys)
        present = [k for k, v in values.items() if v is not None]
        assert present == [] or sorted(present) == sorted(keys), (
            f"fractured commit: only {present} visible"
        )


def test_unscoped_baseline_exhibits_fractured_state():
    """The control: without the shim a mid-DAG crash leaves a partial
    prefix in place — the anomaly fig_workflow measures."""
    storage = MemoryStorage()
    spec = WorkflowSpec("torn")

    def w(ctx):
        ctx.put(f"t{ctx.branch}", b"x")
        if ctx.branch == 2:
            raise FunctionFailure("die after branches 0-2 wrote")
        return ctx.branch

    # serial chain so the crash point is deterministic
    prev = []
    for i in range(4):
        step = spec.step(f"s{i}", w, deps=prev)
        spec.steps[step].branch = i
        prev = [step]

    ex = WorkflowExecutor(
        fast_platform(), storage=storage,
        config=WorkflowConfig(scope=TxnScope.NONE, max_attempts=1),
    )
    with pytest.raises(WorkflowError):
        ex.run(spec)
    visible = [k for k in ("t0", "t1", "t2", "t3") if storage.get(k) is not None]
    assert visible == ["t0", "t1", "t2"]  # fractured prefix persisted
    value, _tid, cowritten = extract_metadata(storage.get("t0"))
    assert value == b"x"  # §6.1.2 metadata embedded for the auditors


def test_retry_commit_is_idempotent_per_workflow_uuid():
    cluster = make_cluster()
    ex = WorkflowExecutor(fast_platform(), cluster=cluster)
    spec = WorkflowSpec("idem")
    spec.step("a", lambda ctx: (ctx.put("ik", b"v"), "done")[1])
    r1 = ex.run(spec, uuid="fixed-wf-uuid")
    r2 = ex.run(spec, uuid="fixed-wf-uuid")  # re-driven whole workflow
    assert r1.committed_tid == r2.committed_tid
    commits = [
        k for k in cluster.storage.list_keys(COMMIT_PREFIX)
        if k.endswith(".fixed-wf-uuid")
    ]
    assert len(commits) == 1  # exactly one workflow commit record


def test_cross_process_redrive_resumes_from_memo():
    """An explicit UUID is the cross-process resume path: a second executor
    (a fresh 'process') re-driving the same workflow UUID must consult memos
    on its FIRST attempt — not re-run bodies and drift the results."""
    cluster = make_cluster()
    ran = []

    def build():
        spec = WorkflowSpec("redrive")

        def a(ctx):
            ran.append(1)
            raw = ctx.get("c")
            ctx.put("c", str(int(raw or 0) + 1).encode())
            return int(raw or 0) + 1

        spec.step("a", a)
        return spec

    r1 = WorkflowExecutor(fast_platform(), cluster=cluster).run(
        build(), uuid="redrive-uuid"
    )
    r2 = WorkflowExecutor(fast_platform(), cluster=cluster).run(
        build(), uuid="redrive-uuid"
    )
    assert len(ran) == 1  # the body ran exactly once across both drives
    assert r1.results == r2.results == {"a": 1}
    assert r2.steps_memoized == 1
    assert r1.committed_tid == r2.committed_tid


def test_exhausted_attempts_raise_workflow_error():
    cluster = make_cluster()
    spec = WorkflowSpec("doomed")

    def always_dies(ctx):
        raise FunctionFailure("unconditional")

    spec.step("a", always_dies)
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(max_attempts=3),
    )
    with pytest.raises(WorkflowError, match="after 3 attempts"):
        ex.run(spec)
    assert ex.stats["workflow_retries"] == 2


def test_non_serializable_result_is_a_clear_error():
    cluster = make_cluster()
    spec = WorkflowSpec("bad")
    spec.step("a", lambda ctx: object())
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster, config=WorkflowConfig(max_attempts=1)
    )
    with pytest.raises(WorkflowError) as ei:
        ex.run(spec)
    assert "JSON-serializable" in repr(ei.value.__cause__)


# ---------------------------------------------------------------------------
# platform retry accounting (satellite)
# ---------------------------------------------------------------------------

def test_run_request_reports_attempts_accurately():
    platform = LambdaPlatform(
        FaasConfig(time_scale=0.0, max_retries=2)
    )

    calls = []

    def fn(session):
        calls.append(1)
        raise FunctionFailure("always")

    class S:
        uuid = "u"

    with pytest.raises(RuntimeError, match=r"3 attempts \(2 retries\)"):
        platform.run_request(
            [fn], begin=lambda u: S(), finish=lambda s: None,
            on_failure=lambda s: None,
        )
    assert len(calls) == 3
    assert platform.retries == 2


def test_on_failure_errors_are_counted_not_swallowed():
    platform = LambdaPlatform(FaasConfig(time_scale=0.0, max_retries=1))

    def fn(session):
        raise FunctionFailure("boom")

    def bad_cleanup(session):
        raise ValueError("cleanup died too")

    class S:
        uuid = "u"

    with pytest.raises(RuntimeError):
        platform.run_request(
            [fn], begin=lambda u: S(), finish=lambda s: None,
            on_failure=bad_cleanup,
        )
    assert platform.on_failure_errors == 2
    assert isinstance(platform.last_on_failure_error, ValueError)


def test_failure_sites_scope_injection():
    platform = LambdaPlatform(
        FaasConfig(time_scale=0.0, failure_rate=1.0,
                   failure_sites=("step:shard",))
    )
    platform.maybe_fail()                      # anonymous: not a target
    platform.maybe_fail(site="step:other")     # different site: not a target
    with pytest.raises(FunctionFailure):
        platform.maybe_fail(site="step:shard[3]")  # prefix match: dies
    assert platform.failures_injected == 1
