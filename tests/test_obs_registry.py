"""Metrics registry (repro/obs/registry.py): sketches, scoping, live-dict
views, snapshot/merge/export, and the AftNode.stats() deprecation shim."""

import warnings

import pytest

import repro.core.node as node_mod
from repro.core import AftNode, AftNodeConfig, PlacementHint
from repro.core.routing import CacheAwareRouter
from repro.obs.registry import Counter, QuantileSketch, Registry
from repro.storage.memory import MemoryStorage


# ---------------------------------------------------------------------------
# sketch + histogram
# ---------------------------------------------------------------------------

def test_sketch_exact_count_sum_min_max_and_percentiles():
    s = QuantileSketch()
    for v in range(1, 1001):
        s.observe(float(v))
    out = s.summary()
    assert out["count"] == 1000
    assert out["sum_ms"] == pytest.approx(500500.0)
    assert out["min_ms"] == 1.0
    assert out["max_ms"] == 1000.0
    # compaction keeps a uniform stride, so percentiles stay tight
    assert out["p50_ms"] == pytest.approx(500, rel=0.05)
    assert out["p99_ms"] == pytest.approx(990, rel=0.05)


def test_sketch_compaction_bounds_memory():
    s = QuantileSketch()
    for v in range(100_000):
        s.observe(float(v))
    assert len(s.summary()["samples"]) <= 256
    assert s.summary()["count"] == 100_000


def test_histogram_observe_s_converts_to_ms():
    reg = Registry(name="t")
    h = reg.histogram("lat")
    h.observe_s(0.25)
    assert reg.snapshot()["lat"]["sum_ms"] == pytest.approx(250.0)


def test_timer_context_observes():
    reg = Registry(name="t")
    with reg.timer("op"):
        pass
    assert reg.snapshot()["op"]["count"] == 1


# ---------------------------------------------------------------------------
# registry API
# ---------------------------------------------------------------------------

def test_get_or_create_and_kind_mismatch():
    reg = Registry(name="t")
    c = reg.counter("n")
    assert reg.counter("n") is c
    with pytest.raises(TypeError):
        reg.gauge("n")


def test_scoped_nests_with_dotted_prefixes():
    reg = Registry(name="t")
    reg.scoped("a").scoped("b").counter("c").inc(3)
    assert reg.snapshot()["a.b.c"] == 3


def test_attach_counters_is_a_live_view():
    reg = Registry(name="t")
    stats = {"ops": 0}
    reg.attach_counters(stats)
    stats["ops"] = 7
    assert reg.snapshot()["ops"] == 7


def test_attach_provider_computes_at_snapshot_time():
    reg = Registry(name="t")
    state = {"v": 1}
    reg.attach_provider(lambda: {"derived": state["v"] * 2})
    state["v"] = 21
    assert reg.snapshot()["derived"] == 42


def test_merge_sums_counters_averages_rates_merges_hists():
    a, b = Registry(name="a"), Registry(name="b")
    a.counter("commits").inc(10)
    b.counter("commits").inc(5)
    a.gauge("hit_rate").set(1.0)
    b.gauge("hit_rate").set(0.0)
    a.histogram("lat").observe(10.0)
    b.histogram("lat").observe(30.0)
    merged = Registry.merge([a.snapshot(), b.snapshot()])
    assert merged["commits"] == 15
    assert merged["hit_rate"] == pytest.approx(0.5)
    assert merged["lat"]["count"] == 2
    assert merged["lat"]["min_ms"] == 10.0
    assert merged["lat"]["max_ms"] == 30.0


def test_to_prometheus_renders_counters_and_summaries():
    reg = Registry(name="t")
    reg.counter("commits").inc(2)
    reg.histogram("commit.total").observe(5.0)
    text = Registry.to_prometheus(reg.snapshot(), prefix="aft",
                                  labels={"node": "n0"})
    assert 'aft_commits{node="n0"} 2' in text
    assert "aft_commit_total" in text


# ---------------------------------------------------------------------------
# AftNode integration: registry absorbs the stats dict, shim stays compatible
# ---------------------------------------------------------------------------

def _commit_once(node: AftNode) -> None:
    tx = node.start_transaction()
    node.put(tx, "k", b"v")
    node.commit_transaction(tx)


def test_node_stats_shim_warns_once_and_keeps_legacy_keys():
    node = AftNode(MemoryStorage(), AftNodeConfig(node_id="n0"))
    _commit_once(node)
    node_mod._stats_deprecation_warned = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        snap = node.stats()
        node.stats()  # second call: the warning fires only once
    assert sum(issubclass(w.category, DeprecationWarning)
               for w in caught) == 1
    for key in ("commits", "open_sessions", "inflight_ops",
                "data_cache_hit_rate", "commit_p50_ms", "commit_p99_ms"):
        assert key in snap
    assert snap["commits"] == 1


def test_node_registry_snapshot_carries_commit_phase_histograms():
    node = AftNode(MemoryStorage(), AftNodeConfig(node_id="n0"))
    _commit_once(node)
    snap = node.registry.snapshot()
    assert snap["commit.total"]["count"] == 1
    assert snap["commit.version_flush"]["count"] == 1
    assert snap["commit.record_write"]["count"] == 1
    assert snap["commits"] == 1  # the legacy counters ride along


def test_cache_aware_router_scores_through_the_shim():
    node = AftNode(MemoryStorage(), AftNodeConfig(node_id="n0"))
    _commit_once(node)
    router = CacheAwareRouter()
    router.sync([node])
    hint = PlacementHint(uuid="u", keys=("k",))
    assert router.route([node], hint) is node


def test_fault_manager_collect_metrics_merges_without_gossip():
    """The direct (no-jax) aggregation path: the fault manager snapshots
    live members in-process and serves the same merged view the gossip
    MetricsPlane would feed it."""
    from repro.core import AftCluster, ClusterConfig

    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=2, start_background_threads=False),
    )
    try:
        for node in cluster.live_nodes():
            _commit_once(node)
        fm = cluster.fault_manager
        assert fm.collect_metrics() == 2
        merged = fm.cluster_metrics()
        assert len(merged["nodes"]) == 2
        assert merged["cluster"]["commits"] == 2
        assert merged["cluster"]["commit.total"]["count"] == 2
    finally:
        cluster.stop()


def test_counter_is_thread_safe_under_concurrent_inc():
    import threading

    c = Counter("c")
    def bump():
        for _ in range(10_000):
            c.inc()
    threads = [threading.Thread(target=bump) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 40_000
