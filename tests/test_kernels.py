"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import attention
from repro.kernels.ref import attention_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan


def _qkv(key, b, h, kvh, s, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kvh, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kvh, s, d), jnp.float32).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # b, h, kvh, s, d, bq, bk, causal, window, softcap
    (2, 4, 2, 128, 64, 64, 64, True, 0, 0.0),
    (1, 8, 4, 256, 64, 128, 64, True, 0, 50.0),     # softcap (gemma2)
    (2, 4, 4, 96, 32, 64, 64, False, 0, 0.0),       # pad path, non-causal
    (1, 4, 2, 256, 128, 64, 128, True, 64, 0.0),    # sliding window
    (1, 2, 1, 64, 16, 32, 32, True, 0, 0.0),        # tiny dims
    (2, 6, 2, 160, 64, 64, 64, True, 32, 30.0),     # window + softcap + pad
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, h, kvh, s, d, bq, bk, causal, window, cap = case
    q, k, v = _qkv(jax.random.key(hash(case) % 2**31), b, h, kvh, s, d, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_kv_valid():
    q, k, v = _qkv(jax.random.key(0), 1, 2, 2, 64, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True, kv_valid=40)
    ref = attention_ref(q, k, v, causal=True, kv_valid=40)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_attention_op_gradients():
    """custom-vjp wrapper: gradient must equal the reference gradient."""
    q, k, v = _qkv(jax.random.key(1), 1, 2, 1, 64, 32, jnp.float32)

    def f_kernel(q, k, v):
        return attention(q, k, v, True, 0, 0.0, True).sum()

    def f_ref(q, k, v):
        return attention_ref(q, k, v, causal=True).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


SSD_CASES = [
    # b, s, h, p, n, chunk
    (2, 64, 3, 8, 8, 16),
    (1, 128, 2, 16, 16, 32),
    (2, 96, 1, 8, 4, 96),      # single chunk
    (1, 64, 4, 32, 8, 8),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_ref(case):
    b, s, h, p, n, chunk = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    da = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    y, st = ssd_scan(x, da, bm, cm, chunk=chunk, interpret=True)
    yr, sr = ssd_scan_ref(x, da, bm, cm)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st, sr, rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_model_chunked():
    """Kernel == the model's XLA chunked reference (same semantics)."""
    from repro.models.ssm import ssd_chunked

    b, s, h, p, n = 2, 64, 2, 8, 8
    ks = jax.random.split(jax.random.key(5), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y_kernel, st_kernel = ssd_scan((x * dt[..., None]).astype(jnp.float32),
                                   dt * a[None, None, :], bm, cm,
                                   chunk=16, interpret=True)
    y_model, st_model = ssd_chunked(x, dt, a, bm, cm, 16)
    np.testing.assert_allclose(y_kernel, y_model, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_kernel, st_model, rtol=2e-4, atol=2e-4)
