"""Span tracing (repro/obs/trace.py): deterministic UUID-derived trace IDs,
the ring/file sinks, and structural propagation through the workflow pool
and the ChainConsumer child handoff — including kill-and-retry and
memo-resume, where span IDs must stay unique (satellite d)."""

import json

from repro.core import AftCluster, ClusterConfig
from repro.core.records import claim_txn_uuid, trigger_entry_id
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.obs import trace as obs_trace
from repro.obs.checker import check_events
from repro.storage.memory import MemoryStorage
from repro.workflow import (
    ChainConsumerConfig,
    PoolConfig,
    Trigger,
    WorkflowPool,
    WorkflowSpec,
)


def make_cluster(nodes: int = 1) -> AftCluster:
    return AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=nodes, start_background_threads=False),
    )


def fast_platform(**kw) -> LambdaPlatform:
    return LambdaPlatform(FaasConfig(time_scale=0.0, **kw))


def consumer_cfg(**kw) -> ChainConsumerConfig:
    kw.setdefault("reclaim_after_s", 0.0)
    return ChainConsumerConfig(**kw)


def parent_spec(child: WorkflowSpec) -> WorkflowSpec:
    spec = WorkflowSpec("parent")

    def produce(ctx):
        ctx.put("chain/parent-effect", b"done")
        return {"payload": 41}

    spec.step("produce", produce)
    spec.trigger(Trigger(child, args_from="produce"))
    return spec


def child_spec(ran) -> WorkflowSpec:
    spec = WorkflowSpec("child")

    def consume(ctx):
        ran.append(ctx.args)
        ctx.put("chain/child-effect", json.dumps(ctx.args).encode())
        return ctx.args

    spec.step("consume", consume)
    return spec


def counter_spec(i: int) -> WorkflowSpec:
    spec = WorkflowSpec(f"count{i}")

    def bump(ctx):
        raw = ctx.get(f"cnt/{i}")
        count = json.loads(raw)["count"] if raw else 0
        ctx.maybe_fail()
        ctx.put(f"cnt/{i}", json.dumps({"count": count + 1}).encode())
        return count + 1

    spec.step("bump", bump)
    return spec


# ---------------------------------------------------------------------------
# trace-ID grammar
# ---------------------------------------------------------------------------

def test_trace_id_is_deterministic_and_uuid_scoped():
    assert obs_trace.trace_id("wf-1") == obs_trace.trace_id("wf-1")
    assert obs_trace.trace_id("wf-1") != obs_trace.trace_id("wf-2")
    assert len(obs_trace.trace_id("wf-1")) == 16


def test_base_uuid_strips_derived_decorations():
    assert obs_trace.base_uuid("wf-1.step.branch0") == "wf-1"
    assert obs_trace.base_uuid("wf-1.memo.agg") == "wf-1"
    assert obs_trace.base_uuid("wf-1.chain.child.claim") == "wf-1.chain.child"
    assert obs_trace.base_uuid("wf-1.chain.child.enq") == "wf-1.chain.child"
    # a chain child is its own workflow — the .chain. infix is kept
    assert obs_trace.base_uuid("wf-1.chain.child") == "wf-1.chain.child"
    assert obs_trace.base_uuid("wf-1.chain.child.step.s0") == "wf-1.chain.child"


def test_txn_trace_id_maps_every_derived_txn_to_the_owning_trace():
    wf = "figw-7"
    for derived in (wf, f"{wf}.step.s0", f"{wf}.memo.s0"):
        assert obs_trace.txn_trace_id(derived) == obs_trace.trace_id(wf)
    # the claim transaction of a queue entry lands in the CHILD's trace
    entry = trigger_entry_id("figw-7", "next")
    assert obs_trace.txn_trace_id(claim_txn_uuid(entry)) \
        == obs_trace.trace_id(entry)


def test_span_ids_are_attempt_qualified():
    t = obs_trace.trace_id("wf-1")
    assert obs_trace.span_id(t, "step:a", 1) != obs_trace.span_id(t, "step:a", 2)
    assert obs_trace.span_id(t, "step:a", 1) == f"{t}/step:a#1"


# ---------------------------------------------------------------------------
# tracer sinks
# ---------------------------------------------------------------------------

def test_ring_buffer_caps_and_orders_events():
    t = obs_trace.Tracer(capacity=4)
    for i in range(10):
        t.emit("x", i=i)
    evs = t.events()
    assert len(evs) == 4
    assert [e["i"] for e in evs] == [6, 7, 8, 9]
    assert [e["seq"] for e in evs] == [7, 8, 9, 10]


def test_file_sink_round_trips_through_json_lines(tmp_path):
    path = tmp_path / "trace.jsonl"
    t = obs_trace.Tracer(path=str(path))
    t.emit("read", txn="u1", key="k")
    t.emit("span", name="wf", trace="t", span="t/wf#1")
    t.close()
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert [rec["ev"] for rec in lines] == ["read", "span"]
    assert lines[0]["key"] == "k"
    assert lines[1]["span"] == "t/wf#1"


def test_span_context_manager_records_duration_and_error_status():
    t = obs_trace.Tracer()
    with t.span("ok-op", "tr"):
        pass
    try:
        with t.span("bad-op", "tr"):
            raise ValueError("boom")
    except ValueError:
        pass
    ok, bad = t.events()
    assert ok["status"] == "ok" and ok["dur_ms"] >= 0
    assert bad["status"] == "error"


def test_disabled_tracer_emits_nothing():
    t = obs_trace.Tracer(enabled=False)
    t.emit("x")
    assert t.events() == []
    assert not obs_trace.get_tracer().enabled  # global default stays off


def test_set_tracer_returns_previous_for_restore():
    mine = obs_trace.Tracer()
    prev = obs_trace.set_tracer(mine)
    try:
        assert obs_trace.get_tracer() is mine
    finally:
        obs_trace.set_tracer(prev)
    assert obs_trace.get_tracer() is prev


# ---------------------------------------------------------------------------
# end-to-end propagation: pool submit → claim → chain child
# ---------------------------------------------------------------------------

def _events_by_ev(events):
    by = {}
    for e in events:
        by.setdefault(e["ev"], []).append(e)
    return by


def test_chain_child_claim_lands_in_child_trace_linked_to_parent():
    cluster = make_cluster()
    ran = []
    child = child_spec(ran)
    prev = obs_trace.set_tracer(obs_trace.Tracer(capacity=100_000))
    try:
        with WorkflowPool(fast_platform(), cluster=cluster) as pool:
            consumer = pool.attach_chain_consumer(
                {"child": child}, consumer_cfg(), start=False
            )
            pool.submit(parent_spec(child), uuid="tp-parent").result(timeout=30)
            assert consumer.drain(timeout_s=30)
        events = obs_trace.get_tracer().events()
    finally:
        obs_trace.set_tracer(prev)

    assert ran == [{"payload": 41}]
    entry = trigger_entry_id("tp-parent", "child")
    parent_trace = obs_trace.trace_id("tp-parent")
    child_trace = obs_trace.trace_id(entry)
    by = _events_by_ev(events)

    # the claim rides the child's trace with no plumbing: its txn UUID is
    # <entry>.claim, whose base_uuid is the entry (= the child's UUID)
    committed = [e for e in by["claim"] if e["outcome"] == "committed"]
    assert committed and committed[0]["trace"] == child_trace
    assert committed[0]["txn"] == claim_txn_uuid(entry)

    # the child's submit event links back to the parent's trace
    child_submits = [e for e in by["submit"] if e["uuid"] == entry]
    assert child_submits and child_submits[0]["trace"] == child_trace
    assert child_submits[0]["parent"] == parent_trace
    assert child_submits[0]["chain"]["entry"] == entry

    # the consumer's chain_child event carries both ends of the link
    link = by["chain_child"][0]
    assert link["trace"] == child_trace
    assert link["parent_trace"] == parent_trace

    # both workflows closed their root spans in their own traces
    wf_spans = {e["trace"] for e in by["span"] if e["name"] == "wf"}
    assert {parent_trace, child_trace} <= wf_spans
    cluster.stop()


def test_kill_mid_handoff_keeps_child_trace_and_unique_spans():
    """The replayed handoff recommits under the same entry UUID, so the
    child keeps ONE trace across the crash — while the retry's spans stay
    distinct (attempt-qualified IDs)."""
    cluster = make_cluster()
    ran = []
    child = child_spec(ran)
    platform = fast_platform(
        failure_rate=1.0, failure_sites=("chain:handoff",)
    )
    prev = obs_trace.set_tracer(obs_trace.Tracer(capacity=100_000))
    try:
        with WorkflowPool(platform, cluster=cluster) as pool:
            consumer = pool.attach_chain_consumer(
                {"child": child}, consumer_cfg(), start=False
            )
            pool.submit(parent_spec(child), uuid="kh-parent").result(timeout=30)
            assert consumer.step() == 0  # claimed, then died mid-handoff
            platform.config.failure_rate = 0.0
            assert consumer.drain(timeout_s=30)
        events = obs_trace.get_tracer().events()
    finally:
        obs_trace.set_tracer(prev)

    assert ran == [{"payload": 41}]
    entry = trigger_entry_id("kh-parent", "child")
    child_trace = obs_trace.trace_id(entry)
    by = _events_by_ev(events)
    # crash + replay: ≥ 2 claim events, all in the child's single trace
    claims = [e for e in by["claim"] if e["entry"] == entry]
    assert len(claims) >= 2
    assert {e["trace"] for e in claims} == {child_trace}

    checked = check_events(events)
    assert checked.ok, checked.violations
    span_ids = [e["span"] for e in by.get("span", [])]
    assert len(span_ids) == len(set(span_ids))
    cluster.stop()


def test_retry_and_memo_resume_emit_fresh_spans_and_one_tid(tmp_path):
    """Kill-and-retry inside the pool plus a cross-pool memo re-drive: the
    trace stays checker-clean, span IDs never collide, and every workflow
    UUID commits exactly one transaction ID."""
    cluster = make_cluster()
    prev = obs_trace.set_tracer(
        obs_trace.Tracer(path=str(tmp_path / "t.jsonl"), capacity=100_000)
    )
    try:
        platform = fast_platform(failure_rate=0.35, seed=7)
        cfg = PoolConfig(max_attempts=25, declare_finished=False)
        with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
            tickets = [
                pool.submit(counter_spec(i), uuid=f"obs-{i}") for i in range(8)
            ]
            results = [t.result(timeout=60) for t in tickets]
        # memo re-drive in a "new process": bodies replay from memos under
        # the SAME uuid — same trace, a fresh attempt's worth of spans
        with WorkflowPool(fast_platform(), cluster=cluster, config=cfg) as pool:
            redriven = pool.submit(counter_spec(0), uuid="obs-0").result(60)
        tracer = obs_trace.get_tracer()
        events = tracer.events()
        tracer.close()
    finally:
        obs_trace.set_tracer(prev)

    assert any(r.attempts > 1 for r in results)  # the kill actually fired
    assert redriven.steps_memoized == 1
    assert redriven.committed_tid == results[0].committed_tid

    checked = check_events(events)
    assert checked.ok, checked.violations

    by = _events_by_ev(events)
    span_ids = [e["span"] for e in by["span"]]
    assert len(span_ids) == len(set(span_ids))
    # exactly one committed tid per workflow uuid, re-drive included
    tids = {}
    for e in by["wf_finished"]:
        tids.setdefault(e["uuid"], set()).add(e["tid"])
    assert tids and all(len(ts) == 1 for ts in tids.values())
    # every cnt/ write was committed exactly once
    for i in range(8):
        raw = cluster.storage.get(f"d/cnt/{i}/")  # versioned: prefix scan
        keys = cluster.storage.list_keys(f"d/cnt/{i}/")
        assert len(keys) == 1, (i, raw, keys)

    # the file sink captured the same stream the ring did
    lines = (tmp_path / "t.jsonl").read_text().splitlines()
    assert len(lines) == len(events)
    cluster.stop()
