"""Fault-injectable multicast fabric (core/multicast.py).

Per-failure-mode unit tests for the seeded ``BusFaults`` knobs, the named
``multicast:send`` fault site, membership hygiene (replacement nodes must
not inherit a predecessor's backlog; killed nodes must not leave orphaned
inboxes), and the gossip-plane envelope: eager commit push, per-peer
horizon tracking along contiguous sequence prefixes, and gap repair."""

import pytest

from repro.core import (
    AftCluster,
    AftNodeConfig,
    BusFaults,
    ClusterConfig,
    MulticastBus,
    SnapshotUnavailable,
    TransactionRecord,
    TxnId,
)
from repro.faas.platform import FaasConfig, FunctionFailure, LambdaPlatform
from repro.storage import MemoryStorage


def make_cluster(n=2, **node_kw):
    cfg = ClusterConfig(
        num_nodes=n,
        node=AftNodeConfig(**node_kw),
        start_background_threads=False,
    )
    return AftCluster(MemoryStorage(), cfg)


def put_commit(node, items, uuid=None):
    tx = node.start_transaction(uuid)
    for k, v in items.items():
        node.put(tx, k, v)
    return node.commit_transaction(tx)


def rec(ts, uuid, *keys):
    return TransactionRecord(tid=TxnId(ts, uuid), write_set=tuple(keys))


# ----------------------------------------------------------- fault knobs
def test_drop_rate_loses_messages():
    bus = MulticastBus(BusFaults(drop_rate=1.0))
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u", "k")])
    assert bus.inbox_depth("b") == 0
    assert bus.messages_dropped == 1
    assert bus.drain_messages("b") == []


def test_delay_holds_messages_for_n_drains():
    bus = MulticastBus(BusFaults(delay_rate=1.0, delay_rounds=2))
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u", "k")])
    assert bus.messages_delayed == 1
    assert bus.inbox_depth("b") == 1  # held, but not lost
    assert bus.drain_messages("b") == []          # round 1: still held
    delivered = bus.drain_messages("b")           # round 2: released
    assert [m.records[0].tid.uuid for m in delivered] == ["u"]


def test_reorder_front_inserts():
    bus = MulticastBus(BusFaults(reorder_rate=1.0))
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u1", "k")], seq=1)
    bus.send("a", "b", [rec(2, "u2", "k")], seq=2)
    seqs = [m.seq for m in bus.drain_messages("b")]
    assert seqs == [2, 1]  # the later send jumped the queue
    assert bus.messages_reordered >= 1


def test_duplicate_delivers_twice():
    bus = MulticastBus(BusFaults(duplicate_rate=1.0))
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u", "k")])
    delivered = bus.drain_messages("b")
    assert len(delivered) == 2
    assert bus.messages_duplicated == 1


def test_drop_wins_over_other_knobs():
    bus = MulticastBus(BusFaults(drop_rate=1.0, delay_rate=1.0,
                                 duplicate_rate=1.0, reorder_rate=1.0))
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u", "k")])
    assert bus.inbox_depth("b") == 0
    assert bus.messages_delayed == 0


def test_set_faults_none_heals_the_bus():
    bus = MulticastBus(BusFaults(drop_rate=1.0))
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u1", "k")])
    bus.set_faults(None)
    bus.send("a", "b", [rec(2, "u2", "k")])
    assert [m.records[0].tid.uuid for m in bus.drain_messages("b")] == ["u2"]


def test_faults_are_seeded_deterministic():
    def schedule(seed):
        bus = MulticastBus(BusFaults(drop_rate=0.5, seed=seed))
        bus.register("a")
        bus.register("b")
        for i in range(40):
            bus.send("a", "b", [rec(i + 1, f"u{i}", "k")])
        return [m.records[0].tid.uuid for m in bus.drain_messages("b")]

    assert schedule(7) == schedule(7)
    assert schedule(7) != schedule(8)  # and the knob actually bites


# --------------------------------------------------- named fault site
def test_multicast_send_fault_site_raises_into_sender():
    platform = LambdaPlatform(FaasConfig(
        failure_rate=1.0, failure_sites=("multicast:send",)))
    bus = MulticastBus()
    bus.fault_hook = platform.maybe_fail
    bus.register("a")
    bus.register("b")
    with pytest.raises(FunctionFailure):
        bus.send("a", "b", [rec(1, "u", "k")])
    assert bus.inbox_depth("b") == 0
    assert platform.failures_injected == 1


def test_fault_site_scoping_spares_other_sites():
    platform = LambdaPlatform(FaasConfig(
        failure_rate=1.0, failure_sites=("step:shard",)))
    bus = MulticastBus()
    bus.fault_hook = platform.maybe_fail
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u", "k")])  # site mismatch: no injection
    assert bus.inbox_depth("b") == 1


def test_agent_counts_send_failures_and_fault_manager_heals():
    """An agent whose broadcast dies mid-send must not raise into the
    committing client; the §4.2 anti-entropy scan recovers the commit."""
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    platform = LambdaPlatform(FaasConfig(
        failure_rate=1.0, failure_sites=("multicast:send",)))
    cluster.bus.fault_hook = platform.maybe_fail
    put_commit(n0, {"k": b"v"})  # eager push dies at the fault site
    agent = cluster.agents[n0.node_id]
    assert agent.send_failures >= 1
    cluster.bus.fault_hook = None
    cluster.fault_manager.step()  # finds the unannounced commit in storage
    cluster.step_all()
    tx = n1.start_transaction()
    assert n1.get(tx, "k") == b"v"


# ------------------------------------------------------------ membership
def test_register_replaces_and_reports_discarded_backlog():
    bus = MulticastBus()
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u1", "k")])
    bus.send("a", "b", [rec(2, "u2", "k")])
    assert bus.register("b") == 2  # replacement starts with an empty inbox
    assert bus.inbox_depth("b") == 0


def test_register_discards_delayed_backlog_too():
    bus = MulticastBus(BusFaults(delay_rate=1.0, delay_rounds=3))
    bus.register("a")
    bus.register("b")
    bus.send("a", "b", [rec(1, "u", "k")])
    assert bus.register("b") == 1
    for _ in range(4):
        assert bus.drain_messages("b") == []  # the held message is gone


def test_unregister_removes_member():
    bus = MulticastBus()
    bus.register("a")
    bus.unregister("a")
    assert "a" not in bus.members()
    assert bus.inbox_depth("a") == 0


def test_send_to_unknown_member_is_a_noop():
    bus = MulticastBus()
    bus.register("a")
    bus.send("a", "ghost", [rec(1, "u", "k")])
    assert bus.inbox_depth("ghost") == 0


def test_kill_mid_stream_leaves_no_orphaned_inbox():
    """Regression: a killed node used to keep its bus inbox registered, so
    peers' eager pushes accumulated in a queue nobody would ever drain."""
    cluster = make_cluster(3)
    n0 = cluster.nodes[0]
    dead = cluster.kill_node(1)
    assert dead.node_id not in cluster.bus.members()
    # commits after the kill must not pile up for the corpse
    for i in range(5):
        put_commit(n0, {f"k{i}": b"v"})
    cluster.step_all()
    assert cluster.bus.inbox_depth(dead.node_id) == 0


def test_replacement_node_does_not_inherit_backlog():
    cluster = make_cluster(2)
    n0 = cluster.nodes[0]
    put_commit(n0, {"k": b"v"})
    cluster.kill_node(1)
    cluster.fault_manager.check_heartbeats()  # spawns the replacement
    live = cluster.live_nodes()
    assert len(live) == 2
    fresh = [n for n in live if n is not n0][0]
    # the replacement bootstrapped from durable storage, not the bus
    tx = fresh.start_transaction()
    assert fresh.get(tx, "k") == b"v"
    cluster.step_all()  # and normal gossip keeps flowing to it
    put_commit(n0, {"k2": b"v2"})
    cluster.step_all()
    tx2 = fresh.start_transaction()
    assert fresh.get(tx2, "k2") == b"v2"


# ------------------------------------------- gossip envelope & horizons
def test_eager_push_delivers_commit_metadata_at_commit_time():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    tid = put_commit(n0, {"k": b"v"})
    assert cluster.agents[n0.node_id].eager_pushes == 1
    # the record is already on the wire: draining n1's inbox alone (no n0
    # step) folds it into n1's commit-set cache
    cluster.agents[n1.node_id].step()
    assert n1.cache.get(tid) is not None
    tx = n1.start_transaction()
    assert n1.get(tx, "k") == b"v"


def test_peer_horizons_advance_and_cover_commits():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    tid = put_commit(n0, {"k": b"v"})
    cluster.step_all()
    a1 = cluster.agents[n1.node_id]
    assert a1.peer_horizons.get(n0.node_id, -1) >= tid.timestamp
    assert n1.read_watermark_ns() >= tid.timestamp


def test_unheard_peer_floors_the_watermark():
    cluster = make_cluster(2)
    n1 = cluster.nodes[1]
    # no round has run: the peer's horizon is unknown → floor at -1
    assert n1.read_watermark_ns() == -1
    with pytest.raises(SnapshotUnavailable):
        n1.snapshot_read("k", max_staleness_s=1.0)


def test_dropped_message_stalls_horizon_until_gap_repair():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    cluster.step_all()  # establish seq baselines both ways
    a1 = cluster.agents[n1.node_id]
    baseline = a1.peer_horizons[n0.node_id]

    # lose one commit announcement: the receiver sees a seq gap
    cluster.bus.set_faults(BusFaults(drop_rate=1.0))
    tid = put_commit(n0, {"k": b"v"})
    cluster.bus.set_faults(None)
    cluster.step_all()
    # the horizon may advance only below the lost commit, never past it
    assert a1.peer_horizons[n0.node_id] < tid.timestamp
    assert a1.peer_horizons[n0.node_id] >= baseline

    # after gap_repair_rounds stalled rounds the agent re-bootstraps and
    # jumps the gap, adopting the newest pending horizon
    for _ in range(a1.gap_repair_rounds + 1):
        cluster.step_all()
    assert a1.gap_repairs >= 1
    assert a1.peer_horizons[n0.node_id] >= tid.timestamp
    # and the re-scan observed the commit the drop had hidden
    tx = n1.start_transaction()
    assert n1.get(tx, "k") == b"v"


def test_duplicate_envelopes_do_not_regress_horizons():
    cluster = make_cluster(2)
    cluster.bus.set_faults(BusFaults(duplicate_rate=1.0))
    n0, n1 = cluster.nodes
    tid = put_commit(n0, {"k": b"v"})
    cluster.step_all()
    cluster.step_all()
    a1 = cluster.agents[n1.node_id]
    assert a1.peer_horizons[n0.node_id] >= tid.timestamp
    tx = n1.start_transaction()
    assert n1.get(tx, "k") == b"v"
