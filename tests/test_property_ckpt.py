"""Property test: checkpoint atomicity under arbitrary crash points.

For any sequence of saves where each may crash after an arbitrary number of
leaf-chunk puts, a reader must always observe exactly the latest *committed*
checkpoint — never a mixture, never a partial one.  This is the framework
instance of the paper's atomic-visibility guarantee.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import AftCheckpointer, CheckpointNotFound
from repro.core import AftCluster
from repro.storage.memory import MemoryStorage


class Crash(Exception):
    pass


@given(st.lists(st.one_of(st.none(), st.integers(0, 12)),
                min_size=1, max_size=8))
@settings(max_examples=25, deadline=None)
def test_reader_never_sees_torn_checkpoint(crash_points):
    """crash_points[i]: None = save i commits; k = save i crashes after k
    puts.  After each event the restored state must equal the last
    committed tree exactly."""
    cluster = AftCluster(MemoryStorage())
    try:
        ck = AftCheckpointer(cluster.client(), run_id="prop", chunk_bytes=48)
        committed_step = None
        committed_val = None
        for step, crash_after in enumerate(crash_points):
            # every leaf value depends on step → a mixture is detectable
            tree = {"a": jnp.full((9,), step, jnp.float32),
                    "b": {"w": jnp.full((4, 4), step * 10, jnp.float32),
                          "step": jnp.int32(step)}}
            if crash_after is None:
                ck.save(step, tree)
                committed_step, committed_val = step, tree
            else:
                calls = {"n": 0}

                def failpoint(path, ci):
                    calls["n"] += 1
                    if calls["n"] > crash_after:
                        raise Crash()

                try:
                    ck.save(step, tree, failpoint=failpoint)
                    committed_step, committed_val = step, tree
                except Crash:
                    pass
            # invariant: reader sees exactly the last committed state
            if committed_step is None:
                with pytest.raises(CheckpointNotFound):
                    ck.restore()
            else:
                got_step, got, _ = ck.restore(like=committed_val)
                assert got_step == committed_step
                for leaf, want in zip(
                        [got["a"], got["b"]["w"], got["b"]["step"]],
                        [committed_val["a"], committed_val["b"]["w"],
                         committed_val["b"]["step"]]):
                    np.testing.assert_array_equal(leaf, np.asarray(want))
    finally:
        cluster.stop()
