"""Memo-record GC: finished workflows' ``.wf/`` + derived ``u/`` keys are
reclaimed by the §5 sweep, unfinished ones survive so resume still works."""

import json

from repro.core import AftCluster, ClusterConfig
from repro.core.gc import LocalGcAgent
from repro.core.node import AftNode, AftNodeConfig
from repro.core.records import (
    COMMIT_PREFIX,
    DATA_PREFIX,
    UUID_PREFIX,
    WF_FINISH_PREFIX,
)
from repro.faas.platform import FaasConfig, FunctionFailure, LambdaPlatform
from repro.storage.memory import MemoryStorage
from repro.workflow import (
    MEMO_PREFIX,
    PoolConfig,
    TxnScope,
    WorkflowConfig,
    WorkflowExecutor,
    WorkflowPool,
    WorkflowSpec,
)


def make_cluster(nodes: int = 1) -> AftCluster:
    return AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=nodes, start_background_threads=False),
    )


def fast_platform(**kw) -> LambdaPlatform:
    return LambdaPlatform(FaasConfig(time_scale=0.0, **kw))


def crashy_chain(crashes: int = 1) -> WorkflowSpec:
    """a → b where b dies ``crashes`` times before succeeding: attempt 1
    memoizes a and crashes mid-workflow, the retry resumes a from its memo."""
    spec = WorkflowSpec("crashy")
    remaining = [crashes]

    def a(ctx):
        ctx.put("data/a", b"va")
        return "a-done"

    def b(ctx):
        if remaining[0] > 0:
            remaining[0] -= 1
            raise FunctionFailure("injected mid-workflow crash")
        ctx.put("data/b", b"vb")
        return "b-done"

    spec.step("a", a)
    spec.step("b", b, deps=["a"])
    return spec


def memo_keys(storage, uuid):
    return {
        "wf_data": storage.list_keys(f"{DATA_PREFIX}{MEMO_PREFIX}{uuid}/"),
        "derived_u": storage.list_keys(f"{UUID_PREFIX}{uuid}."),
        "marker": storage.list_keys(f"{WF_FINISH_PREFIX}{uuid}"),
    }


def test_crash_resume_finish_then_gc_reclaims_memo_state():
    """The satellite scenario end to end: crash mid-workflow, resume from
    memo, finish, run LocalGcAgent.step() — every ``.wf/`` and derived
    ``u/`` key is gone, while the workflow's own commit survives."""
    cluster = make_cluster()
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(max_attempts=5, declare_finished=True),
    )
    r = ex.run(crashy_chain(crashes=1), uuid="gc-wf")
    assert r.attempts == 2 and r.steps_memoized == 1  # crash → memo resume

    storage = cluster.storage
    before = memo_keys(storage, "gc-wf")
    assert len(before["wf_data"]) == 2      # memo versions for a and b
    assert len(before["derived_u"]) == 2    # u/gc-wf.memo.{a,b}
    assert len(before["marker"]) == 1       # declared finished

    agent = LocalGcAgent(cluster.live_nodes()[0])
    agent.step()

    after = memo_keys(storage, "gc-wf")
    assert after["wf_data"] == []
    assert after["derived_u"] == []
    # the marker outlives the sweep (peers' cache purges need it) until the
    # fault manager retires it after the TTL
    assert len(after["marker"]) == 1
    cluster.fault_manager.config.workflow_marker_ttl_s = 0.0
    cluster.fault_manager.sweep_finished_markers()
    cluster.fault_manager.deleter.drain()
    assert memo_keys(storage, "gc-wf")["marker"] == []
    assert agent.workflows_reclaimed == 1
    # pure-memo commit records are gone; the workflow's own commit survives
    commits = storage.list_keys(COMMIT_PREFIX)
    assert len([k for k in commits if ".memo." in k]) == 0
    assert len([k for k in commits if k.endswith(".gc-wf")]) == 1
    # the workflow's own u/ entry (final-commit idempotence) survives
    assert storage.get(f"{UUID_PREFIX}gc-wf") is not None
    # and its data is still readable from a fresh bootstrapped node
    fresh = AftNode(storage, AftNodeConfig(node_id="fresh"))
    tx = fresh.start_transaction()
    assert fresh.get(tx, "data/a") == b"va"
    assert fresh.get(tx, "data/b") == b"vb"
    fresh.abort_transaction(tx)
    cluster.stop()


def test_unfinished_workflow_memos_survive_gc_and_still_resume():
    """No finish marker ⇒ the sweep must not touch the workflow: a crashed
    workflow's memos survive GC and a later re-drive resumes from them."""
    cluster = make_cluster()
    spec = crashy_chain(crashes=10)  # more crashes than attempts → fails
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(max_attempts=2, declare_finished=True),
    )
    try:
        ex.run(spec, uuid="unfinished-wf")
    except Exception:
        pass
    storage = cluster.storage
    assert len(memo_keys(storage, "unfinished-wf")["wf_data"]) == 1  # a's memo
    assert memo_keys(storage, "unfinished-wf")["marker"] == []

    agent = LocalGcAgent(cluster.live_nodes()[0])
    agent.step()
    # unfinished: everything still there
    assert len(memo_keys(storage, "unfinished-wf")["wf_data"]) == 1
    assert len(memo_keys(storage, "unfinished-wf")["derived_u"]) == 1

    # the re-drive resumes from the surviving memo instead of re-running a
    spec2 = crashy_chain(crashes=0)
    r = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(max_attempts=5),
    ).run(spec2, uuid="unfinished-wf")
    assert r.steps_memoized == 1
    assert r.results == {"a": "a-done", "b": "b-done"}
    cluster.stop()


def test_finished_and_unfinished_coexist():
    """One sweep over a mixed population deletes exactly the finished half."""
    cluster = make_cluster()
    storage = cluster.storage
    cfg_fin = WorkflowConfig(declare_finished=True)
    cfg_not = WorkflowConfig(declare_finished=False)
    for i in range(4):
        cfg = cfg_fin if i % 2 == 0 else cfg_not
        ex = WorkflowExecutor(fast_platform(), cluster=cluster, config=cfg)
        ex.run(crashy_chain(crashes=0), uuid=f"mix-{i}")
    LocalGcAgent(cluster.live_nodes()[0]).step()
    for i in range(4):
        keys = memo_keys(storage, f"mix-{i}")
        if i % 2 == 0:
            assert keys["wf_data"] == [] and keys["derived_u"] == []
        else:
            assert len(keys["wf_data"]) == 2 and len(keys["derived_u"]) == 2
    cluster.stop()


def test_step_scope_gc_keeps_real_data_commit_records():
    """TxnScope.STEP memos ride inside the step's own transaction (mixed
    write set): the sweep deletes memo bytes + u/ entries but must keep the
    commit records that carry the real keys' cowritten metadata."""
    cluster = make_cluster()
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(
            scope=TxnScope.STEP, max_attempts=5, declare_finished=True
        ),
    )
    ex.run(crashy_chain(crashes=1), uuid="step-wf")
    storage = cluster.storage
    LocalGcAgent(cluster.live_nodes()[0]).step()
    keys = memo_keys(storage, "step-wf")
    assert keys["wf_data"] == [] and keys["derived_u"] == []
    # step transactions wrote real data → their commit records survive
    step_commits = [
        k for k in storage.list_keys(COMMIT_PREFIX) if ".step." in k
    ]
    assert len(step_commits) == 2
    fresh = AftNode(storage, AftNodeConfig(node_id="fresh-step"))
    tx = fresh.start_transaction()
    assert fresh.get(tx, "data/a") == b"va"
    assert fresh.get(tx, "data/b") == b"vb"
    fresh.abort_transaction(tx)
    cluster.stop()


def test_pool_plus_gc_bounds_storage_footprint():
    """A pool stream with a GC agent interleaved keeps total key count
    bounded; the same stream without GC grows monotonically."""
    def run_stream(gc: bool) -> list:
        cluster = make_cluster()
        platform = fast_platform()
        agent = LocalGcAgent(cluster.live_nodes()[0], workflow_gc_batch=1000)
        sizes = []
        with WorkflowPool(platform, cluster=cluster) as pool:
            for wave in range(4):
                specs = []
                for i in range(25):
                    spec = WorkflowSpec(f"w{wave}-{i}")
                    spec.step(
                        "only",
                        lambda ctx, k=f"key/{i}": ctx.put(k, b"x") or k,
                    )
                    specs.append(spec)
                pool.run_all(specs, timeout=60)
                if gc:
                    cluster.fault_manager.config.workflow_marker_ttl_s = 0.0
                    agent.step()
                    cluster.fault_manager.step()  # supersedence GC + markers
                    cluster.fault_manager.deleter.drain()
                sizes.append(len(cluster.storage.list_keys()))
        cluster.stop()
        return sizes

    with_gc = run_stream(gc=True)
    without = run_stream(gc=False)
    assert without[-1] > without[0]            # leak without GC
    assert with_gc[-1] < without[-1] / 2       # GC reclaims the bulk
    # plateau: the GC'd footprint stops growing after the first wave
    assert with_gc[-1] <= with_gc[1] + 5


def test_gc_spares_workflows_whose_uuid_extends_a_finished_one():
    """Regression: user-supplied UUIDs can be textual extensions of each
    other (serve/refresh.py builds ``publish.<run_id>.<step>``).  Finishing
    ``job.1`` must not destroy the memos or idempotence index of the
    still-running ``job.1.5`` — its exactly-once resume depends on them."""
    cluster = make_cluster()
    storage = cluster.storage
    ex_fin = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(declare_finished=True),
    )
    ex_fin.run(crashy_chain(crashes=0), uuid="job.1")
    # a *different* workflow that crashes mid-flight and stays unfinished
    ex_live = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(max_attempts=2, declare_finished=True),
    )
    try:
        ex_live.run(crashy_chain(crashes=10), uuid="job.1.5")
    except Exception:
        pass
    assert len(memo_keys(storage, "job.1.5")["wf_data"]) == 1  # a's memo

    LocalGcAgent(cluster.live_nodes()[0]).step()

    # finished workflow reclaimed ... (its u/ prefix also matches job.1.5's
    # keys, so probe its own derived entries exactly)
    assert memo_keys(storage, "job.1")["wf_data"] == []
    assert storage.get(f"{UUID_PREFIX}job.1.memo.a") is None
    assert storage.get(f"{UUID_PREFIX}job.1.memo.b") is None
    # ... the unfinished extension untouched
    assert len(memo_keys(storage, "job.1.5")["wf_data"]) == 1
    assert len(memo_keys(storage, "job.1.5")["derived_u"]) == 1
    assert storage.get(f"{UUID_PREFIX}job.1.5") is None  # never committed

    # and it still resumes from its surviving memo
    r = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(max_attempts=5),
    ).run(crashy_chain(crashes=0), uuid="job.1.5")
    assert r.steps_memoized == 1
    cluster.stop()


def test_multi_node_caches_purge_memo_records():
    """Regression: every node's cache must shed a finished workflow's
    pure-memo records, not just the node whose agent swept storage first —
    the marker outlives the sweep so slower peers still see it."""
    cluster = make_cluster(nodes=2)
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(declare_finished=True),
    )
    ex.run(crashy_chain(crashes=0), uuid="mn-wf")
    # propagate the memo commits to both nodes' caches
    cluster.step_all()

    def memo_cached(node):
        return [
            tid for tid in node.cache.all_tids()
            if (node.cache.get(tid) is not None
                and all(k.startswith(MEMO_PREFIX)
                        for k in node.cache.get(tid).write_set))
        ]

    # both agents sweep, in either order; the second one finds storage
    # already clean but must still purge its own cache
    for node in cluster.live_nodes():
        LocalGcAgent(node).step()
    for node in cluster.live_nodes():
        assert memo_cached(node) == []
        assert node.committed_tid_for_uuid("mn-wf.memo.a") is None
    cluster.stop()


def test_marker_retirement_waits_for_every_nodes_gc_agent():
    """Regression: TTL-only retirement raced slow GC agents.  With two
    nodes, the marker must survive until BOTH agents have consumed it —
    deleting earlier would orphan the slow node's view (and, before the
    fix, the memo records themselves if no agent ever swept)."""
    cluster = make_cluster(nodes=2)
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(declare_finished=True),
    )
    ex.run(crashy_chain(crashes=0), uuid="ack-wf")
    # propagate the memo commits to both nodes' caches (two multicast
    # passes: send, then deliver)
    for _ in range(2):
        for agent in cluster.agents.values():
            agent.step()
    fm = cluster.fault_manager
    fm.config.workflow_marker_ttl_s = 0.0

    # age gate passed, but NO agent has swept yet: the marker must survive
    assert fm.sweep_finished_markers() == 0
    assert len(cluster.storage.list_keys(f"{WF_FINISH_PREFIX}ack-wf")) == 1

    nodes = cluster.live_nodes()
    cluster.gc_agents[nodes[0].node_id].step()
    # one of two nodes acked: still not retirable
    assert fm.sweep_finished_markers() == 0
    assert len(cluster.storage.list_keys(f"{WF_FINISH_PREFIX}ack-wf")) == 1

    cluster.gc_agents[nodes[1].node_id].step()
    assert fm.sweep_finished_markers() == 1
    fm.deleter.drain()
    assert cluster.storage.list_keys(f"{WF_FINISH_PREFIX}ack-wf") == []
    # both nodes purged their caches before the marker went away
    for node in nodes:
        assert node.committed_tid_for_uuid("ack-wf.memo.a") is None
    cluster.stop()


def test_marker_hard_ttl_backstop_retires_without_acks():
    """A node whose agent never runs must not pin markers forever: past
    workflow_marker_max_ttl_s the marker retires unacked (bounded-staleness
    escape hatch, the pre-fix behavior as a backstop)."""
    cluster = make_cluster(nodes=2)
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(declare_finished=True),
    )
    ex.run(crashy_chain(crashes=0), uuid="cap-wf")
    fm = cluster.fault_manager
    fm.config.workflow_marker_ttl_s = 0.0
    fm.config.workflow_marker_max_ttl_s = 0.0
    assert fm.sweep_finished_markers() == 1  # no acks, but past the hard cap
    cluster.stop()


def test_unparsable_marker_quarantined_not_deleted():
    """Regression: an unparsable marker was treated as ancient and deleted
    immediately — before any agent could consume it, orphaning the
    workflow's memo records forever.  Now it is re-stamped (quarantined)
    and follows the ordinary ack-gated path, so the memos still get
    reclaimed."""
    cluster = make_cluster()
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(declare_finished=True),
    )
    ex.run(crashy_chain(crashes=0), uuid="quar-wf")
    storage = cluster.storage
    marker = f"{WF_FINISH_PREFIX}quar-wf"
    storage.put(marker, b"\x00 not json")  # bit-rotted payload
    fm = cluster.fault_manager
    fm.config.workflow_marker_ttl_s = 0.0

    assert fm.sweep_finished_markers() == 0
    fm.deleter.drain()
    # still present, now with a parsable quarantine payload
    raw = storage.get(marker)
    assert raw is not None
    assert json.loads(raw)["quarantined"] is True
    assert fm.stats["finish_markers_quarantined"] == 1

    # the GC license survived: the agent reclaims the memos, acks, and only
    # then does the marker retire
    agent = LocalGcAgent(cluster.live_nodes()[0])
    agent.step()
    assert memo_keys(storage, "quar-wf")["wf_data"] == []
    assert fm.sweep_finished_markers() == 1
    fm.deleter.drain()
    assert storage.get(marker) is None
    cluster.stop()


def test_fault_manager_prunes_deleted_memo_records():
    """After the node-side sweep deletes memo commit records from storage,
    the fault manager's aggregate (unpruned) view drops them too — otherwise
    its memory grows forever even though storage is bounded."""
    cluster = make_cluster()
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(declare_finished=True),
    )
    ex.run(crashy_chain(crashes=0), uuid="fm-wf")
    # multicast the commits to the fault manager (without running GC agents)
    for agent in cluster.agents.values():
        agent.step()
    fm = cluster.fault_manager
    fm.ingest()
    memo_records = [
        r for r in fm.cache.snapshot_records()
        if all(k.startswith(MEMO_PREFIX) for k in r.write_set)
    ]
    assert len(memo_records) == 2
    LocalGcAgent(cluster.live_nodes()[0]).step()  # deletes them from storage
    fm.config.prune_grace_s = 0.0
    fm.scan_commit_set()
    memo_records = [
        r for r in fm.cache.snapshot_records()
        if all(k.startswith(MEMO_PREFIX) for k in r.write_set)
    ]
    assert memo_records == []
    cluster.stop()


# ---------------------------------------------------------------------------
# chaining × GC (workflow/chain.py: the q/ trigger queue rides the sweep)
# ---------------------------------------------------------------------------

def _chain_pair(ran):
    """parent --on_commit--> child; child records its runs in ``ran``."""
    from repro.workflow import Trigger

    child = WorkflowSpec("child")

    def consume(ctx):
        ran.append(ctx.args)
        ctx.put("cg/child-effect", b"ok")
        return ctx.args

    child.step("consume", consume)
    parent = WorkflowSpec("parent")
    parent.step("produce", lambda ctx: ctx.put("cg/parent-effect", b"p") or 7)
    parent.trigger(Trigger(child, args_from="produce"))
    return parent, child


def test_consumed_chain_entry_reclaimed_with_child_marker():
    """A finished child's trigger entry, claim versions, and claim/enqueue
    bookkeeping transactions are reclaimed by the marker sweep — the queue
    footprint plateaus like the memo footprint does."""
    from repro.core.records import TRIGGER_PREFIX, claim_txn_uuid
    from repro.workflow import ChainConsumerConfig, list_queue_entries

    cluster = make_cluster()
    storage = cluster.storage
    ran = []
    parent, child = _chain_pair(ran)
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"child": child},
            ChainConsumerConfig(reclaim_after_s=0.0), start=False,
        )
        pool.submit(parent, uuid="cgc-parent").result(timeout=30)
        assert consumer.drain(timeout_s=30)
    assert ran == [7]
    entry_id = "cgc-parent.chain.child"
    assert list_queue_entries(storage, "default") == [entry_id]
    assert storage.get(f"{UUID_PREFIX}{claim_txn_uuid(entry_id)}") is not None

    node = cluster.live_nodes()[0]
    LocalGcAgent(node).step()

    # entry + claim versions gone, claim bookkeeping gone
    assert list_queue_entries(storage, "default") == []
    assert storage.list_keys(f"{DATA_PREFIX}{TRIGGER_PREFIX}") == []
    assert storage.get(f"{UUID_PREFIX}{claim_txn_uuid(entry_id)}") is None
    assert [
        k for k in storage.list_keys(COMMIT_PREFIX) if ".claim" in k
    ] == []
    # node cache purged of the claim transaction
    assert node.committed_tid_for_uuid(claim_txn_uuid(entry_id)) is None
    # both workflows' memo state reclaimed; their own commits survive
    assert memo_keys(storage, "cgc-parent")["wf_data"] == []
    assert memo_keys(storage, entry_id)["wf_data"] == []
    # the child's durable effects are untouched
    fresh = AftNode(storage, AftNodeConfig(node_id="fresh-chain"))
    tx = fresh.start_transaction()
    assert fresh.get(tx, "cg/child-effect") == b"ok"
    fresh.abort_transaction(tx)
    cluster.stop()


def test_chain_trigger_replay_after_memo_sweep_runs_child_once():
    """The ISSUE-4 satellite scenario end to end: parent commits, its memo
    records are swept, then the CLAIMED trigger replays after a pool
    restart — the child must run exactly once."""
    from repro.workflow import ChainConsumerConfig

    cluster = make_cluster()
    storage = cluster.storage
    ran = []
    parent, child = _chain_pair(ran)
    platform = LambdaPlatform(FaasConfig(
        time_scale=0.0, failure_rate=1.0, failure_sites=("chain:handoff",)
    ))
    with WorkflowPool(platform, cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"child": child},
            ChainConsumerConfig(reclaim_after_s=0.0), start=False,
        )
        pool.submit(parent, uuid="replay-parent").result(timeout=30)
        consumer.step()  # claims the entry, dies mid-handoff
        assert consumer.stats["handoff_crashes"] == 1
    assert ran == []

    # parent finished → its memo records are swept; the claimed-but-undriven
    # entry must SURVIVE the sweep (it is licensed by the child's marker,
    # which does not exist yet)
    LocalGcAgent(cluster.live_nodes()[0]).step()
    assert memo_keys(storage, "replay-parent")["wf_data"] == []
    entries = storage.list_keys("d/q/default/replay-parent.chain.child/")
    assert len(entries) >= 1

    # pool restart: a fresh consumer takes over the stale claim
    with WorkflowPool(fast_platform(), cluster=cluster) as pool2:
        consumer2 = pool2.attach_chain_consumer(
            {"child": child},
            ChainConsumerConfig(reclaim_after_s=0.0), start=False,
        )
        assert consumer2.drain(timeout_s=30)
        assert consumer2.stats["children_completed"] == 1

    # a further replay skips: the finish marker is the never-again fence
    with WorkflowPool(fast_platform(), cluster=cluster) as pool3:
        consumer3 = pool3.attach_chain_consumer(
            {"child": child},
            ChainConsumerConfig(reclaim_after_s=0.0), start=False,
        )
        assert consumer3.drain(timeout_s=30)
        assert consumer3.stats["children_started"] == 0
    assert ran == [7]

    # and the sweep now reclaims the consumed entry too
    LocalGcAgent(cluster.live_nodes()[0]).step()
    assert storage.list_keys("d/q/") == []
    cluster.stop()
