"""Placement-aware routing (core/routing.py): policy determinism, ring
rebalance on membership changes, cache/load-aware scoring, the dead-node
race guard in AftCluster.pick_node, and hint plumbing through AftClient."""

import threading

import pytest

from repro.core import (
    AftCluster,
    CacheAwareConfig,
    CacheAwareRouter,
    ClusterConfig,
    ConsistentHashRouter,
    NodeFailed,
    PlacementHint,
    RoundRobinRouter,
    Router,
    make_router,
)
from repro.storage.memory import MemoryStorage


def make_cluster(nodes: int = 4, routing=None, **cfg_kw) -> AftCluster:
    return AftCluster(
        MemoryStorage(),
        ClusterConfig(
            num_nodes=nodes,
            start_background_threads=False,
            routing=routing,
            **cfg_kw,
        ),
    )


# ---------------------------------------------------------------------------
# policy basics
# ---------------------------------------------------------------------------

def test_make_router_resolves_names_and_instances():
    assert isinstance(make_router(None), RoundRobinRouter)
    assert isinstance(make_router("consistent_hash"), ConsistentHashRouter)
    assert isinstance(make_router("cache_aware"), CacheAwareRouter)
    r = RoundRobinRouter()
    assert make_router(r) is r
    with pytest.raises(ValueError):
        make_router("nope")


def test_round_robin_cycles_and_ignores_hints():
    cluster = make_cluster(3)
    hint = PlacementHint(uuid="sticky", keys=("k",))
    picked = [cluster.pick_node(hint).node_id for _ in range(6)]
    assert picked == ["aft-0", "aft-1", "aft-2"] * 2
    cluster.stop()


def test_consistent_hash_is_deterministic_across_router_instances():
    """Same hint → same node, including from a *fresh* router (a different
    client/process must agree on placement without shared state)."""
    cluster = make_cluster(4, routing="consistent_hash")
    nodes = cluster.live_nodes()
    other = ConsistentHashRouter()
    other.sync(nodes)
    for i in range(50):
        hint = PlacementHint(uuid=f"wf-{i}")
        a = cluster.pick_node(hint)
        b = other.route(nodes, hint)
        assert a.node_id == b.node_id
    cluster.stop()


def test_consistent_hash_spreads_distinct_keys():
    cluster = make_cluster(4, routing="consistent_hash")
    owners = {
        cluster.pick_node(PlacementHint(keys=(f"k/{i}",))).node_id
        for i in range(200)
    }
    assert len(owners) == 4  # every node owns some arc
    cluster.stop()


def test_consistent_hash_minimal_movement_on_scale():
    """Adding one node to four moves ≈1/5 of the keyspace; far less than a
    modulo rehash (which moves ~4/5)."""
    cluster = make_cluster(4, routing="consistent_hash")
    keys = [f"k/{i}" for i in range(400)]
    before = {
        k: cluster.pick_node(PlacementHint(keys=(k,))).node_id for k in keys
    }
    cluster.scale_to(5)
    after = {
        k: cluster.pick_node(PlacementHint(keys=(k,))).node_id for k in keys
    }
    moved = sum(1 for k in keys if before[k] != after[k])
    assert moved / len(keys) < 0.45  # ~0.2 expected; generous bound
    # and everything that moved went to the NEW node
    new_id = after[next(k for k in keys if before[k] != after[k])]
    assert all(after[k] == new_id for k in keys if before[k] != after[k])
    cluster.stop()


def test_consistent_hash_reroutes_only_dead_nodes_keys():
    cluster = make_cluster(4, routing="consistent_hash")
    keys = [f"k/{i}" for i in range(400)]
    before = {
        k: cluster.pick_node(PlacementHint(keys=(k,))).node_id for k in keys
    }
    dead = cluster.kill_node(1)
    after = {
        k: cluster.pick_node(PlacementHint(keys=(k,))).node_id for k in keys
    }
    for k in keys:
        if before[k] == dead.node_id:
            assert after[k] != dead.node_id  # rerouted
        else:
            assert after[k] == before[k]  # unaffected arcs stay put
    cluster.stop()


def test_hint_ring_key_prefers_primary_key_over_uuid():
    assert PlacementHint(uuid="u", keys=("a", "b")).ring_key == "a"
    assert PlacementHint(uuid="u").ring_key == "u"
    assert PlacementHint().ring_key is None


# ---------------------------------------------------------------------------
# cache-aware scoring
# ---------------------------------------------------------------------------

def _commit_and_warm(node, key: str, value: bytes = b"v") -> None:
    """Commit key on node, then read it back so its data cache holds it."""
    tx = node.start_transaction()
    node.put(tx, key, value)
    node.commit_transaction(tx)
    node.release_transaction(tx)
    tx = node.start_transaction()
    assert node.get(tx, key) == value
    node.abort_transaction(tx)
    node.release_transaction(tx)


def test_cache_aware_prefers_node_with_reads_cached():
    cluster = make_cluster(3, routing="cache_aware")
    warm = cluster.live_nodes()[2]
    _commit_and_warm(warm, "hot/a")
    _commit_and_warm(warm, "hot/b")
    # metadata propagates so any node COULD serve the read; only `warm`
    # has the bytes cached
    cluster.step_all()
    hint = PlacementHint(uuid="wf", keys=("hot/a", "hot/b"))
    for _ in range(5):
        assert cluster.pick_node(hint).node_id == warm.node_id
    cluster.stop()


def test_cache_aware_spills_off_overloaded_node():
    """Equal cache affinity everywhere (cold key) → the load term decides:
    a node buried in open sessions loses to an idle one, even when it is
    the ring anchor."""
    router = CacheAwareRouter(
        CacheAwareConfig(load_weight=1.0, load_scale=1.0, anchor_bonus=0.5)
    )
    cluster = make_cluster(2, routing=router)
    hint = PlacementHint(keys=("cold/key",))
    anchor = cluster.pick_node(hint)  # idle cluster: anchor bonus wins
    # bury the anchor in open sessions
    for _ in range(8):
        anchor.start_transaction()
    spilled = cluster.pick_node(hint)
    assert spilled.node_id != anchor.node_id
    cluster.stop()


def test_cache_aware_without_hint_routes_least_loaded():
    cluster = make_cluster(2, routing="cache_aware")
    busy = cluster.live_nodes()[0]
    for _ in range(4):
        busy.start_transaction()
    for _ in range(3):
        assert cluster.pick_node().node_id != busy.node_id
    cluster.stop()


# ---------------------------------------------------------------------------
# dead-node race guard
# ---------------------------------------------------------------------------

class _StaleSnapshotRouter(Router):
    """Pathological policy modeling the race: it decided from a snapshot
    taken BEFORE the node died and keeps returning that stale choice."""

    def __init__(self):
        self.stale_choice = None

    def route(self, nodes, hint=None):
        if self.stale_choice is None:
            self.stale_choice = nodes[0]
        return self.stale_choice  # deliberately skips the alive re-check


def test_pick_node_never_returns_a_known_dead_node():
    """The kill_node → _replace_node race: even if the policy's snapshot
    still contains the dead node, pick_node must not hand it out."""
    cluster = make_cluster(2, routing=_StaleSnapshotRouter())
    victim = cluster.pick_node()
    assert victim.alive
    victim.fail()  # dies WITHOUT the cluster-level sync (the race window)
    with pytest.raises(NodeFailed):
        cluster.pick_node()  # refuses, rather than returning a dead node
    cluster.stop()


def test_pick_node_reroutes_after_kill_before_replacement():
    cluster = make_cluster(3)
    dead = cluster.kill_node(0)
    # fault manager hasn't replaced it yet (no background threads): every
    # pick must still avoid the corpse
    for _ in range(10):
        node = cluster.pick_node()
        assert node.alive and node.node_id != dead.node_id
    cluster.stop()


def test_ring_updated_on_fault_manager_replacement():
    cluster = make_cluster(3, routing="consistent_hash", standby_nodes=1)
    hint = PlacementHint(keys=("k/route-me",))
    first = cluster.pick_node(hint)
    first.fail()
    cluster.fault_manager.step()  # heartbeat → _replace_node → router sync
    node = cluster.pick_node(hint)
    assert node.alive and node.node_id != first.node_id
    assert len(cluster.live_nodes()) == 3  # standby promoted
    cluster.stop()


# ---------------------------------------------------------------------------
# client hint plumbing
# ---------------------------------------------------------------------------

def test_client_routes_sessions_by_hint():
    cluster = make_cluster(4, routing="consistent_hash")
    ring = ConsistentHashRouter()
    ring.sync(cluster.live_nodes())
    client = cluster.client()
    hint = PlacementHint(uuid="wf-9", keys=("data/x",))
    tx = client.start_transaction("wf-9", hint=hint)
    assert client.node_of(tx).node_id == ring.owner_id("data/x")
    client.abort_transaction(tx)
    cluster.stop()


def test_client_retry_rehits_same_node_across_clients():
    """§3.3.1 retry locality without shared client state: a second client
    retrying the same uuid lands on the same node via the ring."""
    cluster = make_cluster(4, routing="consistent_hash")
    c1, c2 = cluster.client(), cluster.client()
    tx1 = c1.start_transaction("retry-uuid")
    n1 = c1.node_of(tx1)
    c1.abort_transaction(tx1)
    tx2 = c2.start_transaction("retry-uuid")
    assert c2.node_of(tx2).node_id == n1.node_id
    c2.abort_transaction(tx2)
    cluster.stop()


# ---------------------------------------------------------------------------
# AftNode.stats() snapshot
# ---------------------------------------------------------------------------

def test_node_stats_snapshot_fields_and_gauges():
    cluster = make_cluster(1)
    node = cluster.live_nodes()[0]
    tx = node.start_transaction()
    node.put(tx, "s/k", b"v")
    node.commit_transaction(tx)
    node.release_transaction(tx)
    open_tx = node.start_transaction()

    snap = node.stats()  # callable form: thread-safe snapshot
    assert isinstance(snap, dict) and snap is not node.stats
    assert snap["commits"] == node.stats["commits"] == 1  # dict form intact
    assert snap["open_sessions"] == 1
    assert snap["inflight_ops"] == 0
    assert snap["alive"] == 1
    assert 0.0 <= snap["data_cache_hit_rate"] <= 1.0
    for field in ("data_cache_hits", "data_cache_misses",
                  "data_cache_entries", "data_cache_bytes",
                  "metadata_records"):
        assert field in snap
    # mutating the snapshot cannot touch the node
    snap["commits"] = 999
    assert node.stats["commits"] == 1
    node.abort_transaction(open_tx)
    cluster.stop()


def test_node_stats_snapshot_is_thread_safe_under_traffic():
    cluster = make_cluster(1)
    node = cluster.live_nodes()[0]
    stop = threading.Event()
    errors = []

    def traffic():
        i = 0
        while not stop.is_set():
            tx = node.start_transaction()
            node.put(tx, f"t/{i % 7}", b"x")
            node.commit_transaction(tx)
            node.release_transaction(tx)
            i += 1

    def snapshotter():
        try:
            while not stop.is_set():
                snap = node.stats()
                assert snap["open_sessions"] >= 0
        except Exception as exc:  # pragma: no cover - the assertion target
            errors.append(exc)

    threads = [threading.Thread(target=traffic) for _ in range(2)]
    threads += [threading.Thread(target=snapshotter) for _ in range(2)]
    for t in threads:
        t.start()
    stop.wait(0.3)
    stop.set()
    for t in threads:
        t.join(timeout=5)
    assert not errors
    cluster.stop()


def test_data_cache_key_presence_index_tracks_evictions():
    from repro.core import DataCache, TxnId

    dc = DataCache(max_bytes=64)
    t1, t2 = TxnId(1, "a"), TxnId(2, "b")
    dc.put("k", t1, b"x" * 30)
    assert dc.contains_key("k")
    dc.put("k", t2, b"y" * 30)
    dc.put("m", t2, b"z" * 30)  # evicts (k, t1) — k still present via t2
    assert dc.contains_key("k") and dc.contains_key("m")
    dc.put("n", t2, b"w" * 60)  # evicts everything else
    assert dc.contains_key("n")
    assert not dc.contains_key("k") and not dc.contains_key("m")
