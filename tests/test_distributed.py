"""Distributed AFT (§4): multicast + pruning, fault-manager liveness,
two-phase global GC (§5.2), node replacement (§6.7)."""

import pytest

from repro.core import (
    AftCluster,
    AftNodeConfig,
    ClusterConfig,
    CommitSetCache,
    FaultManagerConfig,
    NodeFailed,
    TransactionRecord,
    TxnId,
    is_superseded,
)
from repro.core.records import COMMIT_PREFIX, DATA_PREFIX
from repro.storage import MemoryStorage


def make_cluster(n=2, **node_kw):
    cfg = ClusterConfig(
        num_nodes=n,
        node=AftNodeConfig(**node_kw),
        start_background_threads=False,  # deterministic stepping
    )
    return AftCluster(MemoryStorage(), cfg)


def put_commit(node, items, uuid=None):
    tx = node.start_transaction(uuid)
    for k, v in items.items():
        node.put(tx, k, v)
    return node.commit_transaction(tx)


# ------------------------------------------------------------- supersedence
def test_algorithm_2_supersedence():
    cache = CommitSetCache()
    t1 = TxnId(1, "a")
    t2 = TxnId(2, "b")
    cache.add(TransactionRecord(tid=t1, write_set=("k", "l")))
    cache.add(TransactionRecord(tid=t2, write_set=("k",)))
    # t1 not superseded: l has no newer version
    assert not is_superseded(cache.get(t1), cache)
    assert not is_superseded(cache.get(t2), cache)
    t3 = TxnId(3, "c")
    cache.add(TransactionRecord(tid=t3, write_set=("l",)))
    assert is_superseded(cache.get(t1), cache)  # both k and l superseded
    assert not is_superseded(cache.get(t3), cache)


# ----------------------------------------------------------------- multicast
def test_commits_propagate_between_nodes():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    put_commit(n0, {"k": b"v"})
    tx = n1.start_transaction()
    assert n1.get(tx, "k") is None  # not yet propagated
    cluster.step_all()
    tx2 = n1.start_transaction()
    assert n1.get(tx2, "k") == b"v"


def test_multicast_prunes_superseded(monkeypatch):
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    # two commits to the same key inside one multicast interval: the older is
    # locally superseded and must be omitted from the broadcast (§4.1)
    put_commit(n0, {"k": b"v1"})
    put_commit(n0, {"k": b"v2"})
    agent = cluster.agents[n0.node_id]
    agent.step()
    assert agent.pruned_total == 1
    cluster.step_all()
    tx = n1.start_transaction()
    assert n1.get(tx, "k") == b"v2"


def test_receiver_skips_superseded_on_merge():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    t_old = TxnId(1, "old")
    t_new = put_commit(n1, {"k": b"new"})
    assert t_old < t_new
    merged = n1.merge_remote_commits(
        [TransactionRecord(tid=t_old, write_set=("k",))]
    )
    assert merged == 0  # superseded by local knowledge (§4.1)
    assert n1.stats["remote_skipped_superseded"] == 1


# ---------------------------------------------------- fault manager liveness
def test_fault_manager_recovers_unannounced_commit():
    """§4.2: node commits, acks, dies before broadcasting — the fault manager
    finds the commit record in storage and notifies everyone."""
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    put_commit(n0, {"k": b"v"})
    n0.fail()  # dies with the fresh-commit queue undrained
    cluster.fault_manager.step()
    assert cluster.fault_manager.stats["recovered_commits"] >= 1
    tx = n1.start_transaction()
    assert n1.get(tx, "k") == b"v"


def test_node_replacement_bootstraps_from_commit_set():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    put_commit(n0, {"k": b"v"})
    cluster.step_all()
    dead = cluster.kill_node(0)
    cluster.fault_manager.check_heartbeats()
    live = cluster.live_nodes()
    assert len(live) == 2 and dead not in live
    fresh = [n for n in live if n is not n1][0]
    tx = fresh.start_transaction()
    assert fresh.get(tx, "k") == b"v"  # warmed from the Commit Set (§3.1)


def test_requests_to_dead_node_fail_but_cluster_serves():
    cluster = make_cluster(2)
    n0, _ = cluster.nodes
    n0.fail()
    with pytest.raises(NodeFailed):
        n0.start_transaction()
    client = cluster.client()
    tx = client.start_transaction()
    client.put(tx, "k", b"v")
    client.commit_transaction(tx)


# ------------------------------------------------------------- global GC
def test_local_gc_requires_supersedence_and_no_readers():
    cluster = make_cluster(1)
    (n0,) = cluster.nodes
    t1 = put_commit(n0, {"k": b"v1"})
    # a running transaction reads k@t1: GC must spare t1 (§5.1)
    tx = n0.start_transaction()
    assert n0.get(tx, "k") == b"v1"
    put_commit(n0, {"k": b"v2"})
    assert n0.gc_sweep_local() == []
    n0.abort_transaction(tx)
    removed = n0.gc_sweep_local()
    assert removed == [t1]
    assert n0.cache.get(t1) is None


def test_global_gc_deletes_only_after_all_nodes_ack():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    t1 = put_commit(n0, {"k": b"v1"})
    put_commit(n0, {"k": b"v2"})
    cluster.step_all()  # propagate both to n1 (older may be pruned en route)
    fm = cluster.fault_manager
    fm.ingest()
    # a reader on n1 pins t1 if it read it; here no readers — GC may proceed
    deleted = fm.gc_round()
    fm.deleter.drain()
    if deleted:
        data_keys = cluster.storage.list_keys(DATA_PREFIX)
        assert not any(t1.encode() in k for k in data_keys)
        commit_keys = cluster.storage.list_keys(COMMIT_PREFIX)
        assert not any(t1.encode() in k for k in commit_keys)
    # storage still serves the newest version
    tx = n1.start_transaction()
    assert n1.get(tx, "k") == b"v2"


def test_global_gc_blocked_by_remote_reader():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    t1 = put_commit(n0, {"k": b"v1"})
    cluster.step_all()
    # n1 has a running transaction that read k@t1
    tx = n1.start_transaction()
    assert n1.get(tx, "k") == b"v1"
    put_commit(n0, {"k": b"v2"})
    cluster.step_all()
    fm = cluster.fault_manager
    fm.ingest()
    deleted = fm.gc_round()
    assert deleted == 0  # n1's reader blocks the all-node ack
    data_keys = cluster.storage.list_keys(DATA_PREFIX)
    assert any(t1.encode() in k for k in data_keys)  # bytes survive
    # after the reader finishes, GC completes
    n1.commit_transaction(tx)
    for n in (n0, n1):
        n.gc_sweep_local()
    assert fm.gc_round() >= 1


def test_gc_then_fresh_node_never_sees_deleted_versions():
    cluster = make_cluster(2)
    n0, n1 = cluster.nodes
    put_commit(n0, {"k": b"v1"})
    put_commit(n0, {"k": b"v2"})
    cluster.step_all()
    for n in (n0, n1):
        n.gc_sweep_local()
    cluster.fault_manager.ingest()
    cluster.fault_manager.gc_round()
    cluster.fault_manager.deleter.drain()
    fresh = AftCluster(
        cluster.storage,
        ClusterConfig(num_nodes=1, start_background_threads=False),
    ).nodes[0]
    tx = fresh.start_transaction()
    assert fresh.get(tx, "k") == b"v2"


# ------------------------------------------------------------ orphan spills
def test_orphan_spill_sweep():
    cluster = make_cluster(1, write_buffer_max_bytes=32)
    (n0,) = cluster.nodes
    tx = n0.start_transaction()
    n0.put(tx, "a", b"x" * 64)  # spills
    n0.fail()  # crash pre-commit: spill orphaned
    spills = [k for k in cluster.storage.list_keys(DATA_PREFIX) if "/.spill/" in k]
    assert spills
    fm = cluster.fault_manager
    fm.config.orphan_spill_age_s = 0.0
    assert fm.sweep_orphan_spills() == len(spills)
    fm.deleter.drain()
    assert [k for k in cluster.storage.list_keys(DATA_PREFIX) if "/.spill/" in k] == []


def test_committed_spills_survive_orphan_sweep():
    cluster = make_cluster(1, write_buffer_max_bytes=32)
    (n0,) = cluster.nodes
    tx = n0.start_transaction()
    n0.put(tx, "a", b"x" * 64)
    n0.commit_transaction(tx)
    cluster.step_all()  # fault manager learns the commit (and its spill keys)
    fm = cluster.fault_manager
    fm.config.orphan_spill_age_s = 0.0
    assert fm.sweep_orphan_spills() == 0
    tx2 = n0.start_transaction()
    assert n0.get(tx2, "a") == b"x" * 64
