"""Thread-safety of ONE transaction session driven by parallel branches.

A workflow DAG funnels every branch's get/put through a single AFT
transaction context; these tests hammer `read_set`/`buffer` from many
threads and assert the §3.2 session guarantees still hold: no internal
errors (dict-mutation races), repeatable reads (one version per key per
session), read-your-writes, and a commit containing every branch's write.
"""

import json
import threading
from concurrent.futures import ThreadPoolExecutor

import pytest

from repro.core import AftNode, AftNodeConfig
from repro.storage.memory import MemoryStorage

THREADS = 16
OPS = 60


def make_node(**cfg) -> AftNode:
    return AftNode(MemoryStorage(), AftNodeConfig(node_id="n0", **cfg))


def seed_versions(node: AftNode, keys, versions=3):
    for v in range(versions):
        tx = node.start_transaction()
        for k in keys:
            node.put(tx, k, f"{k}@v{v}".encode())
        node.commit_transaction(tx)
        node.release_transaction(tx)


def test_concurrent_reads_converge_on_one_version_per_key():
    node = make_node()
    keys = [f"k{i}" for i in range(8)]
    seed_versions(node, keys)
    tx = node.start_transaction()
    observed = [dict() for _ in range(THREADS)]
    errors = []

    def branch(ti: int) -> None:
        try:
            for i in range(OPS):
                k = keys[(ti + i) % len(keys)]
                value, tid = node.get_versioned(tx, k)
                assert value is not None
                prev = observed[ti].get(k)
                # repeatable reads within the session, across threads
                assert prev is None or prev == tid, (k, prev, tid)
                observed[ti][k] = tid
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    with ThreadPoolExecutor(THREADS) as pool:
        list(pool.map(branch, range(THREADS)))
    assert not errors, errors
    # every thread saw the SAME version per key (session-wide convergence)
    merged = {}
    for per_thread in observed:
        for k, tid in per_thread.items():
            assert merged.setdefault(k, tid) == tid
    # and the recorded read set matches what the threads saw
    assert node.read_set_of(tx) == merged


def test_concurrent_writes_all_land_in_one_commit():
    node = make_node()
    tx = node.start_transaction()
    errors = []

    def branch(ti: int) -> None:
        try:
            for i in range(OPS):
                node.put(tx, f"w{ti}/{i}", json.dumps({"t": ti, "i": i}).encode())
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    with ThreadPoolExecutor(THREADS) as pool:
        list(pool.map(branch, range(THREADS)))
    assert not errors, errors
    tid = node.commit_transaction(tx)
    record = node.cache.get(tid)
    assert record is not None
    assert len(record.write_set) == THREADS * OPS
    # read-back: every branch's write is visible post-commit
    tx2 = node.start_transaction()
    assert node.get(tx2, f"w0/0") == json.dumps({"t": 0, "i": 0}).encode()
    assert node.get(tx2, f"w{THREADS-1}/{OPS-1}") is not None
    node.abort_transaction(tx2)


def test_concurrent_mixed_get_put_with_ryw():
    """Interleaved reads+writes from parallel branches: reads of keys the
    session wrote must return the session's bytes (read-your-writes, §3.5),
    reads of foreign keys must stay repeatable."""
    node = make_node()
    shared = [f"s{i}" for i in range(4)]
    seed_versions(node, shared, versions=2)
    tx = node.start_transaction()
    errors = []

    def branch(ti: int) -> None:
        try:
            own = f"own{ti}"
            node.put(tx, own, f"mine-{ti}".encode())
            seen = {}
            for i in range(OPS):
                assert node.get(tx, own) == f"mine-{ti}".encode()
                k = shared[i % len(shared)]
                value, tid = node.get_versioned(tx, k)
                assert seen.setdefault(k, tid) == tid
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    with ThreadPoolExecutor(THREADS) as pool:
        list(pool.map(branch, range(THREADS)))
    assert not errors, errors
    tid = node.commit_transaction(tx)
    assert len(node.cache.get(tid).write_set) == THREADS


def test_concurrent_session_use_with_gc_sweeps():
    """GC iterates active read sets while branches mutate them — the
    historical dict-changed-size crash vector."""
    node = make_node(min_gc_age_s=0.0)
    keys = [f"g{i}" for i in range(6)]
    seed_versions(node, keys, versions=4)
    tx = node.start_transaction()
    stop = threading.Event()
    errors = []

    def sweeper() -> None:
        while not stop.is_set():
            try:
                node.gc_sweep_local()
            except BaseException as exc:  # noqa: BLE001
                errors.append(exc)
                return

    def branch(ti: int) -> None:
        try:
            for i in range(OPS):
                node.get(tx, keys[(ti + i) % len(keys)])
                node.put(tx, f"b{ti}/{i}", b"v")
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    gc_thread = threading.Thread(target=sweeper)
    gc_thread.start()
    with ThreadPoolExecutor(THREADS) as pool:
        list(pool.map(branch, range(THREADS)))
    stop.set()
    gc_thread.join(timeout=10)
    assert not errors, errors
    node.commit_transaction(tx)
