"""Weight publication through a shared WorkflowPool under failure injection.

``serve/refresh.py``'s publish DAG is driver-agnostic; these tests drive it
through a ``WorkflowPool`` (the fleet shape: many runs/steps publishing
concurrently through shared platform invocations) and prove the atomic /
exactly-once contract holds under injected step crashes and a node kill:
a reader never assembles a torn weight set, and re-driving a publish UUID
never double-commits.  Framework-free — no jax."""

import pytest

from repro.core import AftCluster, ClusterConfig
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.serve.refresh import (
    build_publish_workflow,
    manifest_key,
    publish_uuid,
    read_weight_set,
)
from repro.storage.memory import MemoryStorage
from repro.workflow import PoolConfig, TxnScope, WorkflowPool


def make_cluster(nodes=1, routing=None):
    return AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=nodes, start_background_threads=False,
                      routing=routing),
    )


def fast_platform(**kw):
    return LambdaPlatform(FaasConfig(time_scale=0.0, **kw))


def shard_bytes(step):
    return {f"part{i}": bytes([i]) * 64 + str(step).encode()
            for i in range(4)}


def publish_spec(run_id, step):
    blobs = shard_bytes(step)
    return build_publish_workflow(
        sorted(blobs), lambda name, _s: blobs[name],
        run_id=run_id, step=step)


def assert_untorn(cluster, run_id, expect_steps):
    """The visible set must be whole and from one publish.  Concurrent
    publishes commit in *commit* order, not submission order (which is why
    ``install_weights`` guards monotonically) — so the final visible step
    is any of ``expect_steps``."""
    got = read_weight_set(cluster.client(), run_id=run_id)
    assert got is not None
    step, blobs = got
    assert step in expect_steps
    assert blobs == shard_bytes(step)  # every shard from the same publish
    return step


def test_pool_publish_visible_and_untorn():
    cluster = make_cluster()
    platform = fast_platform()
    with WorkflowPool(platform, cluster=cluster,
                      config=PoolConfig(scope=TxnScope.WORKFLOW)) as pool:
        t = pool.submit(publish_spec("r0", 1), uuid=publish_uuid("r0", 1))
        res = t.result(timeout=60)
        assert res.results["manifest"] == 1
    assert_untorn(cluster, "r0", {1})
    platform.shutdown()


def test_pool_publish_survives_injected_crashes():
    """Step bodies crash at random (ctx.maybe_fail); the pool re-drives
    until every publish commits — and no reader interleaving can observe a
    half-published set (read_weight_set is one read transaction)."""
    cluster = make_cluster()
    platform = fast_platform(failure_rate=0.3, seed=13)
    cfg = PoolConfig(scope=TxnScope.WORKFLOW, max_attempts=40)
    steps = list(range(1, 6))
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        for s in steps:  # sequential: each step awaited, as a trainer would
            res = pool.submit(publish_spec("r1", s),
                              uuid=publish_uuid("r1", s)).result(timeout=120)
            assert res.results["manifest"] == s
            # the set visible after each commit is THIS complete publish
            assert_untorn(cluster, "r1", {s})
    assert_untorn(cluster, "r1", {max(steps)})
    platform.shutdown()


def test_pool_publish_redrive_same_uuid_exactly_once():
    """Re-submitting a committed publish UUID must dedupe, not re-commit:
    the manifest's version history grows by exactly one commit."""
    cluster = make_cluster()
    platform = fast_platform()
    with WorkflowPool(platform, cluster=cluster,
                      config=PoolConfig(scope=TxnScope.WORKFLOW)) as pool:
        first = pool.submit(publish_spec("r2", 7),
                            uuid=publish_uuid("r2", 7)).result(timeout=60)
        again = pool.submit(publish_spec("r2", 7),
                            uuid=publish_uuid("r2", 7)).result(timeout=60)
    assert first.committed_tid is not None
    # the re-drive resolves against the SAME committed transaction
    assert again.committed_tid == first.committed_tid
    assert again.deduped or again.steps_memoized > 0
    assert_untorn(cluster, "r2", {7})
    platform.shutdown()


def test_pool_publish_through_node_kill():
    """Hard-kill an AFT node while a stream of publishes is in flight:
    every publish lands, the final set is whole."""
    cluster = make_cluster(nodes=2, routing="consistent_hash")
    platform = fast_platform(failure_rate=0.1, seed=7)
    cfg = PoolConfig(scope=TxnScope.WORKFLOW, max_attempts=40)
    steps = list(range(1, 9))
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [
            pool.submit(publish_spec("r3", s), uuid=publish_uuid("r3", s))
            for s in steps
        ]
        cluster.kill_node(0)
        results = [t.result(timeout=120) for t in tickets]
    assert [r.results["manifest"] for r in results] == steps
    assert_untorn(cluster, "r3", set(steps))
    platform.shutdown()


def test_step_scope_reader_never_torn_mid_publish():
    """A read-only consumer polling while publishes stream through the
    pool: each observation is a complete set of a single step."""
    cluster = make_cluster()
    platform = fast_platform()
    cfg = PoolConfig(scope=TxnScope.WORKFLOW, max_attempts=20)
    observations = []
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [
            pool.submit(publish_spec("r4", s), uuid=publish_uuid("r4", s))
            for s in range(1, 7)
        ]
        import time
        while not all(t.done() for t in tickets):
            got = read_weight_set(cluster.client(), run_id="r4")
            if got is not None:
                observations.append(got)
            time.sleep(0.001)
        for t in tickets:
            t.result(timeout=60)
    for step, blobs in observations:
        assert blobs == shard_bytes(step), f"torn set at step {step}"
    assert_untorn(cluster, "r4", set(range(1, 7)))
    platform.shutdown()


def test_manifest_key_shape():
    assert manifest_key("weights", "run") == "weights/run/manifest"
    with pytest.raises(TypeError):
        manifest_key()  # keys are explicit, no defaults
