"""Workflow spec layer: DAG validation, topology, fan-out/fan-in builders."""

import pytest

from repro.workflow import WorkflowSpec, WorkflowSpecError


def noop(ctx):
    return None


def test_topological_order_respects_deps():
    spec = WorkflowSpec("wf")
    spec.step("a", noop)
    spec.step("b", noop, deps=["a"])
    spec.step("c", noop, deps=["a"])
    spec.step("d", noop, deps=["b", "c"])
    order = spec.topological_order()
    assert order.index("a") < order.index("b")
    assert order.index("a") < order.index("c")
    assert order.index("b") < order.index("d")
    assert order.index("c") < order.index("d")
    spec.validate()


def test_cycle_detected():
    spec = WorkflowSpec("wf")
    spec.step("a", noop, deps=["b"])
    spec.step("b", noop, deps=["a"])
    with pytest.raises(WorkflowSpecError, match="cycle"):
        spec.validate()


def test_unknown_dep_rejected():
    spec = WorkflowSpec("wf")
    spec.step("a", noop, deps=["ghost"])
    with pytest.raises(WorkflowSpecError, match="unknown step"):
        spec.validate()


def test_self_dep_rejected():
    spec = WorkflowSpec("wf")
    spec.step("a", noop, deps=["a"])
    with pytest.raises(WorkflowSpecError, match="itself"):
        spec.validate()


def test_duplicate_name_rejected():
    spec = WorkflowSpec("wf")
    spec.step("a", noop)
    with pytest.raises(WorkflowSpecError, match="duplicate"):
        spec.step("a", noop)


def test_fan_out_fan_in_shape():
    spec = WorkflowSpec("wf")
    spec.step("src", noop)
    names = spec.fan_out("shard", noop, 4, deps=["src"])
    assert names == ["shard[0]", "shard[1]", "shard[2]", "shard[3]"]
    assert [spec.steps[n].branch for n in names] == [0, 1, 2, 3]
    agg = spec.fan_in("agg", noop, names)
    assert spec.steps[agg].deps == tuple(names)
    assert spec.steps[agg].allow_skipped_deps  # tolerant by default
    spec.validate()
    assert len(spec) == 6
    assert "shard[2]" in spec


def test_fan_out_rejects_zero():
    spec = WorkflowSpec("wf")
    with pytest.raises(WorkflowSpecError):
        spec.fan_out("s", noop, 0)


def test_roots_and_dependents():
    spec = WorkflowSpec("wf")
    spec.step("a", noop)
    spec.step("b", noop, deps=["a"])
    spec.step("z", noop)
    assert set(spec.roots()) == {"a", "z"}
    assert spec.dependents_of()["a"] == ["b"]
