"""The inference serving lane: requests as read-only AFT workflows.

Covers session placement stickiness, the shard codec round-trip (including
torn-set detection), atomic publish → snapshot-probed poll → monotonic
install, and re-routing after a replica's node dies."""

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import AftCluster, ClusterConfig  # noqa: E402
from repro.faas.platform import FaasConfig, LambdaPlatform  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.models.config import get_config  # noqa: E402
from repro.serve.engine import ContinuousEngine, ServeConfig  # noqa: E402
from repro.serve.lane import (  # noqa: E402
    InferenceLane,
    LaneConfig,
    TornWeightSet,
    params_to_shards,
    shards_to_params,
)
from repro.storage.memory import MemoryStorage  # noqa: E402
from repro.workflow import PoolConfig, TxnScope, WorkflowPool  # noqa: E402


# --------------------------------------------------------------- shard codec

def small_tree():
    return {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"w": np.ones((4,), np.float32),
                  "s": np.asarray(2.5, np.float32)}}


def test_shard_roundtrip():
    tree = small_tree()
    blobs = params_to_shards(tree, step=9, shards=2)
    assert sorted(blobs) == ["part0", "part1"]
    out, step = shards_to_params(blobs, tree)
    assert step == 9
    for want, got in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_shard_torn_step_detected():
    tree = small_tree()
    a = params_to_shards(tree, step=1, shards=2)
    b = params_to_shards(tree, step=2, shards=2)
    torn = {"part0": a["part0"], "part1": b["part1"]}
    with pytest.raises(TornWeightSet):
        shards_to_params(torn, tree)


def test_shard_missing_leaves_detected():
    tree = small_tree()
    blobs = params_to_shards(tree, step=1, shards=2)
    with pytest.raises(TornWeightSet):
        shards_to_params({"part0": blobs["part0"]}, tree)


# ------------------------------------------------------------------ the lane

@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b").reduced(pattern_repeats=2),
        kv_cache_dtype="float32")
    model = Model(cfg)
    return model, model.init_params(jax.random.key(0))


@pytest.fixture()
def lane_setup(model_and_params):
    model, params = model_and_params
    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=2, start_background_threads=False,
                      routing="consistent_hash"))
    platform = LambdaPlatform(FaasConfig(time_scale=0.0))
    pool = WorkflowPool(platform, cluster=cluster,
                        config=PoolConfig(scope=TxnScope.STEP,
                                          max_attempts=8))
    scfg = ServeConfig(max_len=48, slots=4, prefill_chunk=4)
    replicas = {n.node_id: ContinuousEngine(model, None, scfg,
                                            name=f"rep-{n.node_id}")
                for n in cluster.live_nodes()}
    lane = InferenceLane(pool, cluster, replicas,
                         config=LaneConfig(run_id="t"))
    yield model, params, cluster, pool, platform, replicas, lane
    lane.stop()
    pool.close()
    platform.shutdown()


def install_all(lane, cluster, replicas, params, step):
    lane.publish(params, step)
    cluster.step_all()  # propagate commit metadata without gossip threads
    lane.poll_weights()
    assert all(e.weights_step == step for e in replicas.values())


def test_publish_poll_install_and_serve(lane_setup):
    model, params, cluster, pool, platform, replicas, lane = lane_setup
    install_all(lane, cluster, replicas, params, 1)
    for eng in replicas.values():
        eng.start()

    tickets = [lane.submit(f"s{i % 2}", [1 + i, 2, 3], max_new=3)
               for i in range(6)]
    results = [InferenceLane.payload(t.result(timeout=60)) for t in tickets]
    assert all(len(r["tokens"]) == 3 for r in results)
    assert all(r["weights_step"] == 1 for r in results)
    # session stickiness: every request of a session served by ONE node
    by_session = {}
    for i, r in enumerate(results):
        by_session.setdefault(i % 2, set()).add(r["node"])
    assert all(len(nodes) == 1 for nodes in by_session.values())
    assert lane.stats["torn_reads"] == 0
    assert lane.stats["completed"] == 6


def test_refresh_under_traffic_and_snapshot_skip(lane_setup):
    model, params, cluster, pool, platform, replicas, lane = lane_setup
    install_all(lane, cluster, replicas, params, 1)
    for eng in replicas.values():
        eng.start()

    params2 = jax.tree.map(lambda x: x * 1.01, params)
    install_all(lane, cluster, replicas, params2, 2)
    # replicas already current → the snapshot probe skips the read txn
    before = lane.stats["snapshot_skips"]
    assert not lane.poll_weights()
    assert lane.stats["snapshot_skips"] > before

    r = InferenceLane.payload(
        lane.submit("s0", [9, 9, 9], max_new=2).result(timeout=60))
    assert r["weights_step"] == 2
    assert r["manifest_step"] == 2
    assert lane.stats["torn_reads"] == 0


def test_kill_reroutes_to_live_replica(lane_setup):
    model, params, cluster, pool, platform, replicas, lane = lane_setup
    install_all(lane, cluster, replicas, params, 1)
    for eng in replicas.values():
        eng.start()

    victim = cluster.live_nodes()[0]
    cluster.kill_node(0)
    lane.detach(victim.node_id)
    survivor = cluster.live_nodes()[0].node_id

    results = [InferenceLane.payload(
        lane.submit(f"s{i}", [3 + i, 4, 5], max_new=2).result(timeout=60))
        for i in range(4)]
    assert all(r["node"] == survivor for r in results)
    assert all(len(r["tokens"]) == 2 for r in results)


def test_tokenize_step_string_prompts(lane_setup):
    model, params, cluster, pool, platform, replicas, lane = lane_setup
    install_all(lane, cluster, replicas, params, 1)
    for eng in replicas.values():
        eng.start()
    r = InferenceLane.payload(
        lane.submit("s0", "hi there", max_new=2).result(timeout=60))
    assert len(r["tokens"]) == 2  # tokenizer step mapped str → token ids
