"""Algorithm 1 (§3.4) unit tests, including the paper's worked examples."""

import pytest

from repro.core import (
    CommitSetCache,
    ReadStatus,
    TransactionRecord,
    TxnId,
    atomic_read_select,
    is_atomic_readset,
)


def tid(i: int) -> TxnId:
    return TxnId(i, f"uuid-{i:04d}")


def commit(cache: CommitSetCache, i: int, *keys: str) -> TxnId:
    t = tid(i)
    cache.add(TransactionRecord(tid=t, write_set=tuple(sorted(keys))))
    return t


def test_read_latest_when_unconstrained():
    cache = CommitSetCache()
    commit(cache, 1, "k")
    t2 = commit(cache, 2, "k")
    sel = atomic_read_select("k", {}, cache)
    assert sel.status is ReadStatus.OK and sel.tid == t2


def test_null_read_when_key_never_written():
    cache = CommitSetCache()
    commit(cache, 1, "other")
    sel = atomic_read_select("k", {}, cache)
    assert sel.status is ReadStatus.NOT_FOUND


def test_paper_example_section_3_2():
    """T1:{l1}, T2:{k2,l2}; Tn reads k2 first ⇒ later read of l must be ≥ l2."""
    cache = CommitSetCache()
    t1 = commit(cache, 1, "l")
    t2 = commit(cache, 2, "k", "l")
    sel_k = atomic_read_select("k", {}, cache)
    assert sel_k.tid == t2
    R = {"k": t2}
    sel_l = atomic_read_select("l", R, cache)
    # returning l1 would violate Definition 1; must return l2
    assert sel_l.status is ReadStatus.OK and sel_l.tid == t2


def test_lower_bound_skips_older_versions():
    """Case (1): cowritten sibling of a prior read forces newer-or-equal."""
    cache = CommitSetCache()
    commit(cache, 1, "k")
    t5 = commit(cache, 5, "k", "l")
    sel = atomic_read_select("k", {"l": t5}, cache)
    assert sel.tid == t5  # k1 < lower bound t5 is not considered


def test_case2_rejects_conflicting_candidate():
    """§3.6 staleness: after reading l_i, k_j with l∈cowritten(k_j), j>i is
    invalid; fall back to an older valid version of k."""
    cache = CommitSetCache()
    t1 = commit(cache, 1, "l")
    t2 = commit(cache, 2, "k")        # old-but-valid version of k
    t3 = commit(cache, 3, "k", "l")   # cowrites l at version 3 > 1 ⇒ invalid
    sel = atomic_read_select("k", {"l": t1}, cache)
    assert sel.status is ReadStatus.OK and sel.tid == t2


def test_staleness_abort_when_only_conflicting_version_exists():
    """§3.6: if k_j is the only version of k and it conflicts, return NULL —
    'equivalent to reading from a fixed database snapshot'."""
    cache = CommitSetCache()
    t1 = commit(cache, 1, "l")
    commit(cache, 3, "k", "l")
    sel = atomic_read_select("k", {"l": t1}, cache)
    assert sel.status is ReadStatus.NO_VALID_VERSION


def test_gc_hole_example_section_5_2_1():
    """Ta:{k_a}, Tb:{l_b}, Tc:{k_c,l_c}, a<b<c.  Tr reads k_a; if Tb's
    metadata was GC'd, the read of l finds no valid version (l_c conflicts)."""
    cache = CommitSetCache()
    ta = commit(cache, 1, "k")
    commit(cache, 3, "k", "l")  # Tc
    # Tb was garbage collected: never added
    sel = atomic_read_select("l", {"k": ta}, cache)
    assert sel.status is ReadStatus.NO_VALID_VERSION

    # ... and with Tb present, the read succeeds at l_b
    tb = commit(cache, 2, "l")
    sel2 = atomic_read_select("l", {"k": ta}, cache)
    assert sel2.status is ReadStatus.OK and sel2.tid == tb


def test_repeatable_read_emerges_from_algorithm():
    """Corollary 1.1: re-running Algorithm 1 for a key already in R returns
    the same version even after newer commits."""
    cache = CommitSetCache()
    t1 = commit(cache, 1, "k", "x")
    sel1 = atomic_read_select("k", {}, cache)
    assert sel1.tid == t1
    R = {"k": t1}
    commit(cache, 9, "k", "x")  # newer version arrives mid-transaction
    sel2 = atomic_read_select("k", R, cache)
    assert sel2.tid == t1  # same version: repeatable read


def test_newer_nonconflicting_version_preferred():
    cache = CommitSetCache()
    t1 = commit(cache, 1, "a")
    commit(cache, 2, "k")
    t3 = commit(cache, 3, "k")  # no overlap with prior reads ⇒ newest wins
    sel = atomic_read_select("k", {"a": t1}, cache)
    assert sel.tid == t3


def test_readset_checker_definition_1():
    t1, t2 = tid(1), tid(2)
    cow = {t1: frozenset({"l"}), t2: frozenset({"k", "l"})}
    assert is_atomic_readset({"k": t2, "l": t2}, cow)
    assert not is_atomic_readset({"k": t2, "l": t1}, cow)  # fractured
    assert is_atomic_readset({"l": t1}, cow)


def test_incremental_reads_always_form_atomic_readset():
    """Theorem 1: grow R through Algorithm 1 and check Definition 1 directly
    after every read."""
    cache = CommitSetCache()
    commits = [
        (1, ("a", "b")),
        (2, ("b", "c")),
        (3, ("a", "c", "d")),
        (4, ("d",)),
        (5, ("a", "b", "c", "d", "e")),
    ]
    for i, keys in commits:
        commit(cache, i, *keys)
    cowritten_of = {
        tid(i): frozenset(keys) for i, keys in commits
    }
    R = {}
    for key in ["b", "a", "d", "c", "e", "a", "b"]:
        sel = atomic_read_select(key, R, cache)
        assert sel.status is ReadStatus.OK
        R[key] = sel.tid
        assert is_atomic_readset(R, cowritten_of)
