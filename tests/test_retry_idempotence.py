"""Cross-node retry idempotence (§3.3.1): exactly-once even when the ack is
lost and the retry lands on a different node before multicast propagates."""

import pytest

from repro.core import (
    AftCluster,
    AftNode,
    AftNodeConfig,
    ClusterConfig,
)
from repro.core.records import COMMIT_PREFIX
from repro.storage import MemoryStorage


def test_retry_on_fresh_node_finds_commit_in_storage():
    storage = MemoryStorage()
    n0 = AftNode(storage, AftNodeConfig(node_id="n0"))
    tx = n0.start_transaction()
    n0.put(tx, "k", b"v")
    tid = n0.commit_transaction(tx)
    # ack lost; n0 dies before broadcasting; retry lands on a brand-new node
    # that has NOT bootstrapped this commit (bootstrap=False simulates the
    # multicast race window)
    n1 = AftNode(storage, AftNodeConfig(node_id="n1"), bootstrap=False)
    tx2 = n1.start_transaction(tid.uuid)  # same UUID ⇒ retry
    n1.put(tx2, "k", b"v")
    tid2 = n1.commit_transaction(tx2)
    assert tid2 == tid
    assert len(storage.list_keys(COMMIT_PREFIX)) == 1  # exactly one commit


def test_client_retry_sticks_to_owning_node():
    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=3, start_background_threads=False),
    )
    client = cluster.client()
    tx = client.start_transaction()
    node = client.node_of(tx)
    client.put(tx, "k", b"v")
    client.commit_transaction(tx)
    # a retry with the same UUID routes back to the same node
    tx2 = client.start_transaction(tx)
    assert client.node_of(tx2) is node
    tid = client.commit_transaction(tx2)
    assert tid is not None
    assert len(cluster.storage.list_keys(COMMIT_PREFIX)) == 1


def test_retry_after_owner_death_falls_back_to_scan():
    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=2, start_background_threads=False),
    )
    client = cluster.client()
    tx = client.start_transaction()
    client.put(tx, "k", b"v")
    tid = client.commit_transaction(tx)
    # owner dies before multicast; retry must land elsewhere and still be
    # idempotent via the Commit Set scan
    owner = [n for n in cluster.nodes if n.committed_tid_for_uuid(tx)][0]
    owner.fail()
    tx2 = client.start_transaction(tx)
    other = client.node_of(tx2)
    assert other is not owner
    client.put(tx2, "k", b"v")
    tid2 = client.commit_transaction(tx2)
    assert tid2 == tid
    assert len(cluster.storage.list_keys(COMMIT_PREFIX)) == 1
