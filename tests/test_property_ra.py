"""Property-based verification of AFT's isolation guarantees.

A hypothesis state machine interleaves transactions (across multiple nodes of
a cluster), commits, aborts, GC sweeps, multicast rounds, and node crashes —
and validates *independently of the implementation* that every observation
satisfies the paper's §3.2 guarantees:

* no dirty reads — every returned version was committed;
* no fractured reads — each transaction's accumulated read set is an Atomic
  Readset per Definition 1, checked against a ground-truth cowritten map
  maintained by the test itself;
* read-your-writes — reads after an own write return the written bytes;
* repeatable reads — re-reads (without intervening own writes) return the
  same version;
* value integrity — bytes returned match the bytes committed for the version.
"""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import HealthCheck, settings
from hypothesis.stateful import (
    Bundle,
    RuleBasedStateMachine,
    consumes,
    initialize,
    invariant,
    precondition,
    rule,
)

from repro.core import (
    AftCluster,
    AftNodeConfig,
    ClusterConfig,
    NodeFailed,
    ReadAbortError,
    TransactionNotRunning,
    UnknownTransaction,
    is_atomic_readset,
)
from repro.storage import MemoryStorage

KEYS = ["k0", "k1", "k2"]  # small key space ⇒ dense version histories


class AftIsolationMachine(RuleBasedStateMachine):
    txns = Bundle("txns")

    @initialize(num_nodes=st.integers(1, 3))
    def setup(self, num_nodes):
        self.cluster = AftCluster(
            MemoryStorage(),
            ClusterConfig(
                num_nodes=num_nodes,
                node=AftNodeConfig(min_gc_age_s=0.0),
                start_background_threads=False,
            ),
        )
        self.counter = 0
        # ground truth, maintained by the test alone:
        self.committed_cowritten = {}   # tid -> frozenset(keys)
        self.committed_values = {}      # (key, tid) -> bytes
        self.live = {}                  # txid -> state dict

    # ------------------------------------------------------------- lifecycle
    @rule(target=txns)
    def start_txn(self):
        node = self.cluster.pick_node()
        txid = node.start_transaction()
        self.live[txid] = {
            "node": node,
            "reads": {},       # key -> tid observed
            "writes": {},      # key -> bytes (latest own write)
            "done": False,
        }
        return txid

    @rule(txn=txns, key=st.sampled_from(KEYS), size=st.integers(1, 32))
    def put(self, txn, key, size):
        state = self.live[txn]
        if state["done"] or not state["node"].alive:
            return
        self.counter += 1
        value = f"{txn[:6]}:{self.counter}".encode() + b"#" * size
        try:
            state["node"].put(txn, key, value)
        except (NodeFailed, TransactionNotRunning, UnknownTransaction):
            state["done"] = True
            return
        state["writes"][key] = value

    @rule(txn=txns, key=st.sampled_from(KEYS))
    def get(self, txn, key):
        state = self.live[txn]
        if state["done"] or not state["node"].alive:
            return
        try:
            value, tid = state["node"].get_versioned(txn, key)
        except ReadAbortError:
            # §3.6 staleness abort is a legal outcome; the client retries.
            state["node"].abort_transaction(txn)
            state["done"] = True
            return
        except (NodeFailed, TransactionNotRunning, UnknownTransaction):
            state["done"] = True
            return

        if key in state["writes"]:
            # read-your-writes (§3.5): must be our bytes, via the buffer
            assert value == state["writes"][key], "RYW violation"
            assert tid is None
            return
        if tid is None:
            assert value is None, "NULL version carried a value"
            return
        # no dirty reads: the version must be a committed transaction
        assert tid in self.committed_cowritten, f"dirty read of {key}@{tid}"
        # value integrity
        assert value == self.committed_values[key, tid], "wrong version bytes"
        # repeatable read (Corollary 1.1)
        prior = state["reads"].get(key)
        if prior is not None:
            assert tid == prior, "repeatable-read violation"
        state["reads"][key] = tid
        # no fractured reads: Definition 1 over ground-truth cowritten sets
        assert is_atomic_readset(state["reads"], self.committed_cowritten), (
            "fractured read set"
        )

    @rule(txn=consumes(txns))
    def commit(self, txn):
        state = self.live.pop(txn)
        if state["done"] or not state["node"].alive:
            return
        try:
            tid = state["node"].commit_transaction(txn)
        except (NodeFailed, TransactionNotRunning, UnknownTransaction):
            return
        if state["writes"]:
            self.committed_cowritten[tid] = frozenset(state["writes"])
            for k, v in state["writes"].items():
                self.committed_values[k, tid] = v

    @rule(txn=consumes(txns))
    def abort(self, txn):
        state = self.live.pop(txn)
        if state["done"] or not state["node"].alive:
            return
        try:
            state["node"].abort_transaction(txn)
        except (NodeFailed, TransactionNotRunning, UnknownTransaction):
            pass

    @rule(keys=st.sets(st.sampled_from(KEYS), min_size=1, max_size=3))
    def whole_txn_commit(self, keys):
        """A complete multi-key writer in one step.  This is what makes
        fractured-read scenarios *reachable* for the random walk: a reader
        holding an old version immediately faces a newer cowritten group."""
        try:
            node = self.cluster.pick_node()
        except NodeFailed:
            return
        txid = node.start_transaction()
        self.counter += 1
        writes = {}
        for k in keys:
            value = f"W{self.counter}:{k}".encode()
            node.put(txid, k, value)
            writes[k] = value
        tid = node.commit_transaction(txid)
        node.release_transaction(txid)
        self.committed_cowritten[tid] = frozenset(writes)
        for k, v in writes.items():
            self.committed_values[k, tid] = v

    # ------------------------------------------------------- background ops
    @rule()
    def multicast_round(self):
        for agent in list(self.cluster.agents.values()):
            agent.step()
        for agent in list(self.cluster.agents.values()):
            agent.step()

    @rule()
    def local_gc(self):
        for node in self.cluster.live_nodes():
            node.gc_sweep_local()

    @rule()
    def global_gc(self):
        fm = self.cluster.fault_manager
        fm.ingest()
        fm.scan_commit_set()
        fm.gc_round()
        fm.deleter.drain()

    @rule()
    def crash_and_replace_node(self):
        if len(self.cluster.live_nodes()) <= 1:
            return
        dead = self.cluster.kill_node(0)
        # transactions pinned to the dead node are lost (§3.3.1)
        for state in self.live.values():
            if state["node"] is dead:
                state["done"] = True
        self.cluster.fault_manager.check_heartbeats()

    # ---------------------------------------------------------- invariants
    @invariant()
    def committed_data_remains_readable(self):
        # every key's *latest* committed version must stay readable by a
        # fresh transaction (GC must never delete live heads)
        if not self.committed_cowritten:
            return
        latest = {}
        for tid, keys in self.committed_cowritten.items():
            for k in keys:
                if k not in latest or tid > latest[k]:
                    latest[k] = tid
        try:
            node = self.cluster.pick_node()
        except NodeFailed:
            return
        tx = node.start_transaction()
        try:
            for k, expect_tid in latest.items():
                try:
                    value, tid = node.get_versioned(tx, k)
                except ReadAbortError:
                    raise AssertionError(f"latest head of {k} unreadable")
                # node may not have heard of the newest commit yet (multicast
                # is async); it must return *some* committed version
                if tid is not None:
                    assert tid in self.committed_cowritten
                    assert value == self.committed_values[k, tid]
        finally:
            node.abort_transaction(tx)
            node.release_transaction(tx)

    def teardown(self):
        self.cluster.stop()


AftIsolationTest = AftIsolationMachine.TestCase
AftIsolationTest.settings = settings(
    max_examples=40,
    stateful_step_count=40,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
