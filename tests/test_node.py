"""AftNode: Table-1 API, the §3.3 write-ordering commit protocol, §3.5
guarantees, idempotence (§3.3.1), and buffer spill."""

import pytest

from repro.core import (
    AftNode,
    AftNodeConfig,
    ReadAbortError,
    TransactionNotRunning,
    TransactionRecord,
    TxnState,
    commit_key,
)
from repro.core.records import COMMIT_PREFIX, DATA_PREFIX
from repro.storage import MemoryStorage


@pytest.fixture
def node():
    return AftNode(MemoryStorage(), AftNodeConfig(node_id="n0"))


def put_commit(node, items):
    tx = node.start_transaction()
    for k, v in items.items():
        node.put(tx, k, v)
    return node.commit_transaction(tx)


# ---------------------------------------------------------------- commit path
def test_commit_then_read_roundtrip(node):
    put_commit(node, {"k": b"v1", "l": b"w1"})
    tx = node.start_transaction()
    assert node.get(tx, "k") == b"v1"
    assert node.get(tx, "l") == b"w1"


def test_write_ordering_data_before_commit_record():
    """§3.3: every version is durable before the commit record exists."""
    order = []

    class TracingStorage(MemoryStorage):
        def put(self, key, value):
            order.append(key)
            super().put(key, value)

        def put_batch(self, items):
            order.extend(items.keys())
            super().put_batch(items)

    node = AftNode(TracingStorage(), AftNodeConfig())
    put_commit(node, {"a": b"1", "b": b"2"})
    commit_idx = [i for i, k in enumerate(order) if k.startswith(COMMIT_PREFIX)]
    data_idx = [i for i, k in enumerate(order) if k.startswith(DATA_PREFIX)]
    assert len(commit_idx) == 1 and len(data_idx) == 2
    assert max(data_idx) < commit_idx[0]  # data strictly precedes the record


def test_versions_never_overwritten_in_place(node):
    """§3.3: each key version maps to a unique storage key."""
    put_commit(node, {"k": b"v1"})
    put_commit(node, {"k": b"v2"})
    data_keys = node.storage.list_keys(DATA_PREFIX)
    assert len([k for k in data_keys if k.startswith("d/k/")]) == 2


def test_uncommitted_writes_invisible_to_others(node):
    tx1 = node.start_transaction()
    node.put(tx1, "k", b"dirty")
    tx2 = node.start_transaction()
    assert node.get(tx2, "k") is None  # no dirty reads (§3.3)
    node.commit_transaction(tx1)
    tx3 = node.start_transaction()
    assert node.get(tx3, "k") == b"dirty"


def test_abort_discards_everything(node):
    tx = node.start_transaction()
    node.put(tx, "k", b"x")
    node.abort_transaction(tx)
    assert node.storage.list_keys(DATA_PREFIX) == []
    tx2 = node.start_transaction()
    assert node.get(tx2, "k") is None
    with pytest.raises(TransactionNotRunning):
        node.put(tx, "k", b"y")


def test_read_only_transaction_writes_nothing(node):
    put_commit(node, {"k": b"v"})
    before = len(node.storage.list_keys())
    tx = node.start_transaction()
    node.get(tx, "k")
    node.commit_transaction(tx)
    assert len(node.storage.list_keys()) == before


# ------------------------------------------------------------------ RYW / RR
def test_read_your_writes_precedes_algorithm_1(node):
    put_commit(node, {"k": b"committed"})
    tx = node.start_transaction()
    node.put(tx, "k", b"mine-1")
    assert node.get(tx, "k") == b"mine-1"
    node.put(tx, "k", b"mine-2")  # §3.2: successive writes supersede
    assert node.get(tx, "k") == b"mine-2"


def test_repeatable_read_across_concurrent_commit(node):
    put_commit(node, {"k": b"old"})
    tx = node.start_transaction()
    assert node.get(tx, "k") == b"old"
    put_commit(node, {"k": b"new"})  # concurrent writer
    assert node.get(tx, "k") == b"old"  # Corollary 1.1


def test_ryw_overrides_repeatable_read(node):
    """§3.2: RYW is enforced at the expense of repeatable read."""
    put_commit(node, {"k": b"old"})
    tx = node.start_transaction()
    assert node.get(tx, "k") == b"old"
    node.put(tx, "k", b"mine")
    assert node.get(tx, "k") == b"mine"


def test_fast_repeatable_read_matches_algorithm():
    storage = MemoryStorage()
    slow = AftNode(storage, AftNodeConfig(node_id="slow"))
    fast = AftNode(storage, AftNodeConfig(node_id="fast", fast_repeatable_read=True))
    put_commit(slow, {"k": b"v0", "l": b"w0"})
    fast.bootstrap()
    for node in (slow, fast):
        tx = node.start_transaction()
        a = node.get(tx, "k")
        put_commit(slow, {"k": b"v-new"})
        node.merge_remote_commits([])  # no-op; fast node may not know anyway
        b = node.get(tx, "k")
        assert a == b  # repeatable under both implementations


# -------------------------------------------------------------- atomicity
def test_fractured_execution_never_visible(node):
    """§1's motivating example: f writes k then l; a failure between the
    writes must not expose k without l."""
    tx = node.start_transaction()
    node.put(tx, "k", b"k-new")
    # function dies before writing l and before commit: nothing visible
    node.abort_transaction(tx)
    tx2 = node.start_transaction()
    assert node.get(tx2, "k") is None


def test_atomic_readset_across_transactions(node):
    put_commit(node, {"l": b"l1"})
    put_commit(node, {"k": b"k2", "l": b"l2"})
    tx = node.start_transaction()
    assert node.get(tx, "k") == b"k2"
    assert node.get(tx, "l") == b"l2"  # l1 would be fractured


def test_staleness_abort_raises(node):
    t_l = put_commit(node, {"l": b"l1"})
    tx = node.start_transaction()
    assert node.get(tx, "l") == b"l1"
    put_commit(node, {"k": b"k3", "l": b"l3"})
    with pytest.raises(ReadAbortError):
        node.get(tx, "k")  # only version of k cowrites l3 > l1 (§3.6)
    assert node.stats["staleness_aborts"] == 1


# ------------------------------------------------------------- idempotence
def test_commit_idempotent_per_uuid(node):
    tx = node.start_transaction()
    node.put(tx, "k", b"v")
    tid1 = node.commit_transaction(tx)
    tid2 = node.commit_transaction(tx)  # client retry after lost ack
    assert tid1 == tid2
    assert len(node.storage.list_keys(COMMIT_PREFIX)) == 1
    assert len(node.storage.list_keys(DATA_PREFIX)) == 1


def test_retry_with_same_uuid_continues_transaction(node):
    tx = node.start_transaction("retry-uuid")
    node.put(tx, "k", b"v")
    node.commit_transaction(tx)
    # a retried function re-opens with the same UUID (§3.3.1): committing
    # again persists nothing new
    tx2 = node.start_transaction("retry-uuid")
    node.put(tx2, "k", b"v")
    tid = node.commit_transaction(tx2)
    assert len(node.storage.list_keys(COMMIT_PREFIX)) == 1
    assert node.committed_tid_for_uuid("retry-uuid") == tid


# ------------------------------------------------------------ recovery
def test_node_restart_recovers_committed_state():
    storage = MemoryStorage()
    node = AftNode(storage, AftNodeConfig(node_id="n0"))
    put_commit(node, {"k": b"v", "l": b"w"})
    node.fail()
    # §3.3.1: commit metadata in storage ⇒ transaction survives the node
    node2 = AftNode(storage, AftNodeConfig(node_id="n1"))
    tx = node2.start_transaction()
    assert node2.get(tx, "k") == b"v"
    assert node2.get(tx, "l") == b"w"


def test_crash_before_commit_record_loses_transaction():
    storage = MemoryStorage()

    class DieBeforeRecord(MemoryStorage):
        def put(self, key, value):
            if key.startswith(COMMIT_PREFIX):
                raise RuntimeError("node died before commit record")
            super().put(key, value)

    dying = DieBeforeRecord()
    node = AftNode(dying, AftNodeConfig())
    tx = node.start_transaction()
    node.put(tx, "k", b"v")
    with pytest.raises(RuntimeError):
        node.commit_transaction(tx)
    # data bytes are orphaned in storage but no commit record exists: a fresh
    # node (or the same one) must not see the transaction
    node2 = AftNode(dying, AftNodeConfig(node_id="n2"))
    tx2 = node2.start_transaction()
    assert node2.get(tx2, "k") is None


# ------------------------------------------------------------- buffer spill
def test_buffer_spill_stays_invisible_until_commit():
    storage = MemoryStorage()
    node = AftNode(storage, AftNodeConfig(write_buffer_max_bytes=64))
    tx = node.start_transaction()
    big = b"x" * 100
    node.put(tx, "a", big)  # exceeds buffer: spills
    node.put(tx, "b", big)
    assert any("/.spill/" in k for k in storage.list_keys(DATA_PREFIX))
    tx_other = node.start_transaction()
    assert node.get(tx_other, "a") is None  # invisible pre-commit
    # read-your-writes still works for spilled values
    assert node.get(tx, "a") == big
    node.commit_transaction(tx)
    tx3 = node.start_transaction()
    assert node.get(tx3, "a") == big
    assert node.get(tx3, "b") == big


def test_buffer_spill_abort_cleans_up():
    storage = MemoryStorage()
    node = AftNode(storage, AftNodeConfig(write_buffer_max_bytes=64))
    tx = node.start_transaction()
    node.put(tx, "a", b"x" * 100)
    node.abort_transaction(tx)
    assert storage.list_keys(DATA_PREFIX) == []
