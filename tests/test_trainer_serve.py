"""End-to-end fault tolerance: trainer crash/resume exactly-once, serving
weight refresh atomicity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AftCheckpointer
from repro.core import AftCluster
from repro.models import Model, get_config
from repro.serve import ServeConfig, ServeEngine
from repro.storage.memory import MemoryStorage
from repro.train import get_optimizer
from repro.train.data import data_for_model
from repro.train.loop import CrashInjected, Trainer, TrainerConfig


@pytest.fixture()
def setup():
    cfg = get_config("tinyllama-1.1b").reduced(pattern_repeats=2)
    model = Model(cfg)
    data = data_for_model(cfg, global_batch=4, seq_len=32)
    cluster = AftCluster(MemoryStorage())
    yield cfg, model, data, cluster
    cluster.stop()


def _trainer(model, data, ck, **kw):
    return Trainer(model, get_optimizer("adamw", lr=1e-2), data, ck,
                   TrainerConfig(**kw))


def test_crash_resume_exactly_once(setup):
    cfg, model, data, cluster = setup
    ck = AftCheckpointer(cluster.client(), run_id="r1")

    # uninterrupted reference run
    ck_ref = AftCheckpointer(cluster.client(), run_id="ref")
    t_ref = _trainer(model, data, ck_ref, total_steps=20, ckpt_every=5,
                     log_every=5)
    ref_hist = t_ref.run()

    # crash after step 11, restart, finish
    t1 = _trainer(model, data, ck, total_steps=20, ckpt_every=5, log_every=5,
                  crash_after_step=11)
    with pytest.raises(CrashInjected):
        t1.run()
    assert ck.latest_step() == 9  # last committed boundary
    t2 = _trainer(model, data, ck, total_steps=20, ckpt_every=5, log_every=5)
    hist = t2.run()
    assert hist[0]["step"] == 10
    # exactly-once: final loss identical to the uninterrupted run
    assert hist[-1]["loss"] == ref_hist[-1]["loss"]


def test_crash_during_save_leaves_no_torn_state(setup):
    cfg, model, data, cluster = setup
    ck = AftCheckpointer(cluster.client(), run_id="r2")
    t1 = _trainer(model, data, ck, total_steps=20, ckpt_every=5, log_every=5,
                  crash_after_step=14, crash_during_save=True)
    with pytest.raises(CrashInjected):
        t1.run()
    assert ck.latest_step() == 9   # step-14 save aborted atomically
    t2 = _trainer(model, data, ck, total_steps=20, ckpt_every=5, log_every=5)
    hist = t2.run()
    assert hist[0]["step"] == 10 and hist[-1]["step"] == 19


def test_serve_refresh_and_generate(setup):
    cfg, model, data, cluster = setup
    ck = AftCheckpointer(cluster.client(), run_id="r3")
    _trainer(model, data, ck, total_steps=6, ckpt_every=3, log_every=3).run()
    eng = ServeEngine(model, AftCheckpointer(cluster.client(), run_id="r3"),
                      ServeConfig(max_len=64))
    assert eng.refresh_weights()
    assert eng.weights_step == 5
    out = eng.generate([[1, 2, 3, 4], [5, 6, 7, 8]], max_new=5)
    assert len(out) == 2 and all(len(o) == 5 for o in out)
    assert not eng.refresh_weights()  # idempotent when no newer ckpt

    # trainer commits more steps → refresh picks them up atomically
    _trainer(model, data, ck, total_steps=12, ckpt_every=3, log_every=3).run()
    assert eng.refresh_weights()
    assert eng.weights_step == 11


def test_elastic_restore_different_layout(setup):
    """Checkpoints store full leaves: restore works with a different
    device layout / donated buffers (elastic resume)."""
    cfg, model, data, cluster = setup
    ck = AftCheckpointer(cluster.client(), run_id="r4")
    _trainer(model, data, ck, total_steps=4, ckpt_every=2, log_every=2).run()
    like, _ = _trainer(model, data, None, total_steps=1,
                       ckpt_every=1).init_state()
    step, tree, extra = ck.restore(like=like)
    assert step == 3 and extra["next_step"] == 4
    # leaves come back as host arrays, shardable onto any mesh
    leaf = jax.tree.leaves(tree)[0]
    assert isinstance(np.asarray(leaf), np.ndarray)
