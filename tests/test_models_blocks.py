"""Block-level numerical validation against oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config
from repro.models.moe import moe_defs, moe_ffn, moe_reference
from repro.models.params import initialize
from repro.models.ssm import ssd_chunked, ssd_reference
from repro.models.xlstm import (mlstm_chunked, mlstm_decode_step,
                                mlstm_reference)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mlstm_chunked_matches_sequential(chunk):
    B, S, H, P = 2, 64, 3, 8
    ks = jax.random.split(jax.random.key(0), 5)
    q = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    ir = jax.random.normal(ks[3], (B, S, H)) * 2
    fr = jax.random.normal(ks[4], (B, S, H)) * 2 + 1
    out, _ = mlstm_chunked(q, k, v, ir, fr, chunk)
    ref = mlstm_reference(q, k, v, ir, fr)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_mlstm_state_handoff_prefill_to_decode():
    """Chunked prefill state continues exactly into single-token steps."""
    B, S, H, P = 1, 64, 2, 8
    ks = jax.random.split(jax.random.key(1), 5)
    q = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    ir = jax.random.normal(ks[3], (B, S, H))
    fr = jax.random.normal(ks[4], (B, S, H)) + 1
    ref = mlstm_reference(q, k, v, ir, fr)
    out1, st = mlstm_chunked(q[:, :48], k[:, :48], v[:, :48],
                             ir[:, :48], fr[:, :48], 16)
    outs = [out1]
    c, n, m = st
    for t in range(48, 64):
        o, (c, n, m) = mlstm_chunked(q[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                                     ir[:, t:t+1], fr[:, t:t+1], 1, (c, n, m))
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, axis=1), ref,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [8, 64])
def test_ssd_chunked_matches_quadratic(chunk):
    B, S, H, P, N = 2, 64, 3, 8, 8
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(ks[4], (B, S, N))
    y, _ = ssd_chunked(x, dt, a, bm, cm, chunk)
    ref = ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(y, ref, rtol=2e-3, atol=2e-3)


def test_moe_exact_at_high_capacity():
    """Gather-dispatch MoE == dense-masked oracle when nothing overflows."""
    cfg = get_config("kimi-k2-1t-a32b").reduced(capacity_factor=8.0)
    params = initialize(jax.random.key(3), moe_defs(cfg))
    x = jax.random.normal(jax.random.key(4), (2, 16, cfg.d_model))
    out, aux = moe_ffn(params, x, cfg)
    ref = moe_reference(params, x, cfg)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
    assert 0.5 < float(aux) < 4.0  # aux loss near 1 for near-uniform routing


def test_moe_grouped_dispatch_matches_reference():
    """Per-group (EP-aligned) dispatch == dense oracle at high capacity."""
    import dataclasses

    cfg = get_config("kimi-k2-1t-a32b").reduced(capacity_factor=8.0)
    cfg_g = dataclasses.replace(cfg, moe_dispatch_groups=4)
    params = initialize(jax.random.key(3), moe_defs(cfg))
    x = jax.random.normal(jax.random.key(4), (2, 16, cfg.d_model))
    ref = moe_reference(params, x, cfg)
    out, aux = moe_ffn(params, x, cfg_g)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
    out0, aux0 = moe_ffn(params, x, cfg)
    np.testing.assert_allclose(float(aux), float(aux0), rtol=1e-5)


def test_kv_cache_int8_roundtrip():
    from repro.models.layers import kv_dequantize, kv_quantize

    x = jax.random.normal(jax.random.key(0), (2, 7, 3, 16)) * 5.0
    q, s = kv_quantize(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 7, 3)
    back = kv_dequantize(q, s, jnp.float32)
    np.testing.assert_allclose(back, x, atol=float(jnp.abs(x).max()) / 100)


def test_moe_capacity_drop_is_bounded():
    """At cf=1.0 some tokens drop, but output stays finite and close-ish."""
    cfg = get_config("kimi-k2-1t-a32b").reduced(capacity_factor=1.0)
    params = initialize(jax.random.key(5), moe_defs(cfg))
    x = jax.random.normal(jax.random.key(6), (2, 32, cfg.d_model))
    out, _ = moe_ffn(params, x, cfg)
    assert bool(jnp.isfinite(out).all())
