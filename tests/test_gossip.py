"""TPU-native commit-digest plane (core/gossip.py)."""

import numpy as np
import pytest

from repro.core import AftCluster, ClusterConfig
from repro.core.gossip import (METRICS_PREFIX, DigestPlane, MetricsPlane,
                               _hash64, exchange_digests, pack_digest,
                               unpack_digest)
from repro.core.ids import TxnId
from repro.storage.memory import MemoryStorage


try:  # the property test needs hypothesis; the rest of the module doesn't
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None


@pytest.mark.skipif(st is None, reason="hypothesis not installed")
def test_digest_roundtrip():
    @given(st.lists(st.tuples(st.integers(0, 2**62),
                              st.text(min_size=1, max_size=24)),
                    min_size=0, max_size=16, unique_by=lambda t: t))
    @settings(max_examples=50, deadline=None)
    def prop(items):
        tids = [TxnId(ts, u) for ts, u in items]
        rows = pack_digest(tids, 16)
        got = set(unpack_digest(rows))
        want = {(t.timestamp, _hash64(t.encode())) for t in tids}
        # pack keeps the newest ≤16; with ≤16 inputs nothing drops
        assert got == want or (len(items) == 0 and not got)

    prop()


def test_exchange_degenerate_single_device():
    d = np.arange(2 * 4 * 4, dtype=np.int32).reshape(2, 4, 4)
    out = exchange_digests(d)
    np.testing.assert_array_equal(out, d)


def test_plane_propagates_commits():
    cluster = AftCluster(MemoryStorage(), ClusterConfig(num_nodes=3))
    try:
        nodes = cluster.live_nodes()
        plane = DigestPlane(nodes, cluster.storage)
        txid = nodes[0].start_transaction()
        nodes[0].put(txid, "k", b"v1")
        nodes[0].put(txid, "l", b"v2")
        nodes[0].commit_transaction(txid)
        # invisible elsewhere before the round
        t = nodes[1].start_transaction()
        assert nodes[1].get(t, "k") is None
        nodes[1].abort_transaction(t)
        merged = plane.step()
        assert merged >= 2
        t = nodes[2].start_transaction()
        assert nodes[2].get(t, "k") == b"v1"
        assert nodes[2].get(t, "l") == b"v2"
        nodes[2].abort_transaction(t)
    finally:
        cluster.stop()


def test_plane_prunes_superseded():
    cluster = AftCluster(MemoryStorage(), ClusterConfig(num_nodes=2))
    try:
        nodes = cluster.live_nodes()
        plane = DigestPlane(nodes, cluster.storage)
        for i in range(3):  # same key thrice: first two become superseded
            txid = nodes[0].start_transaction()
            nodes[0].put(txid, "hot", f"v{i}".encode())
            nodes[0].commit_transaction(txid)
        plane.step()
        assert plane.stats["pruned"] >= 1
        t = nodes[1].start_transaction()
        assert nodes[1].get(t, "hot") == b"v2"
        nodes[1].abort_transaction(t)
    finally:
        cluster.stop()


# ---------------------------------------------------------------------------
# metrics plane: gossip-fed registry snapshots → fault-manager merged view
# ---------------------------------------------------------------------------

def test_metrics_plane_feeds_fault_manager_merged_view():
    cluster = AftCluster(MemoryStorage(), ClusterConfig(num_nodes=3))
    try:
        nodes = cluster.live_nodes()
        txid = nodes[0].start_transaction()
        nodes[0].put(txid, "k", b"v")
        nodes[0].commit_transaction(txid)

        fm = cluster.fault_manager
        plane = MetricsPlane(nodes, cluster.storage, fault_manager=fm)
        assert plane.step() == len(nodes)
        # every node's snapshot blob landed under the reserved m/ prefix
        for node in nodes:
            assert cluster.storage.get(f"{METRICS_PREFIX}{node.node_id}")
        assert set(plane.views) == {n.node_id for n in nodes}

        merged = fm.cluster_metrics()
        assert set(merged["nodes"]) == {n.node_id for n in nodes}
        # counters sum across nodes; histogram summaries merge
        assert merged["cluster"]["commits"] == 1
        assert merged["cluster"]["commit.total"]["count"] == 1
    finally:
        cluster.stop()


def test_metrics_plane_rounds_refresh_the_view():
    cluster = AftCluster(MemoryStorage(), ClusterConfig(num_nodes=2))
    try:
        nodes = cluster.live_nodes()
        fm = cluster.fault_manager
        plane = MetricsPlane(nodes, cluster.storage, fault_manager=fm)
        plane.step()
        assert fm.cluster_metrics()["cluster"].get("commits", 0) == 0
        for node in nodes:  # one commit per node between rounds
            txid = node.start_transaction()
            node.put(txid, f"k/{node.node_id}", b"v")
            node.commit_transaction(txid)
        plane.step()
        assert fm.cluster_metrics()["cluster"]["commits"] == 2
        assert plane.stats["rounds"] == 2
        assert plane.stats["hash_mismatches"] == 0
    finally:
        cluster.stop()


def test_metrics_plane_skips_dead_nodes():
    cluster = AftCluster(MemoryStorage(), ClusterConfig(num_nodes=2))
    try:
        nodes = cluster.live_nodes()
        nodes[1].fail()
        plane = MetricsPlane(nodes, cluster.storage)
        assert plane.step() == 1  # the dead node contributes a zero row
        assert set(plane.views) == {nodes[0].node_id}
    finally:
        cluster.stop()
