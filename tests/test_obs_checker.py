"""Offline trace-replay invariant checker (repro/obs/checker.py).

Synthetic event-log fixtures for each invariant, the acceptance-criteria
negative test (the seeded read-atomicity violation MUST be flagged), and
the CLI entry point's exit codes."""

import json

import pytest

from repro.obs.checker import (
    SEED_KINDS,
    check_events,
    check_file,
    main,
    seeded_violation_events,
)


def tid(ts: int, uuid: str) -> str:
    return f"{ts:020d}.{uuid}"


def clean_commit(uuid: str, seq0: int, writes: int = 1):
    """versions → record → visible, the §3.3 order."""
    return [
        {"seq": seq0, "ev": "order", "uuid": uuid, "stage": "versions"},
        {"seq": seq0 + 1, "ev": "order", "uuid": uuid, "stage": "record",
         "writes": writes},
        {"seq": seq0 + 2, "ev": "order", "uuid": uuid, "stage": "visible",
         "tid": tid(1000, uuid)},
    ]


# ---------------------------------------------------------------------------
# clean traces score clean
# ---------------------------------------------------------------------------

def test_clean_synthetic_trace_has_zero_violations():
    t1 = tid(2000, "bbbb")
    events = (
        clean_commit("aaaa", 1) + clean_commit("bbbb", 10, writes=2)
        # an atomic observation: both keys from the SAME cowriting txn
        + [
            {"seq": 20, "ev": "read", "txn": "r1", "key": "x", "tid": t1,
             "cow": ["x", "y"]},
            {"seq": 21, "ev": "read", "txn": "r1", "key": "y", "tid": t1,
             "cow": ["x", "y"]},
        ]
        + [
            {"seq": 30, "ev": "wf_finished", "uuid": "wf-1",
             "tid": t1, "deduped": False},
            {"seq": 31, "ev": "span", "span": "t/wf#1"},
            {"seq": 32, "ev": "span", "span": "t/wf#2"},
        ]
    )
    res = check_events(events)
    assert res.ok, res.summary()
    assert res.commits_checked == 2
    assert res.txns_checked == 1
    assert res.finishes_checked == 1
    assert res.spans_checked == 2


def test_null_reads_are_not_fractures():
    """A key read as NULL alongside a cowriting sibling mirrors Algorithm
    1's dynamic read set — legitimate, not a violation."""
    t1 = tid(2000, "bbbb")
    events = [
        {"seq": 1, "ev": "read", "txn": "r1", "key": "x", "tid": t1,
         "cow": ["x", "y"]},
        {"seq": 2, "ev": "read", "txn": "r1", "key": "y", "tid": None},
    ]
    assert check_events(events).ok


def test_newer_sibling_read_is_atomic():
    """Reading l at j > i (a NEWER version than the cowriter wrote)
    satisfies Definition 1 — only j < i fractures.  y's own writer must
    not cowrite x, else the x@t0 read would fracture from y's side."""
    events = [
        {"seq": 1, "ev": "read", "txn": "r1", "key": "x",
         "tid": tid(1000, "aaaa"), "cow": ["x", "y"]},
        {"seq": 2, "ev": "read", "txn": "r1", "key": "y",
         "tid": tid(2000, "bbbb"), "cow": ["y"]},
    ]
    assert check_events(events).ok


# ---------------------------------------------------------------------------
# each invariant's violation fixture
# ---------------------------------------------------------------------------

def test_seeded_read_atomicity_violation_is_flagged():
    """Acceptance criterion: the checker MUST flag the seeded violation."""
    res = check_events(seeded_violation_events())
    assert not res.ok
    assert [v.invariant for v in res.violations] == ["read-atomicity"]
    assert "reader" in res.violations[0].detail


def test_fractured_read_detected_regardless_of_read_order():
    """The fracture is caught whether the stale or the fresh read lands
    first (the witness scan is incremental but order-insensitive)."""
    t0, t1 = tid(1000, "aaaa"), tid(2000, "bbbb")
    fresh_then_stale = [
        {"seq": 1, "ev": "read", "txn": "r", "key": "y", "tid": t1,
         "cow": ["x", "y"]},
        {"seq": 2, "ev": "read", "txn": "r", "key": "x", "tid": t0,
         "cow": ["x"]},
    ]
    res = check_events(fresh_then_stale)
    assert [v.invariant for v in res.violations] == ["read-atomicity"]


def test_one_stale_read_counts_once():
    """The offending read is dropped after its first witness, so later
    reads of the same transaction do not re-count it."""
    t0, t1 = tid(1000, "aaaa"), tid(2000, "bbbb")
    events = seeded_violation_events() + [
        {"seq": 6, "ev": "read", "txn": "reader", "key": "z", "tid": t1,
         "cow": ["z"]},
    ]
    res = check_events(events)
    assert len(res.violations) == 1


def test_write_ordering_record_before_version_flush():
    events = [
        {"seq": 1, "ev": "order", "uuid": "u", "stage": "record", "writes": 3},
        {"seq": 2, "ev": "order", "uuid": "u", "stage": "versions"},
        {"seq": 3, "ev": "order", "uuid": "u", "stage": "visible"},
    ]
    res = check_events(events)
    assert [v.invariant for v in res.violations] == ["write-ordering"]
    assert "no prior version flush" in res.violations[0].detail


def test_write_ordering_visible_before_record():
    events = [
        {"seq": 1, "ev": "order", "uuid": "u", "stage": "versions"},
        {"seq": 2, "ev": "order", "uuid": "u", "stage": "visible"},
        {"seq": 3, "ev": "order", "uuid": "u", "stage": "record", "writes": 1},
    ]
    res = check_events(events)
    assert [v.invariant for v in res.violations] == ["write-ordering"]
    assert "before any commit-record write" in res.violations[0].detail


def test_write_ordering_zero_write_record_needs_no_version_flush():
    """A read-only (or trigger-only) commit writes no versions; its record
    landing first is legal."""
    events = [
        {"seq": 1, "ev": "order", "uuid": "u", "stage": "record", "writes": 0},
        {"seq": 2, "ev": "order", "uuid": "u", "stage": "visible"},
    ]
    assert check_events(events).ok


def test_exactly_once_flags_two_tids_for_one_uuid():
    events = [
        {"seq": 1, "ev": "wf_finished", "uuid": "wf-1",
         "tid": tid(1000, "aaaa"), "deduped": False},
        {"seq": 2, "ev": "wf_finished", "uuid": "wf-1",
         "tid": tid(2000, "bbbb"), "deduped": False},
    ]
    res = check_events(events)
    assert [v.invariant for v in res.violations] == ["exactly-once"]


def test_exactly_once_allows_deduped_refinishes():
    """A replayed finish marked deduped (resolved from the finish marker)
    does not count against the single-TID rule."""
    events = [
        {"seq": 1, "ev": "wf_finished", "uuid": "wf-1",
         "tid": tid(1000, "aaaa"), "deduped": False},
        {"seq": 2, "ev": "wf_finished", "uuid": "wf-1",
         "tid": tid(2000, "bbbb"), "deduped": True},
        {"seq": 3, "ev": "wf_finished", "uuid": "wf-1",
         "tid": tid(1000, "aaaa"), "deduped": False},
    ]
    assert check_events(events).ok


def test_duplicate_span_ids_are_flagged():
    events = [
        {"seq": 1, "ev": "span", "span": "t/step:a#1"},
        {"seq": 2, "ev": "span", "span": "t/step:a#1"},
    ]
    res = check_events(events)
    assert [v.invariant for v in res.violations] == ["span-unique"]


# ---------------------------------------------------------------------------
# read durability (gossip-fed fast path)
# ---------------------------------------------------------------------------

def test_read_durability_seed_is_flagged():
    res = check_events(seeded_violation_events("read-durability"))
    assert [v.invariant for v in res.violations] == ["read-durability"]
    assert "before its commit record landed" in res.violations[0].detail


def test_read_after_record_is_durable():
    """The same shape with the read sequenced AFTER the record is clean."""
    t = tid(1500, "cccc")
    events = [
        {"seq": 1, "ev": "order", "uuid": "cccc", "stage": "versions"},
        {"seq": 2, "ev": "order", "uuid": "cccc", "stage": "record",
         "writes": 1},
        {"seq": 3, "ev": "order", "uuid": "cccc", "stage": "visible"},
        {"seq": 4, "ev": "read", "txn": "reader", "key": "x", "tid": t,
         "cow": ["x"]},
    ]
    assert check_events(events).ok


def test_read_durability_skips_unobserved_commits():
    """A read resolving to a txn with no order events in the trace (it
    committed before tracing started) is skipped, not flagged."""
    events = [
        {"seq": 1, "ev": "read", "txn": "reader", "key": "x",
         "tid": tid(1500, "pre-trace"), "cow": ["x"]},
    ]
    assert check_events(events).ok


# ---------------------------------------------------------------------------
# bounded-staleness snapshot reads
# ---------------------------------------------------------------------------

def snap_commit(uuid: str, ts: int, seq0: int, keys):
    """A §3.3-ordered commit whose record carries snapshot metadata."""
    return [
        {"seq": seq0, "ev": "order", "uuid": uuid, "stage": "versions"},
        {"seq": seq0 + 1, "ev": "order", "uuid": uuid, "stage": "record",
         "writes": len(keys), "tid": tid(ts, uuid), "keys": list(keys)},
        {"seq": seq0 + 2, "ev": "order", "uuid": uuid, "stage": "visible"},
    ]


def test_clean_snapshot_read_scores_clean():
    """Returning the newest version at/below the watermark, within bound."""
    events = snap_commit("aaaa", 1000, 1, ["x"]) + snap_commit(
        "bbbb", 2000, 4, ["x"]) + [
        {"seq": 7, "ev": "snap", "key": "x", "tid": tid(2000, "bbbb"),
         "wm": 2500, "lag_ns": 10, "bound_ns": 1000},
    ]
    res = check_events(events)
    assert res.ok, res.summary()
    assert res.snaps_checked == 1


def test_snapshot_missed_covered_version_is_flagged():
    res = check_events(seeded_violation_events("snapshot-bound"))
    assert [v.invariant for v in res.violations] == ["snapshot-bound"]
    assert "covered version was missed" in res.violations[0].detail


def test_snapshot_null_return_misses_covered_version():
    events = snap_commit("aaaa", 1000, 1, ["x"]) + [
        {"seq": 4, "ev": "snap", "key": "x", "tid": None,
         "wm": 1500, "lag_ns": 0, "bound_ns": 1000},
    ]
    res = check_events(events)
    assert [v.invariant for v in res.violations] == ["snapshot-bound"]


def test_snapshot_lag_beyond_bound_is_flagged():
    events = [
        {"seq": 1, "ev": "snap", "key": "x", "tid": None,
         "wm": 100, "lag_ns": 5000, "bound_ns": 1000},
    ]
    res = check_events(events)
    assert [v.invariant for v in res.violations] == ["snapshot-bound"]
    assert "beyond its declared staleness bound" in res.violations[0].detail


def test_snapshot_version_above_watermark_is_flagged():
    events = snap_commit("bbbb", 2000, 1, ["x"]) + [
        {"seq": 4, "ev": "snap", "key": "x", "tid": tid(2000, "bbbb"),
         "wm": 1500, "lag_ns": 0, "bound_ns": 1000},
    ]
    res = check_events(events)
    assert [v.invariant for v in res.violations] == ["snapshot-bound"]
    assert "above its watermark" in res.violations[0].detail


def test_snapshot_version_after_read_not_required():
    """A version committed ABOVE the watermark (or recorded after the
    read) cannot be demanded of the snapshot."""
    events = snap_commit("aaaa", 1000, 1, ["x"]) + [
        {"seq": 4, "ev": "snap", "key": "x", "tid": tid(1000, "aaaa"),
         "wm": 1500, "lag_ns": 0, "bound_ns": 1000},
    ] + snap_commit("bbbb", 1200, 5, ["x"])  # record AFTER the snap read
    assert check_events(events).ok


def test_old_traces_without_record_metadata_skip_snapshot_check():
    """Records lacking tid/keys (pre-fast-path traces) cannot feed the
    missed-version check — the snap event alone stays clean."""
    events = clean_commit("aaaa", 1) + [
        {"seq": 4, "ev": "snap", "key": "x", "tid": None,
         "wm": 99999, "lag_ns": 0, "bound_ns": 1000},
    ]
    assert check_events(events).ok


# ---------------------------------------------------------------------------
# file + CLI round trip
# ---------------------------------------------------------------------------

def _write_jsonl(path, events) -> str:
    path.write_text("".join(json.dumps(e) + "\n" for e in events))
    return str(path)


def test_check_file_round_trips(tmp_path):
    clean = _write_jsonl(tmp_path / "clean.jsonl", clean_commit("u", 1))
    bad = _write_jsonl(tmp_path / "bad.jsonl", seeded_violation_events())
    assert check_file(clean).ok
    assert not check_file(bad).ok


def test_cli_exit_codes(tmp_path, capsys):
    clean = _write_jsonl(tmp_path / "clean.jsonl", clean_commit("u", 1))
    bad = _write_jsonl(tmp_path / "bad.jsonl", seeded_violation_events())
    assert main([clean]) == 0
    assert main([bad]) == 1
    assert main(["--selftest"]) == 0
    out = capsys.readouterr().out
    assert "seeded violation detected" in out
    assert "violations:            1" in out


def test_cli_selftest_covers_all_seed_kinds(capsys):
    assert main(["--selftest"]) == 0
    out = capsys.readouterr().out
    assert out.count("seeded violation detected") == len(SEED_KINDS)
    for kind in SEED_KINDS:
        assert f"-- seed: {kind}" in out


def test_unknown_seed_kind_raises():
    with pytest.raises(ValueError):
        seeded_violation_events("no-such-invariant")


def test_cli_requires_a_trace_or_selftest():
    with pytest.raises(SystemExit):
        main([])
