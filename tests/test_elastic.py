"""Elastic cluster membership: lifecycle, warm-up handoff, weight-aware
ring, hot-arc splitting, autoscaler policy, and the drain-not-kill /
marker-ack regressions (ISSUE 9).
"""

import json
import time

import pytest

from repro.core import (
    AftCluster,
    AftNodeConfig,
    Autoscaler,
    AutoscalerConfig,
    CacheAwareRouter,
    ClusterConfig,
    ConsistentHashRouter,
    NodeLifecycle,
    PlacementHint,
)
from repro.core.fault_manager import FaultManagerConfig
from repro.core.records import workflow_finish_key
from repro.storage import MemoryStorage


def make_cluster(n=2, routing=None, **kw):
    cfg = ClusterConfig(
        num_nodes=n,
        node=AftNodeConfig(),
        start_background_threads=False,
        routing=routing,
        **kw,
    )
    return AftCluster(MemoryStorage(), cfg)


# --------------------------------------------------------------- lifecycle
def test_join_ramps_to_live():
    cluster = make_cluster(2, routing="consistent_hash")
    joiner = cluster.join_node(ramp=True)
    assert cluster.lifecycle_of(joiner) is NodeLifecycle.JOINING
    assert cluster.router.weight_of(joiner.node_id) == pytest.approx(0.25)
    # a JOINING node is already a bus peer and routable
    assert joiner.node_id in cluster.live_node_ids()
    assert joiner in cluster.routable_nodes()
    for _ in range(4):
        cluster.advance_lifecycle()
    assert cluster.lifecycle_of(joiner) is NodeLifecycle.LIVE
    assert cluster.router.weight_of(joiner.node_id) == pytest.approx(1.0)
    cluster.stop()


def test_drain_is_graceful_not_kill():
    cluster = make_cluster(3)
    victim = cluster.live_nodes()[-1]
    cluster.drain_node(victim, wait=True)
    # THE satellite-3 bugfix contract: retirement never reuses the kill
    # path — the node was never failed, its pipeline flushed shut
    assert victim.alive, "drain must not kill the node"
    assert cluster.lifecycle_of(victim) is NodeLifecycle.RETIRED
    assert victim.node_id not in cluster.live_node_ids()
    assert victim.node_id not in cluster.agents
    cluster.stop()


def test_draining_node_takes_no_new_sessions_but_finishes_inflight():
    cluster = make_cluster(2)
    victim = cluster.live_nodes()[-1]
    tx = victim.start_transaction()
    victim.put(tx, "k", b"v")
    cluster.drain_node(victim, wait=False)
    assert cluster.lifecycle_of(victim) is NodeLifecycle.DRAINING
    # no NEW sessions route there, under the weightless default policy too
    for _ in range(8):
        assert cluster.pick_node() is not victim
    # still a member: in-flight work finishes and commits announce
    tid = victim.commit_transaction(tx)
    assert tid is not None
    victim.release_transaction(tx)
    cluster.advance_lifecycle()  # now idle → retired
    assert cluster.lifecycle_of(victim) is NodeLifecycle.RETIRED
    # the drained commit is durably visible to the survivors
    survivor = cluster.live_nodes()[0]
    cluster.step_all()
    tx2 = survivor.start_transaction()
    assert survivor.get(tx2, "k") == b"v"
    survivor.commit_transaction(tx2)
    cluster.stop()


def test_scale_to_drains_on_shrink():
    cluster = make_cluster(3)
    victims = cluster.live_nodes()[1:]
    cluster.scale_to(1)
    assert len(cluster.live_nodes()) == 1
    for v in victims:
        assert v.alive, "scale-down must drain, never kill"
        assert cluster.lifecycle_of(v) is NodeLifecycle.RETIRED
    cluster.scale_to(3)
    assert len(cluster.live_nodes()) == 3
    cluster.stop()


def test_membership_listener_sees_transitions():
    cluster = make_cluster(1)
    events = []
    cluster.add_membership_listener(
        lambda ev, node: events.append((ev, node.node_id))
    )
    joiner = cluster.join_node(ramp=True)
    for _ in range(4):
        cluster.advance_lifecycle()
    cluster.drain_node(joiner, wait=True)
    kinds = [ev for ev, _ in events]
    assert kinds == ["join", "live", "draining", "retired"]
    cluster.stop()


# ------------------------------------------------------------------ handoff
def test_warmup_handoff_streams_commit_metadata():
    cluster = make_cluster(1)
    donor = cluster.live_nodes()[0]
    uuids = []
    for i in range(5):
        tx = donor.start_transaction()
        donor.put(tx, f"h{i}", str(i).encode())
        donor.commit_transaction(tx)
        uuids.append(tx)
        donor.release_transaction(tx)
    joiner = cluster.join_node(ramp=True)
    # weightless policy: the donor streams its records wholesale
    assert joiner.stats["warmup_records_in"] >= 5
    assert donor.stats["handoff_records_out"] >= 5
    # the u/ idempotence metadata arrived with the commit-set records: a
    # retried uuid resolves locally, no storage scan
    for u in uuids:
        assert joiner.committed_tid_for_uuid(u) is not None
    cluster.stop()


def test_warmup_handoff_ring_scoped():
    cluster = make_cluster(2, routing="consistent_hash")
    donors = cluster.live_nodes()
    for i in range(40):
        node = cluster.pick_node(PlacementHint(keys=(f"rk{i}",)))
        tx = node.start_transaction()
        node.put(tx, f"rk{i}", b"x")
        node.commit_transaction(tx)
        node.release_transaction(tx)
    joiner = cluster.join_node(ramp=True)
    # a ring policy hands off only keys the joiner now owns — a strict
    # subset of the donors' records
    total = sum(d.stats["handoff_records_out"] for d in donors)
    assert total <= 40
    assert joiner.stats["warmup_records_in"] == total
    cluster.stop()


# --------------------------------------------------------- weight-aware ring
class _StubNode:
    def __init__(self, node_id):
        self.node_id = node_id
        self.alive = True


def _share(router, nodes, node_id, n=400):
    owned = sum(
        1 for i in range(n) if router.owner_id(f"key-{i}") == node_id
    )
    return owned / n


def test_ring_weight_scales_key_share():
    nodes = [_StubNode(f"n{i}") for i in range(3)]
    router = ConsistentHashRouter(vnodes=64)
    router.sync(nodes)
    base = _share(router, nodes, "n2")
    router.set_weight("n2", 0.25)
    low = _share(router, nodes, "n2")
    assert low < base
    router.set_weight("n2", 0.0)
    assert _share(router, nodes, "n2") == 0.0
    # weight 0 removes arcs but keeps membership (no self-heal thrash)
    assert router.owner_id("key-1") in ("n0", "n1")
    router.set_weight("n2", 1.0)
    assert _share(router, nodes, "n2") == pytest.approx(base)


def test_ring_forget_node_drops_residue():
    nodes = [_StubNode(f"n{i}") for i in range(2)]
    router = ConsistentHashRouter(vnodes=16)
    router.sync(nodes)
    router.set_weight("n1", 0.5)
    router.forget_node("n1")
    assert router.weight_of("n1") == 1.0  # residue gone → default
    assert _share(router, nodes, "n1") == 0.0


def test_hot_arc_split_moves_half_the_arc():
    nodes = [_StubNode("n0"), _StubNode("n1")]
    router = ConsistentHashRouter(vnodes=8)
    router.sync(nodes)
    # hammer one key so its arc runs hot
    hot_key = "hot-key"
    for _ in range(50):
        router.route(nodes, PlacementHint(keys=(hot_key,)))
    owner_before = router.owner_id(hot_key)
    hot = router.hottest_arc()
    assert hot is not None
    arc_hash, owner, load, mean = hot
    assert owner == owner_before and load >= 50
    target = "n1" if owner == "n0" else "n0"
    points_before = len(router._hashes)
    assert router.split_hot_arc(target, min_ratio=2.0)
    # the midpoint virtual point exists, owned by the target: the hot
    # arc's lower half moved without disturbing any other arc
    assert len(router._hashes) == points_before + 1
    assert target in router._splits.values()


def test_split_survives_resync_until_target_leaves():
    nodes = [_StubNode("n0"), _StubNode("n1")]
    router = ConsistentHashRouter(vnodes=8)
    router.sync(nodes)
    for _ in range(20):
        router.route(nodes, PlacementHint(keys=("k",)))
    hot = router.hottest_arc()
    target = "n1" if hot[1] == "n0" else "n0"
    assert router.split_arc(hot[0], target)
    n_points = len(router._hashes)
    router.sync(nodes)  # plain resync keeps the split point
    assert len(router._hashes) == n_points
    router.forget_node(target)  # target retires → split point dropped
    assert all(nid != target for nid in router._ring_ids)


# ------------------------------------------------------------- cache-aware
def test_cache_aware_router_reads_registry_not_stats(recwarn):
    cluster = make_cluster(2, routing="cache_aware")
    assert isinstance(cluster.router, CacheAwareRouter)
    for i in range(6):
        node = cluster.pick_node(PlacementHint(uuid=f"u{i}", keys=(f"k{i}",)))
        tx = node.start_transaction()
        node.put(tx, f"k{i}", b"v")
        node.commit_transaction(tx)
        node.release_transaction(tx)
    deprecations = [
        w for w in recwarn.list if issubclass(w.category, DeprecationWarning)
        and "stats" in str(w.message)
    ]
    assert deprecations == [], "router must not touch the stats() shim"
    cluster.stop()


# ---------------------------------------------------------------- GC acks
def test_marker_sweep_ignores_draining_and_retired_nodes():
    cluster = make_cluster(
        3, fault_manager=FaultManagerConfig(workflow_marker_ttl_s=0.0)
    )
    fm = cluster.fault_manager
    wf = "wf-elastic-1"
    cluster.storage.put(
        workflow_finish_key(wf),
        json.dumps({"finished_at_ns": time.time_ns() - 10**9}).encode(),
    )
    nodes = cluster.live_nodes()
    # only the nodes that will STAY acked; the third is mid-drain and its
    # GC agent never acks — historically this stalled the sweep forever
    nodes[0].ack_workflow_marker(wf)
    nodes[1].ack_workflow_marker(wf)
    cluster.drain_node(nodes[2], wait=False)
    retired = fm.sweep_finished_markers()
    assert retired == 1
    cluster.stop()


def test_marker_sweep_still_requires_live_member_acks():
    cluster = make_cluster(
        2, fault_manager=FaultManagerConfig(workflow_marker_ttl_s=0.0)
    )
    fm = cluster.fault_manager
    wf = "wf-elastic-2"
    cluster.storage.put(
        workflow_finish_key(wf),
        json.dumps({"finished_at_ns": time.time_ns() - 10**9}).encode(),
    )
    cluster.live_nodes()[0].ack_workflow_marker(wf)
    # the second LIVE node has not acked: the marker must survive
    assert fm.sweep_finished_markers() == 0
    cluster.stop()


# --------------------------------------------------------------- autoscaler
def _autoscaler(cluster, **kw):
    cfg = AutoscalerConfig(
        min_nodes=1, max_nodes=3, scale_up_load=1.5, scale_down_load=0.25,
        up_ticks=2, down_ticks=2, up_cooldown_s=0.0, down_cooldown_s=0.0,
        **kw,
    )
    return Autoscaler(cluster, cluster.fault_manager, cfg)


def test_autoscaler_scales_up_on_load_then_down_when_idle():
    cluster = make_cluster(1)
    scaler = _autoscaler(cluster)
    node = cluster.live_nodes()[0]
    txs = [node.start_transaction() for _ in range(4)]  # open_sessions=4
    decisions = [scaler.step() for _ in range(3)]
    assert "scale-up" in decisions
    assert len(cluster.live_nodes()) == 2
    joiner = cluster.live_nodes()[-1]
    # the joiner ramps through JOINING; decisions pause while it migrates
    while cluster.lifecycle_of(joiner) is NodeLifecycle.JOINING:
        scaler.step()
    assert cluster.lifecycle_of(joiner) is NodeLifecycle.LIVE
    for tx in txs:
        node.abort_transaction(tx)
        node.release_transaction(tx)
    for _ in range(8):
        scaler.step()
        if len(cluster.live_nodes()) == 1:
            break
    assert len(cluster.live_nodes()) == 1
    kinds = [e["event"] for e in scaler.events]
    assert "scale-up" in kinds and "scale-down" in kinds
    # the scaled-down node drained: never killed
    assert joiner.alive is True or joiner not in cluster.all_nodes()
    drained = [n for n in (joiner, node) if n not in cluster.live_nodes()]
    for n in drained:
        assert n.alive, "autoscaler scale-down must drain, not kill"
    cluster.stop()


def test_autoscaler_respects_min_max():
    cluster = make_cluster(1)
    scaler = _autoscaler(cluster)
    # idle cluster at min_nodes: never scales below
    for _ in range(6):
        assert scaler.step() != "scale-down"
    assert len(cluster.live_nodes()) == 1
    cluster.stop()


# ------------------------------------------------ workflow survives migration
def test_workflow_resume_infers_placement_from_memoized_reads():
    from repro.faas.platform import FaasConfig, LambdaPlatform
    from repro.workflow import (
        TxnScope,
        WorkflowConfig,
        WorkflowExecutor,
        WorkflowSpec,
    )
    from repro.workflow.txn import MemoStore

    cluster = make_cluster(2, routing="consistent_hash")
    platform = LambdaPlatform(FaasConfig(time_scale=0.0))
    execu = WorkflowExecutor(
        platform,
        cluster=cluster,
        config=WorkflowConfig(scope=TxnScope.WORKFLOW, declare_finished=False),
    )
    seeded = cluster.live_nodes()[0]
    tx = seeded.start_transaction()
    seeded.put(tx, "inferred-key", b"seed")
    seeded.commit_transaction(tx)
    seeded.release_transaction(tx)
    cluster.step_all()

    spec = WorkflowSpec("infer")
    # NOTE: no Step.reads declared — the read set is only discoverable
    # from what the body actually touches
    spec.step("read_it", fn=lambda ctx: (ctx.get("inferred-key") or b"").decode())
    spec.step(
        "write_it",
        fn=lambda ctx: ctx.put("out", b"done") or "ok",
        deps=("read_it",),
    )
    first = execu.run(spec)
    assert first.results["read_it"] == "seed"

    # the memo carries the recorded read set...
    store = MemoStore(cluster)
    _found, records, reads = store.load_all_with_reads(
        first.workflow_uuid, spec.steps, scope=TxnScope.WORKFLOW
    )
    assert "inferred-key" in reads

    # ...and a re-drive routes by it: capture the hint the router sees
    seen_hints = []
    orig_route = cluster.router.route

    def spy(nodes, hint=None):
        seen_hints.append(hint)
        return orig_route(nodes, hint)

    cluster.router.route = spy
    second = execu.run(spec, uuid=first.workflow_uuid)
    cluster.router.route = orig_route
    assert second.steps_memoized == 2
    assert any(
        h is not None and "inferred-key" in h.keys for h in seen_hints
    ), "resume must infer the placement hint from memoized reads"
    platform.shutdown()
    cluster.stop()


def test_workflow_pool_survives_drain_mid_stream():
    from repro.faas.platform import FaasConfig, LambdaPlatform
    from repro.workflow import WorkflowPool, WorkflowSpec

    cluster = make_cluster(2, routing="consistent_hash")
    cluster.start()
    platform = LambdaPlatform(FaasConfig(time_scale=0.0))
    specs = []
    for i in range(6):
        spec = WorkflowSpec(f"mig{i}")
        spec.step(
            "w",
            fn=lambda ctx, i=i: ctx.put(f"mig-{i}", b"v") or i,
        )
        specs.append(spec)
    with WorkflowPool(platform, cluster=cluster) as pool:
        tickets = [pool.submit(s) for s in specs[:3]]
        # drain one node while workflows are in flight, keep submitting
        cluster.drain_node(cluster.live_nodes()[-1], wait=False)
        tickets += [pool.submit(s) for s in specs[3:]]
        cluster.advance_lifecycle()
        results = [t.result(timeout=30) for t in tickets]
    assert all(r.committed_tid is not None or r.deduped for r in results)
    # one deterministic §4 round: commits that landed on the draining node
    # must reach the survivor's commit-set cache before we read (the
    # background loop alone may not have ticked yet in a ~0.2s test)
    cluster.step_all()
    # every workflow's write is durably visible exactly once
    survivor = cluster.live_nodes()[0]
    for i in range(6):
        tx = survivor.start_transaction()
        assert survivor.get(tx, f"mig-{i}") == b"v"
        survivor.commit_transaction(tx)
        survivor.release_transaction(tx)
    cluster.stop()
