"""Equivalence of the incremental Algorithm-1 read path with the reference.

``atomic_read_select_incremental`` + ``SessionReadState`` must select the
*identical* ``ReadSelection`` as the retained coarse-lock reference
``atomic_read_select`` for any sequence of reads interleaved with GC
``remove()``s that respect the §5.1 guard (GC never removes a record read by
a running transaction).  The suite drives both implementations in lockstep
over randomized histories — a hypothesis property test plus a deterministic
seeded sweep that runs even without hypothesis installed.

It also pins the *divergence direction* when the §5.1 guard is deliberately
broken: the incremental map retains constraints the reference drops, so the
incremental path may only ever be more conservative (fresher-or-abort),
never less.
"""

import random

import pytest

from repro.core import (
    CommitSetCache,
    ReadStatus,
    SessionReadState,
    TransactionRecord,
    TxnId,
    atomic_read_select,
    atomic_read_select_incremental,
)

KEYS = ["a", "b", "c", "d", "e"]


def _mk_record(i, write_set):
    return TransactionRecord(
        tid=TxnId(timestamp=i + 1, uuid=f"u{i:04d}"),
        write_set=tuple(sorted(write_set)),
    )


def run_history(records, ops, stripes=4):
    """Drive one session through ``ops`` on a cache seeded with ``records``,
    asserting reference/incremental agreement at every read.

    ``ops`` is a list of ``("read", key)`` / ``("remove", record_index)``;
    removes of records currently in the read set are skipped (the §5.1
    guard the equivalence argument rests on).
    """
    cache = CommitSetCache(stripes=stripes)
    for rec in records:
        cache.add(rec)

    read_set = {}
    state = SessionReadState()
    reads_checked = 0

    for op, arg in ops:
        if op == "remove":
            tid = records[arg].tid
            if tid in read_set.values():
                continue  # §5.1: never GC a record read by a running txn
            cache.remove(tid)
            continue

        key = arg
        ref = atomic_read_select(key, read_set, cache)
        sel, rec = atomic_read_select_incremental(key, read_set, cache, state)
        assert sel.status == ref.status, (
            f"status diverged on read({key}): ref={ref} inc={sel} "
            f"read_set={read_set}"
        )
        assert sel.tid == ref.tid, (
            f"tid diverged on read({key}): ref={ref} inc={sel} "
            f"read_set={read_set}"
        )
        reads_checked += 1
        if sel.status is ReadStatus.OK:
            assert rec is not None and rec.tid == sel.tid
            read_set[key] = sel.tid
            state.note_read(rec)
    return reads_checked


def _random_history(rng, n_txns=12, n_ops=30):
    records = [
        _mk_record(i, rng.sample(KEYS, rng.randint(1, 3)))
        for i in range(n_txns)
    ]
    ops = []
    for _ in range(n_ops):
        if rng.random() < 0.25:
            ops.append(("remove", rng.randrange(n_txns)))
        else:
            ops.append(("read", rng.choice(KEYS)))
    return records, ops


def test_equivalence_seeded_sweep():
    """Deterministic fallback: 200 seeded random histories, no hypothesis
    needed.  Mixed stripe counts including the degenerate single stripe."""
    total = 0
    for seed in range(200):
        rng = random.Random(seed)
        records, ops = _random_history(rng)
        total += run_history(records, ops, stripes=1 + seed % 8)
    assert total > 1000  # the sweep actually exercised reads


def test_equivalence_empty_and_null_reads():
    cache = CommitSetCache(stripes=3)
    state = SessionReadState()
    ref = atomic_read_select("nope", {}, cache)
    sel, rec = atomic_read_select_incremental("nope", {}, cache, state)
    assert ref.status is ReadStatus.NOT_FOUND
    assert sel.status is ReadStatus.NOT_FOUND and rec is None


def test_incremental_only_more_conservative_when_guard_broken():
    """Break the §5.1 guard on purpose: remove a record that *is* in the read
    set.  The reference drops its case-1 constraint (conservative treatment
    of the miss); the incremental map retains it.  The retained constraint
    may only force a fresher selection or an abort — never a fractured read.
    """
    # t1 cowrites {a, b}; t2 writes b alone (newer)
    r1 = _mk_record(0, ["a", "b"])
    r2 = _mk_record(1, ["b"])
    cache = CommitSetCache(stripes=4)
    cache.add(r1)
    cache.add(r2)

    read_set = {}
    state = SessionReadState()
    sel, rec = atomic_read_select_incremental("a", read_set, cache, state)
    assert sel.tid == r1.tid
    read_set["a"] = sel.tid
    state.note_read(rec)

    cache.remove(r1.tid)  # guard violation: r1 was read by this session

    ref = atomic_read_select("b", read_set, cache)
    sel, _ = atomic_read_select_incremental("b", read_set, cache, state)
    # both still pick r2 (newest), but the incremental path got there via a
    # retained lower bound rather than an unconstrained scan
    assert ref.tid == r2.tid and sel.tid == r2.tid

    # now also remove r2: reference sees no constraint -> NOT_FOUND on a
    # fresh key scan; incremental still remembers t1 cowrote b and must
    # abort rather than serve the (now unprovable) NULL version
    cache.remove(r2.tid)
    ref = atomic_read_select("b", {"a": r1.tid}, cache)
    sel, _ = atomic_read_select_incremental("b", {"a": r1.tid}, cache, state)
    assert ref.status is ReadStatus.NOT_FOUND  # reference dropped constraint
    assert sel.status is ReadStatus.NO_VALID_VERSION  # safe direction


# -- hypothesis property test ------------------------------------------------

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402


@st.composite
def histories(draw):
    n_txns = draw(st.integers(2, 16))
    records = []
    for i in range(n_txns):
        ws = draw(st.sets(st.sampled_from(KEYS), min_size=1, max_size=3))
        records.append(_mk_record(i, ws))
    ops = draw(
        st.lists(
            st.one_of(
                st.tuples(st.just("read"), st.sampled_from(KEYS)),
                st.tuples(st.just("remove"), st.integers(0, n_txns - 1)),
            ),
            min_size=1,
            max_size=40,
        )
    )
    stripes = draw(st.integers(1, 8))
    return records, ops, stripes


@settings(max_examples=200, deadline=None)
@given(histories())
def test_equivalence_property(history):
    records, ops, stripes = history
    run_history(records, ops, stripes=stripes)
