"""StorageIOPipeline + async commit path: cross-transaction group commit
coalescing, the per-transaction ordering barrier (versions + u/ index before
the commit record — §3.3 under arbitrary flush interleavings), the
crash-window between the uuid index and the commit record, commit offload
through sessions and the pool, pipelined GC deletes, cowritten prefetch, and
the engine-scaled read-retry backoff."""

import threading
import time
from typing import Dict, List

import pytest

from repro.core import AftCluster, AftNode, AftNodeConfig, ClusterConfig
from repro.core.gc import LocalGcAgent
from repro.core.records import (
    COMMIT_PREFIX,
    UUID_PREFIX,
    TransactionRecord,
    commit_key,
    lookup_committed_record,
    uuid_key,
)
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.storage.base import StorageEngine
from repro.storage.memory import MemoryStorage
from repro.storage.pipeline import PipelineConfig, StorageIOPipeline
from repro.storage.simulated import dynamodb_like
from repro.workflow import (
    PoolConfig,
    TxnScope,
    WorkflowConfig,
    WorkflowExecutor,
    WorkflowPool,
    WorkflowSpec,
)


class RecordingStorage(MemoryStorage):
    """Logs the durable order of every persisted key (appended after the
    write applies) plus per-batch sizes; the ordering-invariant tests and
    the coalescing assertions read these."""

    def __init__(self) -> None:
        super().__init__()
        self.log: List[str] = []
        self.batch_sizes: List[int] = []
        self._log_lock = threading.Lock()

    def put(self, key: str, value: bytes) -> None:
        super().put(key, value)
        with self._log_lock:
            self.log.append(key)
            self.batch_sizes.append(1)

    def put_batch(self, items: Dict[str, bytes]) -> None:
        super().put_batch(items)
        with self._log_lock:
            self.log.extend(items.keys())
            self.batch_sizes.append(len(items))

    def first_positions(self) -> Dict[str, int]:
        with self._log_lock:
            pos: Dict[str, int] = {}
            for i, key in enumerate(self.log):
                pos.setdefault(key, i)
            return pos


def assert_record_ordering(storage) -> None:
    """§3.3 invariant: no commit record durable before every one of its
    version keys and its u/ index entry."""
    pos = storage.first_positions()
    for key in storage.list_keys(COMMIT_PREFIX):
        record = TransactionRecord.decode(storage.get(key))
        rec_pos = pos[key]
        deps = [record.storage_key_for(k) for k in record.write_set]
        deps.append(uuid_key(record.tid.uuid))
        for dep in deps:
            assert dep in pos and pos[dep] < rec_pos, (
                f"commit record {key} durable before its dependency {dep}"
            )


# ---------------------------------------------------------------------------
# pipeline unit behavior
# ---------------------------------------------------------------------------

def test_group_coalescing_and_barrier():
    store = RecordingStorage()
    pipe = StorageIOPipeline(store, PipelineConfig(
        io_workers=2, flush_max_items=25, flush_linger_ms=20.0,
        flush_concurrency=1,
    ))
    try:
        futs = [
            pipe.submit_puts({f"g{i}/a": b"x", f"g{i}/b": b"y"})
            for i in range(10)
        ]
        for f in futs:
            assert f.result(10) is None
        # every item durable once its group future resolves
        assert len(store.list_keys("g")) == 20
        s = pipe.stats()
        # 10 groups (20 items) coalesced into far fewer flushes
        assert s["flushes"] < 10
        assert s["coalesce_ratio"] > 1.5
        assert max(store.batch_sizes) > 2  # real cross-group batches
    finally:
        pipe.close()


def test_large_group_splits_across_flushes_single_barrier():
    store = RecordingStorage()
    pipe = StorageIOPipeline(store, PipelineConfig(
        io_workers=2, flush_max_items=5, flush_linger_ms=0.0,
    ))
    try:
        items = {f"big/{i}": bytes([i]) for i in range(23)}
        fut = pipe.submit_puts(items)
        assert fut.result(10) is None
        assert len(store.list_keys("big/")) == 23  # all durable at resolve
        assert pipe.stats()["flushes"] >= 5  # paged into ≥ ceil(23/5) flushes
    finally:
        pipe.close()


def test_pipelined_gets_coalesce_on_batching_engines():
    store = RecordingStorage()  # MemoryStorage: supports_batch_get
    for i in range(30):
        store.put(f"r/{i}", str(i).encode())
    pipe = StorageIOPipeline(store, PipelineConfig(
        io_workers=2, flush_max_items=25, flush_linger_ms=10.0,
    ))
    try:
        out = pipe.get_many([f"r/{i}" for i in range(30)])
        assert out["r/7"] == b"7" and out["r/29"] == b"29"
        s = pipe.stats()
        assert s["get_batches"] >= 1
        assert s["batched_gets"] == 30
    finally:
        pipe.close()


def test_delete_coalescing_and_drain():
    store = RecordingStorage()
    for i in range(40):
        store.put(f"d/{i}", b"x")
    pipe = StorageIOPipeline(store, PipelineConfig(io_workers=2))
    try:
        futs = [
            pipe.submit_deletes([f"d/{i}" for i in range(j, j + 10)])
            for j in range(0, 40, 10)
        ]
        pipe.drain(timeout=10)
        for f in futs:
            assert f.done()
        assert store.list_keys("d/") == []
        assert pipe.stats()["deleted_keys"] == 40
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# async commit: equivalence + idempotence
# ---------------------------------------------------------------------------

def test_async_commit_matches_sync_and_is_idempotent():
    store = RecordingStorage()
    node = AftNode(store, AftNodeConfig(node_id="n0"))
    tx = node.start_transaction()
    node.put(tx, "k1", b"v1")
    node.put(tx, "k2", b"v2")
    tid = node.commit_transaction_async(tx).result(10)
    assert node.committed_tid_for_uuid(tid.uuid) == tid
    # visible to a fresh transaction through Algorithm 1
    tx2 = node.start_transaction()
    assert node.get(tx2, "k1") == b"v1"
    # §3.3.1 retry of the SAME uuid recommits idempotently (async + sync)
    tx3 = node.start_transaction(tid.uuid)
    node.put(tx3, "k1", b"v1")
    assert node.commit_transaction_async(tx3).result(10) == tid
    tx4 = node.start_transaction(tid.uuid)
    assert node.commit_transaction(tx4) == tid
    assert len(store.list_keys(COMMIT_PREFIX)) == 1
    assert_record_ordering(store)
    node.close_pipeline()


def test_async_commit_read_only_and_shared_future():
    node = AftNode(MemoryStorage(), AftNodeConfig())
    tx = node.start_transaction()
    node.get(tx, "nothing")  # read-only session
    f1 = node.commit_transaction_async(tx)
    tid = f1.result(10)
    assert tid is not None
    assert node.storage.list_keys(COMMIT_PREFIX) == []  # nothing persisted
    node.close_pipeline()


def test_async_commit_retry_probe_finds_rival_commit():
    """A retried UUID whose commit this node never heard of resolves through
    the pipelined u/-probe instead of recommitting (cross-node §3.3.1) —
    and crucially leaves the u/ index pointing at the SURVIVING record: a
    retry that repointed the index at its own never-recorded tid would make
    every later probe read index-without-record as "not committed" and
    recommit a duplicate."""
    store = MemoryStorage()
    n0 = AftNode(store, AftNodeConfig(node_id="n0"))
    tx = n0.start_transaction()
    n0.put(tx, "k", b"v")
    tid = n0.commit_transaction(tx)
    n1 = AftNode(store, AftNodeConfig(node_id="n1"), bootstrap=False)
    tx2 = n1.start_transaction(tid.uuid)  # same UUID ⇒ retry
    n1.put(tx2, "k", b"v")
    tid2 = n1.commit_transaction_async(tx2).result(10)
    assert tid2 == tid
    assert len(store.list_keys(COMMIT_PREFIX)) == 1
    n1.drain_pipeline(timeout=10)  # any stray index write would be in-flight
    assert store.get(uuid_key(tid.uuid)) == commit_key(tid).encode()
    # a THIRD retry on yet another amnesiac node still resolves to tid
    n2 = AftNode(store, AftNodeConfig(node_id="n2"), bootstrap=False)
    tx3 = n2.start_transaction(tid.uuid)
    n2.put(tx3, "k", b"v")
    assert n2.commit_transaction_async(tx3).result(10) == tid
    assert len(store.list_keys(COMMIT_PREFIX)) == 1
    for n in (n0, n1, n2):
        n.close_pipeline()


# ---------------------------------------------------------------------------
# the crash window: u/ index durable, commit record not (satellite)
# ---------------------------------------------------------------------------

class FailOncePut(MemoryStorage):
    """Raises on the first put whose key matches a prefix (sync path)."""

    def __init__(self, fail_prefix: str) -> None:
        super().__init__()
        self.fail_prefix = fail_prefix
        self.fired = False

    def put(self, key: str, value: bytes) -> None:
        if not self.fired and key.startswith(self.fail_prefix):
            self.fired = True
            raise RuntimeError(f"injected crash before {key}")
        super().put(key, value)


def _assert_crash_window_recovery(store, uuid: str) -> None:
    # the index landed, the record did not: reads as NOT committed
    assert store.get(uuid_key(uuid)) is not None
    assert store.list_keys(COMMIT_PREFIX) == []
    assert lookup_committed_record(store, uuid) is None
    # retry on a fresh node recommits exactly once, no duplicate versions
    n1 = AftNode(store, AftNodeConfig(node_id="n1"), bootstrap=False)
    tx = n1.start_transaction(uuid)
    n1.put(tx, "pay/1", b"100")
    tid = n1.commit_transaction(tx)
    records = store.list_keys(COMMIT_PREFIX)
    assert len(records) == 1
    record = TransactionRecord.decode(store.get(records[0]))
    assert record.tid == tid and record.write_set == ("pay/1",)
    # the u/ index points at the surviving record and the value reads back
    assert store.get(uuid_key(uuid)) == commit_key(tid).encode()
    tx2 = n1.start_transaction()
    assert n1.get(tx2, "pay/1") == b"100"
    n1.close_pipeline()


def test_crash_between_index_and_record_sync_path():
    store = FailOncePut(COMMIT_PREFIX)
    node = AftNode(store, AftNodeConfig(node_id="n0"))
    tx = node.start_transaction()
    node.put(tx, "pay/1", b"100")
    with pytest.raises(RuntimeError):
        node.commit_transaction(tx)
    node.fail()  # the function's node dies with the commit half-done
    _assert_crash_window_recovery(store, tx)


def test_crash_between_index_and_record_async_path():
    store = MemoryStorage()
    node = AftNode(store, AftNodeConfig(node_id="n0"))
    pipe = node.io_pipeline()

    def kill_record_flush(site: str, keys: List[str]) -> None:
        if site == "pipeline:flush" and any(
            k.startswith(COMMIT_PREFIX) for k in keys
        ):
            raise RuntimeError("injected kill-mid-flush at the record write")

    pipe.fault_hook = kill_record_flush
    tx = node.start_transaction()
    node.put(tx, "pay/1", b"100")
    fut = node.commit_transaction_async(tx)
    with pytest.raises(RuntimeError):
        fut.result(10)
    pipe.fault_hook = None
    node.fail()
    _assert_crash_window_recovery(store, tx)


# ---------------------------------------------------------------------------
# commit offload through sessions + pool, GC, prefetch, retry scale
# ---------------------------------------------------------------------------

def _cluster(storage=None, **node_kw) -> AftCluster:
    return AftCluster(
        storage if storage is not None else MemoryStorage(),
        ClusterConfig(
            num_nodes=1, start_background_threads=False,
            node=AftNodeConfig(**node_kw),
        ),
    )


def two_step_spec(i: int) -> WorkflowSpec:
    """Dependent step reads the upstream's AFT write — the visibility
    barrier probe for STEP-scope commit offload."""
    spec = WorkflowSpec(f"wf{i}")

    def a(ctx):
        ctx.put(f"off/{i}/a", b"7")
        return 7

    def b(ctx):
        raw = ctx.get(f"off/{i}/a")
        assert raw == b"7", f"dependent read missed upstream commit: {raw!r}"
        ctx.put(f"off/{i}/b", b"14")
        return 14

    spec.step("a", a)
    spec.step("b", b, deps=("a",))
    return spec


def test_step_scope_commit_offload_preserves_dataflow():
    cluster = _cluster()
    platform = LambdaPlatform(FaasConfig(time_scale=0.0))
    ex = WorkflowExecutor(
        platform, cluster=cluster,
        config=WorkflowConfig(scope=TxnScope.STEP, commit_offload=True),
    )
    for i in range(5):
        r = ex.run(two_step_spec(i))
        assert r.results["b"] == 14
    # both steps' commits landed exactly once each
    store = cluster.storage
    assert len(store.list_keys(COMMIT_PREFIX)) == 10
    assert_record_ordering_ok = store.list_keys(UUID_PREFIX)
    assert len(assert_record_ordering_ok) == 10
    platform.shutdown()
    cluster.stop()


def test_pool_offloaded_commits_exactly_once_under_flush_kills():
    store = RecordingStorage()
    cluster = _cluster(storage=store, flush_linger_ms=0.0)
    node = cluster.live_nodes()[0]
    state = {"kills": 0}
    lock = threading.Lock()

    def hook(site: str, keys: List[str]) -> None:
        with lock:
            if state["kills"] >= 12:
                return
            state["kills"] += 1
        raise RuntimeError("injected kill-mid-flush")

    node.io_pipeline().fault_hook = hook
    platform = LambdaPlatform(FaasConfig(time_scale=0.0))
    cfg = PoolConfig(
        scope=TxnScope.WORKFLOW, commit_offload=True, max_attempts=30,
        retry_backoff_ms=0.0, declare_finished=False,
    )
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [pool.submit(two_step_spec(i)) for i in range(30)]
        results = [t.result(timeout=60) for t in tickets]
    node.io_pipeline().fault_hook = None
    assert state["kills"] > 0
    by_uuid: Dict[str, int] = {}
    for key in store.list_keys(COMMIT_PREFIX):
        u = TransactionRecord.decode(store.get(key)).tid.uuid
        by_uuid[u] = by_uuid.get(u, 0) + 1
    for r in results:
        assert by_uuid.get(r.workflow_uuid) == 1  # exactly one commit
    assert all(c == 1 for c in by_uuid.values())  # memos included
    assert_record_ordering(store)
    platform.shutdown()
    cluster.stop()


def test_gc_sweep_deletes_ride_the_pipeline():
    cluster = _cluster()
    node = cluster.live_nodes()[0]
    platform = LambdaPlatform(FaasConfig(time_scale=0.0))
    cfg = PoolConfig(scope=TxnScope.WORKFLOW, declare_finished=True)
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        pool.submit(two_step_spec(0), uuid="gc-wf").result(timeout=30)
    assert cluster.storage.list_keys("d/.wf/")  # memos exist pre-sweep
    agent = LocalGcAgent(node)
    agent.gc_finished_workflows()
    # the sweep settled before returning, THROUGH the pipeline
    assert cluster.storage.list_keys("d/.wf/") == []
    assert node.stats()["io_deleted_keys"] > 0
    platform.shutdown()
    cluster.stop()


def test_abort_after_attempted_commit_preserves_spilled_bytes():
    """Lost-ack window + spilled writes: the commit lands durably but its
    future fails; the failure handler aborts.  Abort must NOT delete the
    spilled version bytes — the durable commit record references them, and
    a retry resolves to the committed tid whose data must stay readable."""
    store = MemoryStorage()
    node = AftNode(store, AftNodeConfig(
        node_id="n0", write_buffer_max_bytes=8,  # force spill
    ))
    pipe = node.io_pipeline()
    fired = {"n": 0}

    def lose_record_ack(site: str, keys: List[str]) -> None:
        if site == "pipeline:flush-landed" and any(
            k.startswith(COMMIT_PREFIX) for k in keys
        ):
            fired["n"] += 1
            raise RuntimeError("ack lost after the record landed")

    pipe.fault_hook = lose_record_ack
    tx = node.start_transaction()
    node.put(tx, "big", b"0123456789abcdef")  # spills past 8 bytes
    fut = node.commit_transaction_async(tx)
    with pytest.raises(RuntimeError):
        fut.result(10)
    pipe.fault_hook = None
    assert fired["n"] == 1
    node.abort_transaction(tx)  # what every async failure handler does
    # the record IS durable; the retry resolves to it...
    record = lookup_committed_record(store, tx)
    assert record is not None
    tx2 = node.start_transaction(tx)
    node.put(tx2, "big", b"0123456789abcdef")
    assert node.commit_transaction_async(tx2).result(10) == record.tid
    # ...and the spilled bytes it references were NOT destroyed
    tx3 = node.start_transaction()
    assert node.get(tx3, "big") == b"0123456789abcdef"
    node.close_pipeline()


def test_gc_withholds_ack_when_pipelined_deletes_fail():
    """A failed delete flush must NOT let the sweep ack the marker — an
    acked marker can retire, permanently orphaning the undeleted keys.  The
    next pass re-sweeps (idempotent) and only then acks."""

    class FlakyDeletes(MemoryStorage):
        def __init__(self):
            super().__init__()
            self.fail_next = False

        def delete_batch(self, keys):
            if self.fail_next:
                self.fail_next = False
                raise RuntimeError("injected delete outage")
            super().delete_batch(keys)

    store = FlakyDeletes()
    cluster = _cluster(storage=store)
    node = cluster.live_nodes()[0]
    platform = LambdaPlatform(FaasConfig(time_scale=0.0))
    cfg = PoolConfig(scope=TxnScope.WORKFLOW, declare_finished=True)
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        pool.submit(two_step_spec(0), uuid="gc-flaky").result(timeout=30)
    agent = LocalGcAgent(node)
    store.fail_next = True
    assert agent.gc_finished_workflows() == 0  # pass aborted, nothing acked
    assert not node.workflow_marker_acked("gc-flaky")
    assert store.list_keys("d/.wf/")  # doomed keys survived the outage
    assert agent.gc_finished_workflows() == 1  # re-sweep succeeds
    assert node.workflow_marker_acked("gc-flaky")
    assert store.list_keys("d/.wf/") == []
    platform.shutdown()
    cluster.stop()


def test_fetch_prefetches_cowritten_keys():
    node = AftNode(MemoryStorage(), AftNodeConfig(node_id="n0"))
    node.io_pipeline()  # prefetch activates once the pipeline exists
    tx = node.start_transaction()
    for i in range(4):
        node.put(tx, f"cw/{i}", str(i).encode())
    tid = node.commit_transaction(tx)
    # forget cached bytes so reads must go to storage
    record = node.cache.get(tid)
    node.data_cache.evict_transaction(record)
    tx2 = node.start_transaction()
    assert node.get(tx2, "cw/0") == b"0"
    node.drain_pipeline(timeout=10)
    deadline = time.monotonic() + 5
    while (
        node.stats["prefetched_keys"] < 3 and time.monotonic() < deadline
    ):
        time.sleep(0.01)
    assert node.stats["prefetched_keys"] == 3
    for i in range(1, 4):
        assert node.data_cache.contains_key(f"cw/{i}")
    node.close_pipeline()


def test_read_retry_backoff_scales_with_engine_time_scale():
    # storage_read_retry_s is huge, but the engine is compressed 10000×:
    # a doomed read must abort quickly instead of out-sleeping the engine
    store = dynamodb_like(time_scale=0.0001)
    node = AftNode(
        store,
        AftNodeConfig(
            node_id="n0", enable_data_cache=False,
            storage_read_retries=3, storage_read_retry_s=0.5,
        ),
    )
    tx = node.start_transaction()
    node.put(tx, "gone", b"x")
    tid = node.commit_transaction(tx)
    # destroy the version bytes (a GC race) so the fetch retries, then fails
    record = node.cache.get(tid)
    store.inner.delete(record.storage_key_for("gone"))
    from repro.core.errors import ReadAbortError

    tx2 = node.start_transaction()
    t0 = time.monotonic()
    with pytest.raises(ReadAbortError):
        node.get(tx2, "gone")
    elapsed = time.monotonic() - t0
    # unscaled backoff would sleep 0.5·(1+2+3) = 3s; scaled is ~instant
    assert elapsed < 1.0
    node.close_pipeline()


# The hypothesis property test for the group-commit ordering invariant
# lives in tests/test_property_pipeline.py (importorskip'd like the other
# property suites), so this module always runs.
