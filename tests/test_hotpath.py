"""Metadata hot-path unit + stress coverage.

* striped ``CommitSetCache`` thread-safety: concurrent add/remove/read load,
  then the records/index invariant ("a transaction appears in the index iff
  its record is present") checked stripe by stripe at quiescence, with no
  dangling index entries and every version list still sorted;
* ``DataCache`` LRU regression: a re-read key must survive eviction pressure
  (the old FIFO evicted it regardless of recency);
* encode-once record fan-out: identity-cached bytes, decode seeding, the
  ``set_encode_cache`` toggle, and the multicast envelope roundtrip;
* binary version-header frame: roundtrip, unicode keys, legacy-JSON
  fallback, unknown-version rejection.
"""

import json
import threading

import pytest

from repro.core import (
    CommitSetCache,
    DataCache,
    TransactionRecord,
    TxnId,
    decode_envelope,
    embed_metadata,
    encode_envelope,
    extract_metadata,
    set_encode_cache,
)
from repro.core.records import encode_cache_enabled


def _rec(ts, uuid, write_set):
    return TransactionRecord(
        tid=TxnId(timestamp=ts, uuid=uuid), write_set=tuple(sorted(write_set))
    )


# -- striped cache -----------------------------------------------------------

def _check_invariant(cache):
    """records/index iff-invariant, checked under the coarse section."""
    with cache.global_section():
        records = {}
        for s in cache._stripes:
            records.update(s.records)
        indexed = set()
        for s in cache._stripes:
            for key, versions in s.index.items():
                assert versions == sorted(versions), f"unsorted list for {key}"
                assert len(versions) == len(set(versions))
                for tid in versions:
                    assert tid in records, f"dangling index entry {key}@{tid}"
                    assert key in records[tid].write_set
                    indexed.add(tid)
        for tid, rec in records.items():
            for key in rec.write_set:
                stripe = cache._stripe_for_key(key)
                assert tid in stripe.index.get(key, ()), (
                    f"record {tid} missing from index of {key}"
                )
        assert indexed <= set(records)


def test_striped_cache_concurrent_stress():
    cache = CommitSetCache(stripes=8)
    keys = [f"k{i}" for i in range(12)]
    n_per_thread = 300
    barrier = threading.Barrier(8)
    errors = []

    def adder(base):
        barrier.wait()
        for i in range(n_per_thread):
            ws = (keys[(base + i) % 12], keys[(base + i * 7 + 3) % 12])
            cache.add(_rec(base * 100_000 + i + 1, f"a{base}-{i}", ws),
                      fresh=(i % 3 == 0))

    def remover(base):
        barrier.wait()
        for i in range(n_per_thread):
            cache.remove(TxnId(base * 100_000 + i + 1, f"a{base}-{i}"))

    def reader():
        barrier.wait()
        for i in range(n_per_thread):
            k = keys[i % 12]
            for t in cache.versions_of(k):
                cache.get(t)  # may be None if pruned concurrently — fine
            cache.latest_version_of(k)
            cache.pruned_max_ts(k)
            len(cache)
            cache.all_tids()

    def run(fn, *args):
        def wrapped():
            try:
                fn(*args)
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append(e)
        return threading.Thread(target=wrapped)

    threads = (
        [run(adder, b) for b in range(4)]
        + [run(remover, b) for b in (0, 2)]  # race adders on same tids
        + [run(reader), run(reader)]
    )
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    _check_invariant(cache)

    stats = cache.lock_stats()
    assert stats["acquires"] > 0
    # drain_fresh returns only records actually added (never duplicates)
    fresh = cache.drain_fresh()
    assert len(fresh) == len({r.tid for r in fresh})


def test_striped_cache_single_stripe_still_correct():
    cache = CommitSetCache(stripes=1)
    r = _rec(1, "u1", ["x", "y"])
    assert cache.add(r)
    assert not cache.add(r)  # idempotent
    assert cache.versions_of("x") == [r.tid]
    assert cache.remove(r.tid) is r
    assert cache.versions_of("x") == []
    assert cache.pruned_max_ts("x") == 1
    _check_invariant(cache)
    with pytest.raises(ValueError):
        CommitSetCache(stripes=0)


def test_versions_view_is_zero_copy():
    cache = CommitSetCache(stripes=4)
    r = _rec(5, "u5", ["k"])
    cache.add(r)
    with cache.lock_for_key("k"):
        view = cache.versions_view("k")
        stripe = cache._stripe_for_key("k")
        assert view is stripe.index["k"]  # no copy under the stripe lock
    assert cache.versions_view("missing") == ()


def test_legacy_coarse_lock_context_manager():
    cache = CommitSetCache(stripes=4)
    with cache.lock:  # freezes every stripe; nested accessors stay legal
        cache.add(_rec(9, "u9", ["z"]))
        assert cache.latest_version_of("z") is not None


# -- DataCache LRU -----------------------------------------------------------

def test_data_cache_lru_rereads_survive_eviction():
    """Regression: under FIFO, k0 (oldest insert) was evicted even though it
    was just re-read; LRU must evict the cold k1 instead."""
    dc = DataCache(max_bytes=100)
    t = TxnId(1, "t")
    dc.put("k0", t, b"x" * 40)
    dc.put("k1", t, b"y" * 40)
    assert dc.get("k0", t) is not None  # promote k0
    dc.put("k2", t, b"z" * 40)          # forces one eviction
    assert dc.get("k0", t) is not None, "re-read key evicted (FIFO behavior)"
    assert dc.get("k1", t) is None      # true LRU victim
    assert dc.stats()["evictions"] == 1
    assert not dc.contains_key("k1") and dc.contains_key("k0")


def test_data_cache_put_existing_promotes():
    dc = DataCache(max_bytes=100)
    t = TxnId(1, "t")
    dc.put("a", t, b"x" * 40)
    dc.put("b", t, b"y" * 40)
    dc.put("a", t, b"X" * 40)  # overwrite promotes too
    dc.put("c", t, b"z" * 40)
    assert dc.get("a", t) == b"X" * 40
    assert dc.get("b", t) is None


# -- encode-once + envelopes -------------------------------------------------

def test_encode_once_identity_and_decode_seeding():
    r = _rec(7, "u7", ["p", "q"])
    e1 = r.encode()
    e2 = r.encode()
    assert e1 is e2  # memoized on the instance
    r2 = TransactionRecord.decode(e1)
    assert r2 == r
    # decode seeds the cache with the wire bytes: no re-serialization
    assert r2.encode() == e1


def test_encode_cache_toggle():
    assert encode_cache_enabled()
    set_encode_cache(False)
    try:
        r = _rec(8, "u8", ["p"])
        e1 = r.encode()
        e2 = r.encode()
        assert e1 == e2
        assert "_enc" not in r.__dict__  # nothing cached while disabled
    finally:
        set_encode_cache(True)


def test_envelope_roundtrip():
    recs = [_rec(i + 1, f"e{i}", ["a", f"k{i}"]) for i in range(3)]
    payload = encode_envelope(recs)
    out = decode_envelope(payload)
    assert list(out) == recs
    assert decode_envelope(encode_envelope([])) == ()
    # each record's bytes ride the encode-once cache inside the envelope
    assert recs[0].encode() in payload


# -- binary version-header frame --------------------------------------------

def test_metadata_frame_roundtrip():
    tid = TxnId(42, "abc")
    framed = embed_metadata(b"\x00payload\xff", tid, ["k2", "k1", "ék"])
    value, out_tid, cow = extract_metadata(framed)
    assert value == b"\x00payload\xff"
    assert out_tid == tid
    assert cow == ("k1", "k2", "ék")  # sorted


def test_metadata_frame_legacy_json_fallback():
    tid = TxnId(7, "legacy")
    header = json.dumps({"t": tid.encode(), "c": ["a", "b"]}).encode()
    legacy = len(header).to_bytes(4, "big") + header + b"body"
    value, out_tid, cow = extract_metadata(legacy)
    assert value == b"body" and out_tid == tid and cow == ("a", "b")


def test_metadata_frame_unknown_version_rejected():
    framed = bytearray(embed_metadata(b"v", TxnId(1, "u"), ["k"]))
    framed[1] = 99
    with pytest.raises(ValueError):
        extract_metadata(bytes(framed))
