"""Durable cross-workflow chaining: exactly-once trigger queue through AFT.

The contract under test (workflow/chain.py): a committed workflow's
``on_commit`` triggers durably start their child workflows exactly once —
no drops, no double-fires — even when the handoff crashes between commit
and enqueue-visible, between claim and child-start, or across a pool
restart.  The unscoped baseline demonstrably violates both halves.
"""

import json
import threading

import pytest

from repro.core import AftCluster, ClusterConfig
from repro.core.gc import LocalGcAgent
from repro.core.records import (
    COMMIT_PREFIX,
    UUID_PREFIX,
    claim_txn_uuid,
    trigger_entry_id,
    trigger_key,
    workflow_finish_key,
)
from repro.faas.platform import FaasConfig, FunctionFailure, LambdaPlatform
from repro.storage.memory import MemoryStorage
from repro.workflow import (
    ChainConsumer,
    ChainConsumerConfig,
    PoolConfig,
    Trigger,
    TxnScope,
    WorkflowConfig,
    WorkflowExecutor,
    WorkflowPool,
    WorkflowSpec,
    WorkflowSpecError,
    list_queue_entries,
)


def make_cluster(nodes: int = 1) -> AftCluster:
    return AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=nodes, start_background_threads=False),
    )


def fast_platform(**kw) -> LambdaPlatform:
    return LambdaPlatform(FaasConfig(time_scale=0.0, **kw))


def consumer_cfg(**kw) -> ChainConsumerConfig:
    kw.setdefault("reclaim_after_s", 0.0)  # tests recover immediately
    return ChainConsumerConfig(**kw)


def parent_spec(child: WorkflowSpec, **trigger_kw) -> WorkflowSpec:
    spec = WorkflowSpec("parent")

    def produce(ctx):
        ctx.put("chain/parent-effect", b"done")
        return {"payload": 41}

    spec.step("produce", produce)
    trigger_kw.setdefault("args_from", "produce")
    spec.trigger(Trigger(child, **trigger_kw))
    return spec


def child_spec(ran_counter) -> WorkflowSpec:
    spec = WorkflowSpec("child")

    def consume(ctx):
        ran_counter.append(ctx.args)
        ctx.put("chain/child-effect", json.dumps(ctx.args).encode())
        return ctx.args

    spec.step("consume", consume)
    return spec


# ---------------------------------------------------------------------------
# DSL + staging semantics
# ---------------------------------------------------------------------------

def test_trigger_validation_rejects_bad_edges():
    spec = WorkflowSpec("bad")
    spec.step("a", lambda ctx: 1)
    spec.trigger(Trigger("x"))
    spec.trigger(Trigger("x"))  # duplicate edge name
    with pytest.raises(WorkflowSpecError):
        spec.validate()

    spec2 = WorkflowSpec("bad2")
    spec2.step("a", lambda ctx: 1)
    spec2.trigger(Trigger("x", name="sl/ash"))
    with pytest.raises(WorkflowSpecError):
        spec2.validate()

    spec3 = WorkflowSpec("bad3")
    spec3.step("a", lambda ctx: 1)
    spec3.trigger(Trigger("x", args_from="nope"))
    with pytest.raises(WorkflowSpecError):
        spec3.validate()


def test_trigger_enqueue_is_atomic_with_parent_commit():
    """WORKFLOW scope: the entry exists iff the parent committed — a parent
    that exhausts its attempts leaves no trigger (and no effects)."""
    cluster = make_cluster()
    ran = []
    ok = parent_spec(child_spec(ran))
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(max_attempts=3),
    )
    ex.run(ok, uuid="atomic-ok")
    assert list_queue_entries(cluster.storage, "default") == [
        trigger_entry_id("atomic-ok", "child")
    ]

    doomed = parent_spec(child_spec(ran))
    doomed.step("dies", lambda ctx: (_ for _ in ()).throw(
        FunctionFailure("always")), deps=["produce"])
    with pytest.raises(Exception):
        ex.run(doomed, uuid="atomic-doomed")
    # no entry for the aborted parent — the trigger rides the commit record
    assert [
        e for e in list_queue_entries(cluster.storage, "default")
        if e.startswith("atomic-doomed")
    ] == []
    cluster.stop()


def test_retried_parent_commit_enqueues_exactly_one_entry():
    """§3.3.1: the parent crashes mid-DAG and retries under the same UUID —
    the deterministic entry id means ONE durable trigger, not one per
    attempt."""
    cluster = make_cluster()
    ran = []
    spec = parent_spec(child_spec(ran))
    remaining = [2]

    def flaky(ctx):
        if remaining[0] > 0:
            remaining[0] -= 1
            raise FunctionFailure("mid-DAG crash")
        return "ok"

    spec.step("flaky", flaky, deps=["produce"])
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(max_attempts=6),
    )
    r = ex.run(spec, uuid="retry-parent")
    assert r.attempts == 3
    entries = list_queue_entries(cluster.storage, "default")
    assert entries == [trigger_entry_id("retry-parent", "child")]
    # exactly one committed version of the entry key
    versions = cluster.storage.list_keys(
        f"d/{trigger_key('default', entries[0])}/"
    )
    assert len(versions) == 1
    cluster.stop()


def test_step_scope_parent_enqueues_exactly_once():
    """STEP scope has no single commit; the standalone deterministic
    enqueue transaction still gives exactly-once entries across retries."""
    cluster = make_cluster()
    ran = []
    spec = parent_spec(child_spec(ran))
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(scope=TxnScope.STEP),
    )
    ex.run(spec, uuid="step-parent")
    # simulate a lost finish acknowledgement: the whole finish re-runs
    ex.run(parent_spec(child_spec(ran)), uuid="step-parent")
    entries = [
        e for e in list_queue_entries(cluster.storage, "default")
        if e.startswith("step-parent")
    ]
    assert entries == [trigger_entry_id("step-parent", "child")]
    versions = cluster.storage.list_keys(
        f"d/{trigger_key('default', entries[0])}/"
    )
    assert len(versions) == 1
    cluster.stop()


# ---------------------------------------------------------------------------
# consumer: claim, drive, dedup
# ---------------------------------------------------------------------------

def test_chain_end_to_end_child_runs_once_with_parent_args():
    cluster = make_cluster()
    ran = []
    child = child_spec(ran)
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"child": child}, consumer_cfg(), start=False
        )
        pool.submit(parent_spec(child)).result(timeout=30)
        assert consumer.drain(timeout_s=30)
    assert ran == [{"payload": 41}]  # once, with the producing step's result
    assert consumer.stats["children_completed"] == 1
    # the child finished under the entry-derived UUID and was marked done
    markers = cluster.storage.list_keys("w/")
    assert any(".chain.child" in m for m in markers)
    cluster.stop()


def test_kill_mid_handoff_replays_exactly_once():
    """The satellite scenario: the consumer dies between claim and
    child-start; a later pass (same or different consumer) re-drives, and
    the child's effects land exactly once."""
    cluster = make_cluster()
    ran = []
    child = child_spec(ran)
    platform = fast_platform(
        failure_rate=1.0, failure_sites=("chain:handoff",)
    )
    with WorkflowPool(platform, cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"child": child}, consumer_cfg(), start=False
        )
        pool.submit(parent_spec(child)).result(timeout=30)
        # every handoff dies at the injection site: entry claimed, no child
        assert consumer.step() == 0
        assert consumer.stats["handoff_crashes"] == 1
        assert ran == []
        # recovery: injection stops (the replacement consumer process)
        platform.config.failure_rate = 0.0
        assert consumer.drain(timeout_s=30)
    assert ran == [{"payload": 41}]
    cluster.stop()


def test_pool_restart_replay_after_claim_runs_child_once():
    """Crash between claim and child-start, then a POOL RESTART: the new
    consumer (different consumer id) takes over the stale claim and the
    child still runs exactly once."""
    cluster = make_cluster()
    ran = []
    child = child_spec(ran)
    platform1 = fast_platform(
        failure_rate=1.0, failure_sites=("chain:handoff",)
    )
    with WorkflowPool(platform1, cluster=cluster) as pool1:
        consumer1 = pool1.attach_chain_consumer(
            {"child": child}, consumer_cfg(), start=False
        )
        pool1.submit(parent_spec(child), uuid="restart-parent").result(30)
        consumer1.step()  # claims, then dies mid-handoff
        assert consumer1.stats["handoff_crashes"] == 1
    assert ran == []

    # fresh process: new pool, new consumer identity
    with WorkflowPool(fast_platform(), cluster=cluster) as pool2:
        consumer2 = pool2.attach_chain_consumer(
            {"child": child}, consumer_cfg(), start=False
        )
        assert consumer2.drain(timeout_s=30)
        assert consumer2.stats["claims_taken_over"] == 1
    assert ran == [{"payload": 41}]

    # a third replay finds the finish marker and never re-drives
    with WorkflowPool(fast_platform(), cluster=cluster) as pool3:
        consumer3 = pool3.attach_chain_consumer(
            {"child": child}, consumer_cfg(), start=False
        )
        assert consumer3.drain(timeout_s=30)
        assert consumer3.stats["already_finished_skips"] >= 1
        assert consumer3.stats["children_started"] == 0
    assert len(ran) == 1
    cluster.stop()


def test_two_consumers_racing_drive_child_effects_once():
    """Claim dedup across racing consumers: both may observe the entry, but
    the child's read-modify-write effect lands exactly once."""
    cluster = make_cluster()
    spec_child = WorkflowSpec("bump")

    def bump(ctx):
        raw = ctx.get("race/cnt")
        count = int(raw) if raw else 0
        ctx.put("race/cnt", str(count + 1).encode())
        return count + 1

    spec_child.step("bump", bump)
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        c1 = pool.attach_chain_consumer(
            {"bump": spec_child},
            consumer_cfg(reclaim_after_s=60.0), start=False,
        )
        c2 = pool.attach_chain_consumer(
            {"bump": spec_child},
            consumer_cfg(reclaim_after_s=60.0), start=False,
        )
        parent = WorkflowSpec("race-parent")
        parent.step("p", lambda ctx: 1)
        parent.trigger(Trigger(spec_child))
        pool.submit(parent).result(timeout=30)
        threads = [
            threading.Thread(target=c.step) for c in (c1, c2) for _ in range(2)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for c in (c1, c2):
            c.drain(timeout_s=30)
        started = (
            c1.stats["children_started"] + c2.stats["children_started"]
        )
        # dedup may allow a redundant idempotent drive, never a lost one
        assert started >= 1
    node = cluster.live_nodes()[0]
    tx = node.start_transaction()
    assert node.get(tx, "race/cnt") == b"1"
    node.abort_transaction(tx)
    cluster.stop()


def test_n_deep_chain_via_registry_factory():
    """A 4-deep pipeline where each level triggers the next through the
    registry's factory form; every level runs exactly once, in order."""
    cluster = make_cluster()
    ran = []
    depth = 4

    def level_factory(args):
        level = (args or {}).get("level", 0)
        spec = WorkflowSpec("level")

        def body(ctx, level=level):
            ran.append(level)
            ctx.put(f"deep/eff/{level}", str(level).encode())
            return {"level": level + 1}

        spec.step("body", body)
        if level + 1 < depth:
            spec.trigger(Trigger("level", args_from="body"))
        return spec

    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"level": level_factory}, consumer_cfg(), start=False
        )
        root = WorkflowSpec("root")
        root.step("seed", lambda ctx: {"level": 0})
        root.trigger(Trigger("level", args_from="seed"))
        pool.submit(root).result(timeout=30)
        assert consumer.drain(timeout_s=60)
    assert ran == [0, 1, 2, 3]
    cluster.stop()


def test_unscoped_handoff_baseline_duplicates_on_retry():
    """TxnScope.NONE: a retried parent enqueues a fresh entry per attempt —
    the duplicate-fire anomaly the durable queue eliminates."""
    storage = MemoryStorage()
    remaining = [1]
    spec = WorkflowSpec("unscoped-parent")

    def flaky(ctx):
        ctx.put("un/effect", b"x")
        if remaining[0] > 0:
            remaining[0] -= 1
            raise FunctionFailure("post-effect crash")
        return 1

    spec.step("p", flaky)
    spec.trigger(Trigger("child"))
    ex = WorkflowExecutor(
        fast_platform(), storage=storage,
        config=WorkflowConfig(scope=TxnScope.NONE, memoize=False,
                              max_attempts=4),
    )
    ex.run(spec, uuid="un-parent")
    # stage_triggers ran once... but a lost-ack re-drive stages again with a
    # fresh suffix: nothing dedups the unscoped handoff
    ex.run(spec, uuid="un-parent")
    entries = storage.list_keys(trigger_key("default",
                                            trigger_entry_id("un-parent",
                                                             "child")))
    assert len(entries) == 2  # duplicate triggers — the baseline anomaly
    storage.delete_batch(entries)


# ---------------------------------------------------------------------------
# claim bookkeeping details
# ---------------------------------------------------------------------------

def test_claim_is_deterministic_and_write_once():
    cluster = make_cluster()
    ran = []
    child = child_spec(ran)
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"child": child}, consumer_cfg(), start=False
        )
        pool.submit(parent_spec(child), uuid="claim-parent").result(30)
        assert consumer.drain(timeout_s=30)
    entry_id = trigger_entry_id("claim-parent", "child")
    storage = cluster.storage
    # exactly one claim commit, under the deterministic claim UUID
    assert storage.get(f"{UUID_PREFIX}{claim_txn_uuid(entry_id)}") is not None
    claim_commits = [
        k for k in storage.list_keys(COMMIT_PREFIX)
        if k.endswith(f".{entry_id}.claim") or claim_txn_uuid(entry_id) in k
    ]
    assert len(claim_commits) == 1
    cluster.stop()


def test_unknown_workflow_entry_parked_not_reclaimed_every_pass():
    """An entry whose spec name is missing from the registry is parked
    after one look — no claim transaction per poll pass, no unbounded
    unknown_workflows growth."""
    cluster = make_cluster()
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer({}, consumer_cfg(), start=False)
        parent = WorkflowSpec("orphan-parent")
        parent.step("p", lambda ctx: 1)
        parent.trigger(Trigger("no-such-spec"))
        pool.submit(parent).result(timeout=30)
        for _ in range(5):
            consumer.step()
        assert consumer.stats["unknown_workflows"] == 1  # parked after one
        assert consumer.stats["claims_committed"] == 0   # never claimed it
    cluster.stop()


def test_same_node_racing_claimants_defer_without_killing_shared_txn():
    """Two consumers whose claim sessions share one deterministic-UUID
    transaction context: the loser must defer WITHOUT aborting the shared
    context (which would kill the winner's in-flight claim commit)."""
    import threading as _threading

    cluster = make_cluster()
    ran = []
    child = child_spec(ran)
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumers = [
            pool.attach_chain_consumer(
                {"child": child},
                consumer_cfg(reclaim_after_s=60.0), start=False,
            )
            for _ in range(3)
        ]
        pool.submit(parent_spec(child), uuid="shared-claim").result(30)
        barrier = _threading.Barrier(len(consumers))

        def race(c):
            barrier.wait()
            c.step()

        threads = [_threading.Thread(target=race, args=(c,))
                   for c in consumers]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for c in consumers:
            c.drain(timeout_s=30)
        # nobody miscounted an abort-kill as a handoff crash, and the entry
        # was driven (claims resolved, not mutually destroyed)
        assert sum(c.stats["handoff_crashes"] for c in consumers) == 0
        assert sum(c.stats["children_started"] for c in consumers) >= 1
    assert ran == [{"payload": 41}]
    cluster.stop()


def test_spilled_trigger_entry_still_discovered_and_driven():
    """A saturated parent's write buffer spills the trigger entry to a
    uuid-derived storage key (§3.3) — only the commit record's storage-key
    map addresses it.  Discovery and payload reads must still find it, or
    a spilling parent's committed trigger would silently drop the chain."""
    from repro.core import AftNodeConfig

    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(
            num_nodes=1,
            start_background_threads=False,
            # every buffered byte saturates: ALL writes spill
            node=AftNodeConfig(write_buffer_max_bytes=1),
        ),
    )
    ran = []
    child = child_spec(ran)
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"child": child}, consumer_cfg(), start=False
        )
        pool.submit(parent_spec(child), uuid="spill-parent").result(30)
        entry_id = trigger_entry_id("spill-parent", "child")
        # the entry bytes really did land at a spill key, not the default
        prefix = f"d/{trigger_key('default', entry_id)}/"
        skeys = cluster.storage.list_keys(prefix)
        assert any("/.spill/" in k for k in skeys)
        assert list_queue_entries(cluster.storage, "default") == [entry_id]
        assert consumer.drain(timeout_s=30)
    assert ran == [{"payload": 41}]
    cluster.stop()


def test_raising_factory_parks_entry_like_unknown_spec():
    """A registry factory that raises is as unresolvable as a missing name:
    the entry is parked after one look, not hot-looped as crashes."""
    cluster = make_cluster()

    def bad_factory(args):
        raise KeyError("factory expects args it never gets")

    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"child": bad_factory}, consumer_cfg(), start=False
        )
        parent = WorkflowSpec("bad-factory-parent")
        parent.step("p", lambda ctx: 1)
        parent.trigger(Trigger("child"))
        pool.submit(parent).result(timeout=30)
        for _ in range(5):
            consumer.step()
        assert consumer.stats["unknown_workflows"] == 1
        assert consumer.stats["handoff_crashes"] == 0
        assert consumer.stats["claims_committed"] == 0
    cluster.stop()


def test_marker_ack_gate_not_vacuous_when_all_nodes_dead():
    """An empty live set must not satisfy the ack gate: only the hard
    cutoff may retire markers while every node is down (the replacement's
    agent still needs the marker's GC license)."""
    cluster = make_cluster()
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(declare_finished=True),
    )
    ex.run(parent_spec(child_spec([])), uuid="dead-wf")
    for node in cluster.live_nodes():
        node.fail()
    fm = cluster.fault_manager
    fm.config.workflow_marker_ttl_s = 0.0
    assert fm.sweep_finished_markers() == 0  # no live acks ⇒ no retirement
    fm.config.workflow_marker_max_ttl_s = 0.0
    assert fm.sweep_finished_markers() == 1  # hard cutoff still works
    cluster.stop()


def test_raised_soft_ttl_does_not_disable_ack_gating():
    """workflow_marker_ttl_s above the default max backstop must not
    silently revert to TTL-only retirement: the hard cutoff tracks
    max(soft, hard)."""
    cluster = make_cluster()
    ex = WorkflowExecutor(
        fast_platform(), cluster=cluster,
        config=WorkflowConfig(declare_finished=True),
    )
    ex.run(parent_spec(child_spec([])), uuid="slow-wf")
    fm = cluster.fault_manager
    # a "raised" soft TTL (negative = already elapsed, the test-time stand-in
    # for a large value that has passed) with the DEFAULT backstop: the hard
    # cutoff is soft + backstop, so the ack gate stays in force
    fm.config.workflow_marker_ttl_s = -10.0
    assert fm.sweep_finished_markers() == 0
    LocalGcAgent(cluster.live_nodes()[0]).step()
    assert fm.sweep_finished_markers() == 1
    cluster.stop()


def test_pool_dedupes_chain_child_whose_marker_already_exists():
    """Check-then-act closure: if a rival drive finished (and possibly
    GC'd) the child between the consumer's marker check and attempt start,
    the pool must resolve the ticket WITHOUT running any bodies — re-running
    after the u/-index sweep would re-commit under STEP scope."""
    from repro.workflow import MemoStore

    cluster = make_cluster()
    MemoStore(cluster).mark_finished("rivaled-entry")  # rival won already
    ran = []
    spec = WorkflowSpec("child")
    spec.step("consume", lambda ctx: ran.append(1) or 1)
    with WorkflowPool(
        fast_platform(), cluster=cluster,
        config=PoolConfig(scope=TxnScope.STEP),
    ) as pool:
        r = pool.submit(
            spec, uuid="rivaled-entry",
            chain_entry={"queue": "default", "entry": "rivaled-entry"},
        ).result(timeout=30)
    assert ran == []                 # no body ran, no re-commit possible
    assert r.steps_run == 0
    assert r.deduped                 # callers can tell this from a real run
    assert pool.stats["already_finished_dedups"] == 1
    cluster.stop()


def test_quarantined_chain_marker_still_reclaims_queue_entry():
    """Losing a chain child's marker payload (quarantine) must not leak its
    queue entry forever: the sweep falls back to locating the entry by the
    child uuid it IS."""
    cluster = make_cluster()
    ran = []
    child = child_spec(ran)
    with WorkflowPool(fast_platform(), cluster=cluster) as pool:
        consumer = pool.attach_chain_consumer(
            {"child": child}, consumer_cfg(), start=False
        )
        pool.submit(parent_spec(child), uuid="quar-chain").result(timeout=30)
        assert consumer.drain(timeout_s=30)
    storage = cluster.storage
    entry_id = trigger_entry_id("quar-chain", "child")
    # bit-rot the child's marker BEFORE any sweep: provenance lost
    storage.put(workflow_finish_key(entry_id), b"\x00garbage")
    cluster.fault_manager.sweep_finished_markers()  # quarantines it
    LocalGcAgent(cluster.live_nodes()[0]).step()
    assert storage.list_keys("d/q/") == []  # entry reclaimed regardless
    cluster.stop()


def test_resume_eligible_redrive_of_finished_uuid_never_reruns_bodies():
    """The attempt-start marker guard covers ANY explicit-uuid resubmit,
    not just chain children: a crashed client re-driving a finished (and
    GC-swept) STEP-scope uuid must not re-commit its steps."""
    cluster = make_cluster()
    ran = []
    spec = WorkflowSpec("redrive-guard")
    spec.step("bump", lambda ctx: ran.append(1) or 1)
    cfg = PoolConfig(scope=TxnScope.STEP)
    with WorkflowPool(fast_platform(), cluster=cluster, config=cfg) as pool:
        pool.submit(spec, uuid="rg-wf").result(timeout=30)
    assert ran == [1]
    LocalGcAgent(cluster.live_nodes()[0]).step()  # memos + u/ entries gone
    with WorkflowPool(fast_platform(), cluster=cluster, config=cfg) as pool:
        r = pool.submit(spec, uuid="rg-wf").result(timeout=30)
    assert ran == [1]  # body did NOT re-run
    assert pool.stats["already_finished_dedups"] == 1
    assert r.steps_run == 0
    cluster.stop()


def test_trigger_validation_rejects_bad_queue_names():
    spec = WorkflowSpec("badq")
    spec.step("a", lambda ctx: 1)
    spec.trigger(Trigger("x", queue="a/b"))
    with pytest.raises(WorkflowSpecError):
        spec.validate()
