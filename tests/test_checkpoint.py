"""AFT-backed checkpointing: atomicity, idempotence, torn-save invisibility."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AftCheckpointer, CheckpointNotFound
from repro.checkpoint.serializer import leaf_from_bytes, leaf_to_bytes
from repro.core import AftCluster
from repro.storage.memory import MemoryStorage


@pytest.fixture()
def cluster():
    c = AftCluster(MemoryStorage())
    yield c
    c.stop()


def _tree():
    return {"a": jnp.arange(100, dtype=jnp.float32).reshape(10, 10),
            "b": {"w": jnp.ones((7,), jnp.bfloat16),
                  "n": jnp.int32(3)}}


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32", "float16",
                                   "int8", "bool"])
def test_leaf_roundtrip(dtype):
    rng = np.random.default_rng(0)
    if dtype == "bool":
        arr = rng.random((3, 5)) > 0.5
    elif "int" in dtype:
        arr = rng.integers(-5, 120, (4, 3)).astype(dtype)
    else:
        arr = jnp.asarray(rng.standard_normal((2, 3, 4)), dtype)
    out = leaf_from_bytes(leaf_to_bytes(arr))
    np.testing.assert_array_equal(np.asarray(arr, np.float32),
                                  np.asarray(out, np.float32))


def test_save_restore_roundtrip(cluster):
    ck = AftCheckpointer(cluster.client(), run_id="t", chunk_bytes=64)
    tree = _tree()
    res = ck.save(3, tree, extra={"note": "x"})
    assert not res.deduped and res.num_keys > 3  # chunked leaves
    step, restored, extra = ck.restore(like=tree)
    assert step == 3 and extra["note"] == "x"
    np.testing.assert_array_equal(restored["a"], np.asarray(tree["a"]))
    assert restored["b"]["w"].dtype == np.asarray(tree["b"]["w"]).dtype


def test_save_is_idempotent(cluster):
    ck = AftCheckpointer(cluster.client(), run_id="t")
    ck.save(1, _tree())
    res = ck.save(1, _tree())
    assert res.deduped


def test_torn_save_is_invisible(cluster):
    ck = AftCheckpointer(cluster.client(), run_id="t", chunk_bytes=64)
    tree = _tree()
    ck.save(1, tree)

    class Boom(Exception):
        pass

    calls = []

    def failpoint(path, ci):
        calls.append(path)
        if len(calls) == 2:
            raise Boom()

    tree2 = {"a": tree["a"] * 2, "b": tree["b"]}
    with pytest.raises(Boom):
        ck.save(2, tree2, failpoint=failpoint)
    step, restored, _ = ck.restore(like=tree)
    assert step == 1
    np.testing.assert_array_equal(restored["a"], np.asarray(tree["a"]))
    # retry commits exactly once and becomes latest
    res = ck.save(2, tree2)
    assert not res.deduped
    step, restored, _ = ck.restore(like=tree)
    assert step == 2
    np.testing.assert_array_equal(restored["a"], np.asarray(tree2["a"]))


def test_restore_missing_raises(cluster):
    ck = AftCheckpointer(cluster.client(), run_id="empty")
    with pytest.raises(CheckpointNotFound):
        ck.restore()
    assert ck.latest_step() is None


def test_restore_survives_node_failure():
    """Kill the committing node; a surviving node (via the client) still
    sees the checkpoint after its bootstrap / commit-set sync — liveness
    comes from the durable commit record (§4.2)."""
    from repro.core import ClusterConfig

    c = AftCluster(MemoryStorage(), ClusterConfig(num_nodes=2))
    try:
        ck = AftCheckpointer(c.client(), run_id="t")
        tree = _tree()
        ck.save(5, tree)
        dead = c.kill_node(0)
        assert not dead.alive
        c.step_all()  # deliver pending multicast / fault-manager scan
        ck2 = AftCheckpointer(c.client(), run_id="t")
        step, restored, _ = ck2.restore(like=tree)
        assert step == 5
        np.testing.assert_array_equal(restored["a"], np.asarray(tree["a"]))
    finally:
        c.stop()
