"""TxnId total order and serialization (§3.1)."""

import pytest

from repro.core import Clock, TxnId, fresh_uuid


def test_order_by_timestamp_then_uuid():
    a = TxnId(1, "bbbb")
    b = TxnId(2, "aaaa")
    c = TxnId(2, "bbbb")
    assert a < b < c
    assert not (b < a)
    assert max(a, b, c) == c


def test_ties_broken_lexicographically_without_coordination():
    # identical timestamps on two nodes: UUIDs give a total order (§3.1)
    a = TxnId(7, "0a")
    b = TxnId(7, "0b")
    assert a < b and b > a and a != b


def test_encode_preserves_order():
    ids = [TxnId(5, "x"), TxnId(40, "a"), TxnId(40, "b"), TxnId(1234567, "z")]
    encoded = [t.encode() for t in ids]
    assert sorted(encoded) == [t.encode() for t in sorted(ids)]
    for t in ids:
        assert TxnId.decode(t.encode()) == t


def test_clock_strictly_monotonic():
    clk = Clock()
    seen = [clk.now_ns() for _ in range(1000)]
    assert all(b > a for a, b in zip(seen, seen[1:]))


def test_clock_skew_does_not_break_order_semantics():
    # correctness never relies on synchronized clocks: IDs from skewed clocks
    # still totally ordered
    past = Clock(skew_ns=-10**12)
    future = Clock(skew_ns=+10**12)
    a = TxnId(past.now_ns(), fresh_uuid())
    b = TxnId(future.now_ns(), fresh_uuid())
    assert a < b or b < a


def test_hash_and_equality():
    t = TxnId(3, "u")
    assert t == TxnId(3, "u")
    assert len({t, TxnId(3, "u"), TxnId(4, "u")}) == 2
