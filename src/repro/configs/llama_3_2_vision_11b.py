"""llama-3.2-vision-11b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  Every 5th layer
cross-attends to stub vision patch embeddings (B, 1600, d_model) provided by
``input_specs()`` — the vision tower is a STUB per the assignment.
"""

from repro.models.config import ATTN, CROSS, ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    pattern=(ATTN, ATTN, ATTN, ATTN, CROSS),
    pattern_repeats=8,
    vision_seq=1600,
    rope_theta=500_000.0,
))
