"""llama4-scout-17b-a16e — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 + shared expert on alternating layers (interleaved dense/MoE).
"""

from repro.models.config import DENSE, MOE, ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    pattern=(DENSE, MOE),
    pattern_repeats=24,
    num_experts=16,
    experts_per_token=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    rope_theta=500_000.0,
))
