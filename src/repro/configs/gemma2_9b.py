"""gemma2-9b — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000; head_dim=256,
sliding window 4096 on local layers, attn softcap 50, final logit softcap 30,
sandwich (post-block) norms, tied embeddings.
"""

from repro.models.config import ATTN, LOCAL, ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma2-9b",
    family="dense",
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    pattern=(LOCAL, ATTN),
    pattern_repeats=21,
    head_dim=256,
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_block_norm=True,
    tie_embeddings=True,
    act="geglu",
))
