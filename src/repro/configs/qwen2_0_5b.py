"""qwen2-0.5b — GQA, QKV bias [arXiv:2407.10671; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""

from repro.models.config import ATTN, ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    pattern=(ATTN,),
    pattern_repeats=24,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
))
