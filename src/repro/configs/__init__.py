"""Assigned-architecture registry: one module per architecture.

Importing this package registers every config; select with
``repro.models.config.get_config(name)`` or ``--arch <name>`` in the
launchers.
"""

from . import (  # noqa: F401
    gemma2_9b,
    kimi_k2_1t_a32b,
    llama4_scout_17b_a16e,
    llama_3_2_vision_11b,
    qwen1_5_110b,
    qwen2_0_5b,
    tinyllama_1_1b,
    whisper_medium,
    xlstm_350m,
    zamba2_7b,
)

from repro.models.config import get_config, list_configs  # noqa: F401
