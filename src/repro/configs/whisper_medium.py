"""whisper-medium — enc-dec, conv frontend (stub) [arXiv:2212.04356;
unverified].

24L (decoder; + 24 encoder layers) d_model=1024 16H (kv=16) d_ff=4096
vocab=51865.  The audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, 1500, d_model); the encoder is a
bidirectional attention stack and the decoder uses cross-attention blocks.
"""

from repro.models.config import CROSS, ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="audio",
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    pattern=(CROSS,),
    pattern_repeats=24,
    encoder_layers=24,
    encoder_seq=1500,
    act="gelu",
))
