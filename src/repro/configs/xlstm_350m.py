"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 vocab=50304.  3:1 mLSTM:sLSTM interleave
(the sLSTM blocks carry the true recurrence; mLSTM blocks are the
chunkwise-parallel matrix-memory form).
"""

from repro.models.config import MLSTM, SLSTM, ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=(MLSTM, MLSTM, MLSTM, SLSTM),
    pattern_repeats=6,
    tie_embeddings=True,
    ssm_chunk=256,
))
