"""kimi-k2-1t-a32b — trillion-param MoE (paper-table) [arXiv:2501.kimi2;
unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384 experts
top-8 + 1 shared expert; one dense layer (placed as the tail block here —
the pattern scan carries the 60 MoE layers).
"""

from repro.models.config import DENSE, MOE, ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    pattern=(MOE,),
    pattern_repeats=60,
    tail=(DENSE,),
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
))
