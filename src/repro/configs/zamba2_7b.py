"""zamba2-7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Layout: 5 Mamba-2 blocks then one application of the *shared* attention+MLP
block (weights shared across all applications; per-application norms),
repeated 13× (= 78 layers), plus a 3-Mamba tail → 81 layers.
"""

from repro.models.config import MAMBA2, SHARED_ATTN, ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    pattern=(MAMBA2, MAMBA2, MAMBA2, MAMBA2, MAMBA2, SHARED_ATTN),
    pattern_repeats=13,
    tail=(MAMBA2, MAMBA2, MAMBA2),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
))
