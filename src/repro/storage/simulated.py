"""Simulated cloud storage engines.

The paper evaluates AFT over AWS S3, AWS DynamoDB, and Redis (ElastiCache).
This container has no cloud, so we reproduce each engine as a latency +
consistency *model* wrapped around an in-process store.  Parameters are
calibrated to the medians/tails reported in §6 (Fig 2, Fig 3):

=============  ========  =========  ======================  =================
engine         op median  tail       batching                consistency
=============  ========  =========  ======================  =================
S3-like        ~18 ms    heavy      none                    new keys RAW; in-
                                                            place overwrites
                                                            eventually visible
DynamoDB-like  ~4 ms     moderate   BatchWriteItem-style    same as S3-like
Redis-like     ~0.6 ms   light      MSET within one shard   per-shard
                                                            linearizable
=============  ========  =========  ======================  =================

The consistency model captures the one property AFT actually exploits: 2020-era
S3/DynamoDB gave read-after-write for **fresh keys** but only eventual
consistency for overwrites.  AFT writes every version to a fresh key (§3.3), so
it is immune; the "plain" baselines of §6.1.2 overwrite in place, which is the
source of their RYW/FR anomalies (Table 2) together with non-atomic
interleaving.

``time_scale`` shrinks every sleep proportionally so the full benchmark suite
fits in CI while preserving latency *ratios*; reported numbers are divided by
the scale to recover engine-calibrated milliseconds.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

from .base import StorageEngine
from .memory import MemoryStorage
from .sharded import ShardedStorage


@dataclass
class LatencyModel:
    """Lognormal-ish per-op latency: ``base + per_kb·size`` with a tail."""

    base_ms: float
    per_kb_ms: float = 0.0
    sigma: float = 0.25          # lognormal shape; bigger ⇒ heavier tail
    batch_base_ms: float = -1.0  # <0 ⇒ batching unsupported
    batch_per_item_ms: float = 0.0

    def sample(self, rng: random.Random, size_kb: float = 0.0) -> float:
        mu = self.base_ms + self.per_kb_ms * size_kb
        return mu * rng.lognormvariate(0.0, self.sigma)

    def sample_batch(self, rng: random.Random, n: int, size_kb: float) -> float:
        mu = self.batch_base_ms + self.batch_per_item_ms * n + self.per_kb_ms * size_kb
        return mu * rng.lognormvariate(0.0, self.sigma)


class SimulatedEngine(StorageEngine):
    """Latency + consistency simulation over an inner engine."""

    def __init__(
        self,
        inner: Optional[StorageEngine] = None,
        *,
        read: LatencyModel,
        write: LatencyModel,
        overwrite_visibility_lag_ms: float = 0.0,
        time_scale: float = 1.0,
        seed: int = 0,
        name: str = "sim",
    ) -> None:
        self.inner = inner if inner is not None else MemoryStorage()
        self.read_model = read
        self.write_model = write
        self.lag_ms = overwrite_visibility_lag_ms
        self.time_scale = time_scale
        self.name = name
        self.supports_batch = write.batch_base_ms >= 0
        self.supports_batch_get = read.batch_base_ms >= 0
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()
        # overwrite consistency: key → (old value, visible_at) while the new
        # value is still propagating.  Fresh keys are never entered here.
        self._stale: Dict[str, tuple] = {}
        self._stale_lock = threading.Lock()
        self._op_ms_total = 0.0
        self._ops = 0

    # -- internals -----------------------------------------------------------
    def _sleep(self, ms: float) -> None:
        self._op_ms_total += ms
        self._ops += 1
        scaled = ms * self.time_scale / 1e3
        if scaled > 0:
            time.sleep(scaled)

    def _sample(self, model_fn, *args) -> float:
        with self._rng_lock:
            return model_fn(self._rng, *args)

    def _note_overwrite(self, key: str, old: Optional[bytes]) -> None:
        if self.lag_ms <= 0 or old is None:
            return
        lag = self._sample(
            LatencyModel(base_ms=self.lag_ms, sigma=0.6).sample
        )
        visible_at = time.monotonic() + lag * self.time_scale / 1e3
        with self._stale_lock:
            self._stale[key] = (old, visible_at)

    def _maybe_stale(self, key: str, fresh: Optional[bytes]) -> Optional[bytes]:
        if self.lag_ms <= 0:
            return fresh
        with self._stale_lock:
            ent = self._stale.get(key)
            if ent is None:
                return fresh
            old, visible_at = ent
            if time.monotonic() >= visible_at:
                del self._stale[key]
                return fresh
            return old

    # -- StorageEngine -------------------------------------------------------
    def put(self, key: str, value: bytes) -> None:
        old = self.inner.get(key) if self.lag_ms > 0 else None
        self._sleep(self._sample(self.write_model.sample, len(value) / 1024))
        self.inner.put(key, value)
        self._note_overwrite(key, old)

    def get(self, key: str) -> Optional[bytes]:
        self._sleep(self._sample(self.read_model.sample, 0.0))
        fresh = self.inner.get(key)
        return self._maybe_stale(key, fresh)

    def delete(self, key: str) -> None:
        self._sleep(self._sample(self.write_model.sample, 0.0))
        self.inner.delete(key)
        with self._stale_lock:
            self._stale.pop(key, None)

    def put_batch(self, items: Dict[str, bytes]) -> None:
        if not items:
            return
        if not self.supports_batch:
            for k, v in items.items():
                self.put(k, v)
            return
        olds = (
            {k: self.inner.get(k) for k in items} if self.lag_ms > 0 else {}
        )
        size_kb = sum(len(v) for v in items.values()) / 1024
        self._sleep(self._sample(self.write_model.sample_batch, len(items), size_kb))
        self.inner.put_batch(items)
        for k, old in olds.items():
            self._note_overwrite(k, old)

    def get_batch(self, keys: Iterable[str]) -> Dict[str, Optional[bytes]]:
        keys = list(keys)
        if not keys:
            return {}
        if self.read_model.batch_base_ms >= 0:
            self._sleep(self._sample(self.read_model.sample_batch, len(keys), 0.0))
            return {k: self._maybe_stale(k, self.inner.get(k)) for k in keys}
        return {k: self.get(k) for k in keys}

    def delete_batch(self, keys: Iterable[str]) -> None:
        keys = list(keys)
        if not keys:
            return
        if self.supports_batch:
            self._sleep(self._sample(self.write_model.sample_batch, len(keys), 0.0))
            self.inner.delete_batch(keys)
        else:
            for k in keys:
                self.delete(k)

    def list_keys(self, prefix: str = "") -> List[str]:
        self._sleep(self._sample(self.read_model.sample, 0.0))
        return self.inner.list_keys(prefix)

    def stats(self) -> Dict[str, int]:
        s = dict(self.inner.stats())
        s["sim_ops"] = self._ops
        s["sim_ms_total"] = int(self._op_ms_total)
        return s


# ---------------------------------------------------------------------------
# presets calibrated against §6 (Fig 2 / Fig 3)
# ---------------------------------------------------------------------------

def s3_like(time_scale: float = 1.0, seed: int = 0) -> SimulatedEngine:
    """Throughput-oriented object store: high base latency, heavy write tail,
    no batching, poor small-object random IO (§6.1.2)."""
    return SimulatedEngine(
        read=LatencyModel(base_ms=11.0, per_kb_ms=0.05, sigma=0.45),
        write=LatencyModel(base_ms=22.0, per_kb_ms=0.10, sigma=0.65),
        overwrite_visibility_lag_ms=80.0,
        time_scale=time_scale,
        seed=seed,
        name="s3",
    )


def dynamodb_like(
    time_scale: float = 1.0,
    seed: int = 0,
    inner: Optional[StorageEngine] = None,
) -> SimulatedEngine:
    """Cloud KVS: ~4 ms ops, BatchWriteItem-style batching (25 items/call).
    ``inner`` substitutes the backing store (e.g. an instrumented recorder
    for write-ordering audits, ``benchmarks/fig_async.py``)."""
    return SimulatedEngine(
        inner,
        # BatchGetItem-style read batching, same shape as the write side
        read=LatencyModel(
            base_ms=3.6,
            per_kb_ms=0.02,
            sigma=0.30,
            batch_base_ms=4.8,
            batch_per_item_ms=0.35,
        ),
        write=LatencyModel(
            base_ms=4.2,
            per_kb_ms=0.02,
            sigma=0.35,
            batch_base_ms=5.5,
            batch_per_item_ms=0.45,
        ),
        overwrite_visibility_lag_ms=25.0,
        time_scale=time_scale,
        seed=seed,
        name="dynamodb",
    )


def redis_like(
    time_scale: float = 1.0, seed: int = 0, shards: int = 2
) -> ShardedStorage:
    """Memory-speed KVS in cluster mode: per-shard linearizable, MSET only
    within a shard (§6.1.2), so cross-shard batches degrade to per-key puts."""
    def make_shard(i: int) -> SimulatedEngine:
        return SimulatedEngine(
            read=LatencyModel(base_ms=0.55, per_kb_ms=0.01, sigma=0.20),
            write=LatencyModel(
                base_ms=0.65,
                per_kb_ms=0.01,
                sigma=0.20,
                batch_base_ms=0.8,
                batch_per_item_ms=0.05,
            ),
            overwrite_visibility_lag_ms=0.0,  # linearizable per shard
            time_scale=time_scale,
            seed=seed * 1000 + i,
            name=f"redis-shard{i}",
        )

    return ShardedStorage([make_shard(i) for i in range(shards)], name="redis")


ENGINE_PRESETS = {
    "s3": s3_like,
    "dynamodb": dynamodb_like,
    "redis": redis_like,
    "memory": lambda time_scale=1.0, seed=0: MemoryStorage(),
}


def make_engine(name: str, time_scale: float = 1.0, seed: int = 0) -> StorageEngine:
    try:
        factory = ENGINE_PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; options: {sorted(ENGINE_PRESETS)}"
        ) from None
    return factory(time_scale=time_scale, seed=seed)
