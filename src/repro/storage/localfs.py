"""File-backed durable storage engine.

One file per key beneath a root directory.  Writes go through a temp file +
``os.replace`` so a crash never leaves a torn value — this is the engine's
"durable once acknowledged" contract (§3.1); everything above it (atomic
multi-key visibility) is AFT's job.  Survives process restarts, which the
crash/resume training examples and tests rely on.
"""

from __future__ import annotations

import os
import tempfile
import threading
import urllib.parse
from typing import Dict, List, Optional

from .base import StorageEngine


def _encode(key: str) -> str:
    # '/' kept readable as directory separators; every other risky char quoted.
    return "/".join(urllib.parse.quote(part, safe="") for part in key.split("/"))


def _decode(path: str) -> str:
    return "/".join(urllib.parse.unquote(part) for part in path.split("/"))


class LocalFSStorage(StorageEngine):
    supports_batch = True  # a batch is a loop of renames, but one fsync policy

    def __init__(self, root: str, fsync: bool = False) -> None:
        self.root = os.path.abspath(root)
        self.fsync = fsync
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()

    # -- helpers -------------------------------------------------------------
    def _path(self, key: str) -> str:
        return os.path.join(self.root, _encode(key))

    def _write_atomic(self, path: str, value: bytes) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(value)
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- StorageEngine -------------------------------------------------------
    def put(self, key: str, value: bytes) -> None:
        self._write_atomic(self._path(key), value)

    def get(self, key: str) -> Optional[bytes]:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass

    def put_batch(self, items: Dict[str, bytes]) -> None:
        for k, v in items.items():
            self.put(k, v)

    def list_keys(self, prefix: str = "") -> List[str]:
        out: List[str] = []
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for name in filenames:
                if name.startswith(".tmp-"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, name), self.root)
                key = _decode(rel.replace(os.sep, "/"))
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)
