"""Storage engine interface.

AFT's *only* requirement of the storage layer (§3.1): an update is durable
once acknowledged.  No consistency, visibility, partitioning, or transactional
guarantees are assumed — those are exactly what the shim provides above.

One subtlety the protocols rely on (and that made AFT deployable over
2020-era S3): AFT only ever writes **fresh keys** (a unique storage key per
version, §3.3), so it needs read-after-write visibility for *new* keys only,
never read-after-overwrite.  The eventually-consistent wrapper in
``simulated.py`` models precisely that distinction, which is how the plain
baselines of §6.1.2 exhibit anomalies while AFT, over the same engine, does
not.
"""

from __future__ import annotations

import abc
from typing import Dict, Iterable, List, Optional


class StorageUnsupported(Exception):
    """Raised when an engine does not support an optional operation."""


class StorageEngine(abc.ABC):
    """A durable key → bytes store."""

    #: whether ``put_batch`` persists many keys in one round trip (DynamoDB
    #: ``BatchWriteItem`` style).  Engines without it still accept
    #: ``put_batch`` but pay per-key latency (Redis-cluster style, §6.1.2).
    supports_batch: bool = False

    #: whether ``get_batch`` fetches many keys in one round trip (DynamoDB
    #: ``BatchGetItem`` style).  When False, ``get_batch`` degrades to a
    #: per-key loop, so callers wanting read parallelism should issue
    #: concurrent point gets instead (``storage/pipeline.py`` does).
    supports_batch_get: bool = False

    #: latency compression factor of a *simulated* engine (``simulated.py``):
    #: every modeled sleep is multiplied by it so benchmark suites fit in CI.
    #: Protocol-level wall-clock waits (read-retry backoff in ``AftNode``)
    #: must scale by the same factor or a single transient miss sleeps
    #: orders of magnitude longer than the op it waits on.  Real engines
    #: leave the default of 1.0.
    time_scale: float = 1.0

    @abc.abstractmethod
    def put(self, key: str, value: bytes) -> None:
        """Durably persist ``value`` at ``key``.  Returns only once durable."""

    @abc.abstractmethod
    def get(self, key: str) -> Optional[bytes]:
        """Fetch ``key``, or ``None`` if absent (or not yet visible)."""

    @abc.abstractmethod
    def delete(self, key: str) -> None:
        ...

    @abc.abstractmethod
    def list_keys(self, prefix: str = "") -> List[str]:
        """All keys with the given prefix, sorted lexicographically."""

    # -- batched variants (default: loop) -----------------------------------
    def put_batch(self, items: Dict[str, bytes]) -> None:
        for k, v in items.items():
            self.put(k, v)

    def get_batch(self, keys: Iterable[str]) -> Dict[str, Optional[bytes]]:
        return {k: self.get(k) for k in keys}

    def delete_batch(self, keys: Iterable[str]) -> None:
        for k in keys:
            self.delete(k)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:  # pragma: no cover - trivial
        pass

    # -- introspection (benchmark harness) -----------------------------------
    def stats(self) -> Dict[str, int]:
        return {}
