"""Hash-sharded storage (Redis-cluster semantics).

Each shard is independently linearizable but no guarantee spans shards; a
multi-key write (``MSET``) can only batch keys that land on one shard
(§6.1.2), so AFT "cannot consistently batch updates" over this engine — the
put_batch below groups by shard and issues one call per shard touched.
"""

from __future__ import annotations

import zlib
from collections import defaultdict
from typing import Dict, Iterable, List, Optional

from .base import StorageEngine


class ShardedStorage(StorageEngine):
    def __init__(self, shards: List[StorageEngine], name: str = "sharded") -> None:
        if not shards:
            raise ValueError("need at least one shard")
        self.shards = shards
        self.name = name
        # batching helps only when all keys co-locate; callers shouldn't rely
        # on a single round trip.
        self.supports_batch = any(s.supports_batch for s in shards)
        self.supports_batch_get = any(
            getattr(s, "supports_batch_get", False) for s in shards
        )
        # retry backoff (AftNode._fetch) scales with the fastest shard — a
        # miss should never out-sleep the op it waits on
        self.time_scale = min(
            getattr(s, "time_scale", 1.0) for s in shards
        )

    def _shard(self, key: str) -> StorageEngine:
        return self.shards[zlib.crc32(key.encode()) % len(self.shards)]

    def put(self, key: str, value: bytes) -> None:
        self._shard(key).put(key, value)

    def get(self, key: str) -> Optional[bytes]:
        return self._shard(key).get(key)

    def delete(self, key: str) -> None:
        self._shard(key).delete(key)

    def put_batch(self, items: Dict[str, bytes]) -> None:
        groups: Dict[int, Dict[str, bytes]] = defaultdict(dict)
        for k, v in items.items():
            groups[zlib.crc32(k.encode()) % len(self.shards)][k] = v
        for idx, group in groups.items():
            self.shards[idx].put_batch(group)

    def get_batch(self, keys: Iterable[str]) -> Dict[str, Optional[bytes]]:
        groups: Dict[int, List[str]] = defaultdict(list)
        for k in keys:
            groups[zlib.crc32(k.encode()) % len(self.shards)].append(k)
        out: Dict[str, Optional[bytes]] = {}
        for idx, group in groups.items():
            out.update(self.shards[idx].get_batch(group))
        return out

    def delete_batch(self, keys: Iterable[str]) -> None:
        groups: Dict[int, List[str]] = defaultdict(list)
        for k in keys:
            groups[zlib.crc32(k.encode()) % len(self.shards)].append(k)
        for idx, group in groups.items():
            self.shards[idx].delete_batch(group)

    def list_keys(self, prefix: str = "") -> List[str]:
        out: List[str] = []
        for s in self.shards:
            out.extend(s.list_keys(prefix))
        return sorted(out)

    def stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = defaultdict(int)
        for s in self.shards:
            for k, v in s.stats().items():
                agg[k] += v
        return dict(agg)
