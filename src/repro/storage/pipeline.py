"""StorageIOPipeline — asynchronous storage I/O with cross-transaction
group commit (§6.1.1 taken to its conclusion).

AFT's overhead is dominated by storage round trips.  The paper batches one
transaction's updates into a single ``put_batch`` (§6.1.1) and its Go
implementation parallelizes *all* storage operations; this module is that
lesson applied across transactions:

* **group commit** — concurrent committers hand their version writes to the
  pipeline as *put groups* (:meth:`StorageIOPipeline.submit_puts`); a flusher
  coalesces pending groups from *different* transactions into shared
  ``put_batch`` flushes (DynamoDB ``BatchWriteItem``-style, up to
  ``flush_max_items`` per call), so under load the per-call base latency is
  paid once per flush instead of once per transaction.  Each group resolves
  its future only when **all** of its items are durable — the §3.3 ordering
  barrier is per *transaction*, never per flush: a caller chains its commit
  record behind its version group's future, and because the record is only
  submitted after that future resolves, no coalescing schedule can reorder a
  record ahead of its own versions (they are never in the same flush);
* **pipelined reads** — :meth:`get_many` fans point reads across the worker
  pool (cloud KVSes serve independent gets concurrently; only the *caller*
  was serial), used by ``AftNode`` to prefetch a commit record's cowritten
  keys while the foreground read returns;
* **coalesced deletes** — GC sweeps enqueue doomed keys
  (:meth:`submit_deletes`); the flusher folds them into shared
  ``delete_batch`` calls so background reclamation stops stalling foreground
  commits on per-key round trips;
* **stats** — queue depth, coalesce ratio (groups per flush), flush sizes,
  and queue-wait times, surfaced through ``AftNode.stats()`` and the
  ``benchmarks/report.py --section io`` table.

Failure injection: ``fault_hook`` (when set) is called around every flush
with a site name and the flush's keys; a hook that raises models a node
dying mid-flush.  Sites:

* ``pipeline:flush`` — before the storage call: nothing in this flush lands;
* ``pipeline:flush-landed`` — after the storage call but before any group
  future resolves: the bytes are durable but the committer never hears the
  ack (the §3.3.1 lost-ack window, now at flush granularity);
* ``pipeline:delete-flush`` — before a coalesced delete batch: a GC sweep
  dies mid-reclamation (the agent withholds its marker ack and re-sweeps).

Either way the affected transactions' commit futures fail, the attempt
retries under the same UUID, and the write-ordering protocol keeps the
outcome exactly-once — ``benchmarks/fig_async.py`` audits precisely this.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Deque, Dict, Iterable, List, Optional, Tuple

from ..obs import trace as obs_trace
from ..obs.registry import Registry
from .base import StorageEngine


@dataclass
class PipelineConfig:
    io_workers: int = 4           # threads for reads / probes / tasks
    flush_max_items: int = 25     # DynamoDB BatchWriteItem page size
    # group-commit linger, in ENGINE milliseconds: scaled by the storage's
    # time_scale (like every other latency in the simulation), so the wait
    # stays proportional to the flush it amortizes.  ~1/2 of a batch-write
    # round trip: long enough to fill a batch under load; an idle pipeline
    # (no flush on the wire) skips it entirely.
    flush_linger_ms: float = 8.0
    # flushes on the wire at once.  Deliberately SMALL: while the slots are
    # busy, arriving groups pile up and the next gather packs a full batch —
    # group commit emerges from bounded concurrency, the way a WAL writer
    # coalesces behind the previous fsync.  Raising it trades coalescing
    # for parallel wire time; 2 keeps one flush filling while one flies.
    flush_concurrency: int = 2
    name: str = "io"


class _Group:
    """One caller's batch of same-kind ops; its future is the caller's
    per-transaction durability barrier.  A large group may be split across
    several flushes running on different workers, so the remaining-items
    countdown is guarded by a per-group lock; the future fires outside it
    (callbacks run inline on the resolving thread)."""

    __slots__ = ("items", "remaining", "future", "enqueued_at", "site",
                 "lock", "settled")

    def __init__(self, items, site: str):
        self.items = items            # dict (puts) or list (deletes)
        self.remaining = len(items)
        self.future: Future = Future()
        self.enqueued_at = time.perf_counter()
        self.site = site
        self.lock = threading.Lock()
        self.settled = False


class StorageIOPipeline:
    """Worker pool + group-commit flusher in front of a StorageEngine."""

    def __init__(
        self,
        storage: StorageEngine,
        config: Optional[PipelineConfig] = None,
        *,
        registry: Optional[Registry] = None,
    ) -> None:
        self.storage = storage
        self.config = config or PipelineConfig()
        # per-site flush latency + queue wait land in the owner's registry
        # (an AftNode shares its own); a standalone pipeline grows a private
        # one so the instrumentation below never needs a None check
        self.registry = registry or Registry(
            name=self.config.name,
            time_scale=getattr(storage, "time_scale", 1.0),
        )
        self._h_flush = self.registry.histogram("site:pipeline:flush")
        self._h_delete_flush = self.registry.histogram(
            "site:pipeline:delete-flush")
        self._h_queue_wait = self.registry.histogram("pipeline.queue_wait")
        # test/benchmark injection point; see module docstring
        self.fault_hook: Optional[Callable[[str, List[str]], None]] = None
        self._lock = threading.Condition()
        self._put_q: Deque[Tuple[_Group, List[str]]] = deque()
        self._del_q: Deque[Tuple[_Group, List[str]]] = deque()
        # pipelined reads: (key, future, enqueued_at) coalesced into
        # BatchGetItem-style get_batch calls on engines that support them
        self._get_q: Deque[Tuple[str, Future, float]] = deque()
        self._batch_get = bool(getattr(storage, "supports_batch_get", False))
        self._pending_put_items = 0
        self._inflight_flushes = 0
        self._inflight_gets = 0
        self._inflight_direct = 0  # point gets / tasks on the worker pool
        self._closed = False
        self._stats_lock = threading.Lock()
        self._s = {
            "put_groups": 0,
            "put_items": 0,
            "flushes": 0,            # SUCCESSFUL put flushes only
            "flushed_items": 0,
            "flushed_bytes": 0,      # value bytes landed by successful
                                     # put flushes (commit records ride the
                                     # encode-once cache, so this now meters
                                     # wire bytes, not re-serialization work)
            "flush_groups": 0,       # Σ distinct groups per flush
            "flush_failures": 0,
            "flush_size_max": 0,
            "delete_flushes": 0,
            "deleted_keys": 0,
            "gets": 0,
            "get_batches": 0,
            "batched_gets": 0,
            "tasks": 0,
            "depth_max": 0,
            "queue_wait_s_total": 0.0,
            "queue_wait_samples": 0,
            "faults_injected": 0,
        }
        # Two pools: flushes get dedicated threads so a burst of queued
        # tasks (commit probes, prefetch reads) can never wedge itself
        # ahead of the flush that would drain the backlog.  The semaphore
        # gates the flusher at one outstanding flush per flush thread —
        # while every slot is on the wire, incoming groups accumulate and
        # the next gather packs a full batch (group commit emerges from
        # backpressure, not from waiting).
        workers = max(self.config.io_workers, 1)
        flushers = max(self.config.flush_concurrency, 1)
        self._workers = ThreadPoolExecutor(
            max_workers=workers,
            thread_name_prefix=f"{self.config.name}-worker",
        )
        self._flush_pool = ThreadPoolExecutor(
            max_workers=flushers,
            thread_name_prefix=f"{self.config.name}-flush",
        )
        self._flush_slots = threading.Semaphore(flushers)
        self._flusher = threading.Thread(
            target=self._flush_loop, name=f"{self.config.name}-flusher",
            daemon=True,
        )
        self._flusher.start()

    # ------------------------------------------------------------------- api
    def submit_puts(self, items: Dict[str, bytes]) -> "Future[None]":
        """Enqueue one transaction's writes; the returned future resolves
        once EVERY item is durable (possibly across several shared flushes).
        Empty groups resolve immediately."""
        group = _Group(dict(items), "pipeline:flush")
        if not group.items:
            group.future.set_result(None)
            return group.future
        keys = list(group.items.keys())
        with self._lock:
            if self._closed:
                raise RuntimeError("StorageIOPipeline is closed")
            self._put_q.append((group, keys))
            self._pending_put_items += len(keys)
            self._note_depth_locked()
            self._lock.notify_all()
        with self._stats_lock:
            self._s["put_groups"] += 1
            self._s["put_items"] += len(keys)
        return group.future

    def submit_put(self, key: str, value: bytes) -> "Future[None]":
        """Single put through the same coalescer — concurrent callers'
        singles (e.g. commit records of independent transactions) share
        flushes too."""
        return self.submit_puts({key: value})

    def submit_deletes(self, keys: Iterable[str]) -> "Future[None]":
        """Enqueue idempotent deletes (GC sweeps); coalesced into shared
        ``delete_batch`` calls off the caller's thread."""
        group = _Group(list(keys), "pipeline:delete")
        if not group.items:
            group.future.set_result(None)
            return group.future
        with self._lock:
            if self._closed:
                raise RuntimeError("StorageIOPipeline is closed")
            self._del_q.append((group, list(group.items)))
            self._note_depth_locked()
            self._lock.notify_all()
        return group.future

    def submit_get(self, key: str) -> "Future[Optional[bytes]]":
        """Pipelined point read.  On engines with true batch gets
        (``supports_batch_get``) concurrent callers' reads coalesce into
        shared ``get_batch`` round trips — the read-side twin of group
        commit; otherwise each read fans out to the worker pool.

        The future resolves on a pipeline thread; callbacks must not block
        on other pipeline futures (chain with ``add_done_callback``)."""
        with self._stats_lock:
            self._s["gets"] += 1
        if not self._batch_get:
            return self._submit_tracked(self.storage.get, key)
        fut: "Future[Optional[bytes]]" = Future()
        with self._lock:
            if self._closed:
                raise RuntimeError("StorageIOPipeline is closed")
            self._get_q.append((key, fut, time.perf_counter()))
            self._note_depth_locked()
            self._lock.notify_all()
        return fut

    def get_many(self, keys: Iterable[str]) -> Dict[str, Optional[bytes]]:
        """Pipelined multi-key read: all keys fetched concurrently (and
        coalesced where the engine batches); blocks the caller only for the
        slowest round trip, not the sum (the pre-pipeline ``for k:
        storage.get(k)`` shape).  Never call from a pipeline thread."""
        futs = {k: self.submit_get(k) for k in keys}
        return {k: f.result() for k, f in futs.items()}

    def submit_task(self, fn: Callable, *args) -> Future:
        """Run arbitrary storage-touching work on the worker pool (commit
        offload, prefetch).  Tasks must not block on pipeline futures —
        batch-get resolution shares these workers."""
        with self._stats_lock:
            self._s["tasks"] += 1
        return self._submit_tracked(fn, *args)

    def _submit_tracked(self, fn: Callable, *args) -> Future:
        """Worker-pool submission that drain() can see.  The returned
        future resolves (callbacks included — they may enqueue follow-up
        writes) BEFORE the in-flight count drops, so a drain can never slip
        through the instant between a probe's completion and the commit
        writes it chains."""
        with self._lock:
            self._inflight_direct += 1
        out: Future = Future()

        def run() -> None:
            try:
                try:
                    out.set_result(fn(*args))  # callbacks run inline here
                except BaseException as e:  # noqa: BLE001 - via future
                    out.set_exception(e)
            finally:
                with self._lock:
                    self._inflight_direct -= 1
                    self._lock.notify_all()

        self._workers.submit(run)
        return out

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until everything enqueued before this call has flushed."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while (
                self._put_q or self._del_q or self._get_q
                or self._inflight_flushes or self._inflight_gets
                or self._inflight_direct
            ):
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError("pipeline drain timed out")
                self._lock.wait(remaining)

    def stats(self) -> Dict[str, float]:
        with self._stats_lock:
            s = dict(self._s)
        with self._lock:
            s["depth"] = (
                len(self._put_q) + len(self._del_q) + len(self._get_q)
            )
            s["inflight_flushes"] = self._inflight_flushes
        flushes = max(s["flushes"], 1)
        s["coalesce_ratio"] = round(s["flush_groups"] / flushes, 3)
        s["mean_flush_items"] = round(s["flushed_items"] / flushes, 3)
        waits = max(s.pop("queue_wait_samples"), 1)
        s["mean_queue_wait_ms"] = round(
            s.pop("queue_wait_s_total") / waits * 1e3, 4
        )
        return s

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._lock.notify_all()
        self._flusher.join(timeout=10)
        self._flush_pool.shutdown(wait=True)
        self._workers.shutdown(wait=True)

    # --------------------------------------------------------------- flusher
    def _note_depth_locked(self) -> None:
        depth = len(self._put_q) + len(self._del_q) + len(self._get_q)
        with self._stats_lock:
            if depth > self._s["depth_max"]:
                self._s["depth_max"] = depth

    def _flush_loop(self) -> None:
        cfg = self.config
        linger_s = (
            cfg.flush_linger_ms
            * getattr(self.storage, "time_scale", 1.0)
            / 1e3
        )
        while True:
            with self._lock:
                while (
                    not self._put_q and not self._del_q and not self._get_q
                    and not self._closed
                ):
                    self._lock.wait()
                if (
                    self._closed
                    and not self._put_q and not self._del_q and not self._get_q
                ):
                    return
                # dispatch coalesced batch-gets FIRST and without slot
                # gating: reads resolve commit probes and prefetches, and
                # must never queue behind write flushes
                self._dispatch_gets_locked(cfg.flush_max_items, linger_s)
                if not self._put_q and not self._del_q:
                    continue  # reads fully drained; wait for more work
            # wait for a free flush slot OUTSIDE the lock: submitters never
            # block, and the backlog that builds while all slots are on the
            # wire is exactly what fills the next batch.  Poll rather than
            # park — reads arriving while every slot is on the wire must
            # still dispatch (they gate commit records via the §3.3.1
            # probe), so keep draining the get queue between attempts.
            while not self._flush_slots.acquire(timeout=0.002):
                with self._lock:
                    self._dispatch_gets_locked(cfg.flush_max_items, linger_s)
            with self._lock:
                # linger until the batch FILLS or this batch's linger
                # budget runs out.  Without the fill condition the system
                # is bistable: tiny eager flushes keep slots free which
                # keeps flushes tiny (4× the wire time of the coalesced
                # regime).  The budget is measured from BATCH START, not
                # from the oldest group's age — under steady arrival the
                # queue front is always already "old", and an age-based
                # deadline degenerates into eager ~2/3-full flushes.  An
                # idle pipeline (nothing on the wire) skips the linger so a
                # lone commit is never taxed for coalescing that cannot
                # happen.
                if (
                    self._put_q
                    and not self._closed
                    and linger_s > 0
                    and self._inflight_flushes > 0
                ):
                    deadline = time.perf_counter() + linger_s
                    while (
                        self._put_q
                        and not self._closed
                        and self._pending_put_items < cfg.flush_max_items
                    ):
                        remaining = deadline - time.perf_counter()
                        if remaining <= 0:
                            break
                        self._lock.wait(remaining)
                        self._dispatch_gets_locked(cfg.flush_max_items, linger_s)
                batch, groups = self._gather_puts_locked(cfg.flush_max_items)
                # deletes are one engine call regardless of size (the
                # engines model BatchWriteItem-style deletes without a page
                # cap), so drain generously — paging them like puts would
                # let a GC wave monopolize flush slots
                dels, del_groups = self._gather_deletes_locked(
                    max(cfg.flush_max_items, 1000)
                )
                if batch or dels:
                    self._inflight_flushes += 1
            if not batch and not dels:
                self._flush_slots.release()
                continue
            # several flushes ride the wire at once (the groups' barriers,
            # not flush ordering, carry the protocol's ordering guarantees)
            self._flush_pool.submit(self._do_flush, batch, groups, dels, del_groups)

    def _dispatch_gets_locked(self, max_items: int, linger_s: float) -> None:
        """Carve pending reads into batch-get round trips.  Reads dispatch
        EAGERLY (no fill/linger gate): they resolve §3.3.1 probes that gate
        commit records, batch-get base cost is low, and arrival bursts
        batch naturally; they ride the worker pool, never the write-flush
        slots."""
        del linger_s  # reads never linger; see docstring
        while self._get_q:
            pairs = [
                self._get_q.popleft() for _ in
                range(min(max_items, len(self._get_q)))
            ]
            self._inflight_gets += 1
            with self._stats_lock:
                self._s["get_batches"] += 1
                self._s["batched_gets"] += len(pairs)
            self._workers.submit(self._do_get_batch, pairs)

    def _do_get_batch(self, pairs) -> None:
        keys = [k for k, _f, _t in pairs]
        try:
            out = self.storage.get_batch(keys)
        except BaseException as exc:  # noqa: BLE001 - delivered via futures
            for _k, fut, _t in pairs:
                if not fut.done():
                    fut.set_exception(exc)
        else:
            for k, fut, _t in pairs:
                if not fut.done():
                    fut.set_result(out.get(k))
        with self._lock:
            self._inflight_gets -= 1
            self._lock.notify_all()  # drain() may be waiting

    def _gather_puts_locked(self, max_items: int):
        batch: Dict[str, bytes] = {}
        groups: List[Tuple[_Group, int]] = []  # (group, items taken)
        while self._put_q and len(batch) < max_items:
            group, keys = self._put_q[0]
            take = min(max_items - len(batch), len(keys))
            taken = keys[-take:]
            del keys[-take:]
            for k in taken:
                batch[k] = group.items[k]
            groups.append((group, take))
            self._pending_put_items -= take
            if not keys:
                self._put_q.popleft()
        return batch, groups

    def _gather_deletes_locked(self, max_items: int):
        dels: List[str] = []
        groups: List[Tuple[_Group, int]] = []
        while self._del_q and len(dels) < max_items:
            group, keys = self._del_q[0]
            take = min(max_items - len(dels), len(keys))
            dels.extend(keys[-take:])
            del keys[-take:]
            groups.append((group, take))
            if not keys:
                self._del_q.popleft()
        return dels, groups

    def _do_flush(self, batch, groups, dels, del_groups) -> None:
        # puts and deletes sharing one flush are INDEPENDENT storage calls
        # with independent failure domains: a GC delete outage must fail
        # only the delete groups, never a committing transaction whose
        # put_batch already landed (and vice versa).
        now = time.perf_counter()
        put_exc: Optional[BaseException] = None
        del_exc: Optional[BaseException] = None
        tracer = obs_trace.get_tracer()
        if batch:
            try:
                self._fault_point("pipeline:flush", list(batch))
                t_put = time.perf_counter()
                self.storage.put_batch(batch)
                self._h_flush.observe_s(time.perf_counter() - t_put)
                self._fault_point("pipeline:flush-landed", list(batch))
            except BaseException as e:  # noqa: BLE001 - delivered via futures
                put_exc = e
            if tracer.enabled:
                tracer.emit("flush", site="pipeline:flush",
                            name=self.config.name, items=len(batch),
                            groups=len(groups), ok=put_exc is None)
        if dels:
            try:
                self._fault_point("pipeline:delete-flush", list(dels))
                t_del = time.perf_counter()
                self.storage.delete_batch(dels)
                self._h_delete_flush.observe_s(time.perf_counter() - t_del)
            except BaseException as e:  # noqa: BLE001 - delivered via futures
                del_exc = e
            if tracer.enabled:
                tracer.emit("flush", site="pipeline:delete-flush",
                            name=self.config.name, items=len(dels),
                            ok=del_exc is None)
        for group, _ in groups:
            self._h_queue_wait.observe_s(now - group.enqueued_at)
        with self._stats_lock:
            if batch and put_exc is None:
                self._s["flushes"] += 1
                self._s["flushed_items"] += len(batch)
                self._s["flushed_bytes"] += sum(
                    len(v) for v in batch.values())
                self._s["flush_groups"] += len(groups)
                if len(batch) > self._s["flush_size_max"]:
                    self._s["flush_size_max"] = len(batch)
            elif batch:
                self._s["flush_failures"] += 1
            if dels and del_exc is None:
                self._s["delete_flushes"] += 1
                self._s["deleted_keys"] += len(dels)
            for group, _ in groups:
                self._s["queue_wait_s_total"] += now - group.enqueued_at
                self._s["queue_wait_samples"] += 1
        self._flush_slots.release()
        for group, take in groups:
            self._settle_group(group, take, put_exc)
        for group, take in del_groups:
            self._settle_group(group, take, del_exc)
        with self._lock:
            self._inflight_flushes -= 1
            self._lock.notify_all()

    def _fault_point(self, site: str, keys: List[str]) -> None:
        hook = self.fault_hook
        if hook is None:
            return
        try:
            hook(site, keys)
        except BaseException:
            with self._stats_lock:
                self._s["faults_injected"] += 1
            raise

    @staticmethod
    def _settle_group(group: _Group, take: int, exc: Optional[BaseException]):
        fire: Optional[bool] = None  # True → success, False → exception
        with group.lock:
            if not group.settled:
                if exc is not None:
                    group.settled = True
                    fire = False
                else:
                    group.remaining -= take
                    if group.remaining <= 0:
                        group.settled = True
                        fire = True
        if fire is True:
            group.future.set_result(None)
        elif fire is False:
            group.future.set_exception(exc)
