from .base import StorageEngine, StorageUnsupported
from .localfs import LocalFSStorage
from .memory import MemoryStorage
from .pipeline import PipelineConfig, StorageIOPipeline
from .sharded import ShardedStorage
from .simulated import (
    ENGINE_PRESETS,
    LatencyModel,
    SimulatedEngine,
    dynamodb_like,
    make_engine,
    redis_like,
    s3_like,
)

__all__ = [
    "StorageEngine",
    "StorageUnsupported",
    "MemoryStorage",
    "LocalFSStorage",
    "ShardedStorage",
    "StorageIOPipeline",
    "PipelineConfig",
    "SimulatedEngine",
    "LatencyModel",
    "ENGINE_PRESETS",
    "make_engine",
    "s3_like",
    "dynamodb_like",
    "redis_like",
]
