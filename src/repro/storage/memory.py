"""In-memory storage engine: the zero-latency substrate.

Used directly for unit tests, and as the inner engine beneath the simulated
cloud-engine wrappers (``simulated.py``) for benchmarks.
"""

from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Dict, List, Optional

from .base import StorageEngine


class MemoryStorage(StorageEngine):
    supports_batch = True
    supports_batch_get = True

    def __init__(self) -> None:
        self._data: Dict[str, bytes] = {}
        # sorted key list for prefix scans; kept lazily in sync
        self._keys: List[str] = []
        self._keys_dirty = False
        self._lock = threading.Lock()
        self._puts = 0
        self._gets = 0
        self._deletes = 0

    def put(self, key: str, value: bytes) -> None:
        with self._lock:
            if key not in self._data:
                self._keys_dirty = True
            self._data[key] = value
            self._puts += 1

    def get(self, key: str) -> Optional[bytes]:
        with self._lock:
            self._gets += 1
            return self._data.get(key)

    def delete(self, key: str) -> None:
        with self._lock:
            if self._data.pop(key, None) is not None:
                self._keys_dirty = True
            self._deletes += 1

    def put_batch(self, items: Dict[str, bytes]) -> None:
        with self._lock:
            for k, v in items.items():
                if k not in self._data:
                    self._keys_dirty = True
                self._data[k] = v
            self._puts += len(items)

    def list_keys(self, prefix: str = "") -> List[str]:
        with self._lock:
            if self._keys_dirty:
                self._keys = sorted(self._data)
                self._keys_dirty = False
            if not prefix:
                return list(self._keys)
            lo = bisect_left(self._keys, prefix)
            hi = bisect_left(self._keys, prefix + "￿")
            return self._keys[lo:hi]

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "puts": self._puts,
                "gets": self._gets,
                "deletes": self._deletes,
                "keys": len(self._data),
                "bytes": sum(len(v) for v in self._data.values()),
            }
