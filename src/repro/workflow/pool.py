"""WorkflowPool — batched scheduling of thousands of concurrent workflows.

``WorkflowExecutor`` drives ONE workflow per call: every ready step pays its
own platform invocation (warm-start overhead, §6.1.2) and the caller blocks
until the DAG commits.  That shape cannot sustain the paper's "thousands of
requests per second" (§6) when the requests are many small DAGs.  The pool
is the scheduler-level answer:

* **submission** — ``submit(spec)`` enqueues a workflow and returns a
  :class:`PoolTicket` immediately; thousands of logical workflows are in
  flight at once, multiplexed over one shared :class:`LambdaPlatform`;
* **batching** — ready steps from *different* workflows are folded into a
  single platform invocation (``LambdaPlatform.invoke_batch``), so the
  per-invoke overhead is paid once per batch instead of once per step.  The
  batch size is adaptive by default (:class:`AdaptiveBatcher`: EWMA of
  observed step latency vs. measured invoke overhead); an explicit
  ``batch_max_steps`` is a static override.  A short linger
  (``batch_linger_ms``) lets partial batches fill while other batches are
  in flight; an idle pool dispatches immediately;
* **placement** — every workflow carries a ``PlacementHint`` (uuid +
  declared read set), so a multi-node cluster's routing policy
  (``core/routing.py``) shards workflows by locality; STEP scope with
  ``place_steps=True`` places each step independently;
* **fairness** — dispatch is round-robin across workflows (one step per
  workflow per pass) with a per-workflow in-flight cap, so a wide DAG cannot
  starve its neighbours;
* **bounded windows & backpressure** — at most ``max_inflight_steps`` step
  bodies execute at once, and ``submit`` blocks once
  ``max_admitted_workflows`` tickets are unresolved, so a faster producer
  cannot grow the pool's memory without bound;
* **failure model** — identical to the executor's (§2.2/§3.3.1 lifted to
  DAGs): a step failure drains the workflow's in-flight siblings, rolls back
  the attempt, and retries the whole workflow under the same UUID with
  memoized steps replayed, up to ``max_attempts``;
* **GC integration** — a successfully committed workflow is *declared
  finished* (``MemoStore.mark_finished``), which licenses the §5 GC
  (``core/gc.py``) to reclaim its ``.wf/`` memo records and derived ``u/``
  index entries, so a long-running pool's storage footprint plateaus instead
  of growing monotonically.  See ``docs/WORKFLOWS.md`` for tuning.

Internally one scheduler thread owns all bookkeeping (guarded by a single
condition variable); step bodies run on the platform pool inside batched
invocations, and session lifecycle I/O (memo loads, commit, abort) runs on a
small finisher pool so the scheduler never blocks on storage.  With
``commit_offload=True`` (default) the finisher does not even block on
commits: they ride the node's storage I/O pipeline
(``storage/pipeline.py``), the ticket resolves when the commit future
lands, and concurrent workflows' version writes coalesce into shared
group-commit flushes.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Deque, Dict, List, Optional, Set, Tuple

from ..core import AftCluster, PlacementHint
from ..core.ids import fresh_uuid
from ..core.records import (
    WF_CHAIN_INFIX,
    lookup_committed_record,
    workflow_finish_key,
)
from ..faas.platform import LambdaPlatform
from ..obs import trace as obs_trace
from ..obs.registry import Registry
from ..storage.base import StorageEngine
from .executor import (
    StepFailure,
    WorkflowError,
    WorkflowResult,
    execute_step,
)
from .spec import WorkflowSpec
from .txn import MemoStore, TxnScope, WorkflowSession, make_session


@dataclass
class PoolConfig:
    # transaction semantics (same knobs as WorkflowConfig)
    scope: TxnScope = TxnScope.WORKFLOW
    max_attempts: int = 6
    retry_backoff_ms: float = 5.0
    memoize: bool = True
    declared_writes: Tuple[str, ...] = ()
    # the pool owns workflow lifecycle, so unlike the bare executor it
    # declares workflows finished by default — committing a ticket is the
    # promise that its UUID is never re-driven
    declare_finished: bool = True
    # placement (see core/routing.py): STEP scope may place each step's
    # transaction independently at the node scored best for its declared
    # reads; WORKFLOW scope always stays pinned per §3.1 but the pin itself
    # is routed by the workflow's hint
    place_steps: bool = False
    # commit offload (storage/pipeline.py): route commits through the
    # node's asynchronous I/O pipeline.  WORKFLOW scope: the finisher
    # enqueues the DAG's commit and moves on — the ticket resolves when the
    # commit future lands, and concurrent workflows' version writes
    # group-commit into shared put_batch flushes.  STEP scope: a step's
    # commit overlaps the dispatch of its dependents (visibility barrier at
    # the dependent's body start).  Memo saves become fire-and-forget
    # (safe: a lost memo just re-runs its step, recommitting idempotently).
    commit_offload: bool = True
    # honor ``Step.read_only`` declarations: such steps ride the read-only
    # fast lane (no version writes, no commit record, no memo — see
    # workflow/executor.py ``execute_step`` and core/node.py
    # ``_commit_read_only``)
    read_only_lane: bool = True
    # scheduling.  batch_max_steps=None (default) sizes batches adaptively
    # from an EWMA of observed step latency vs. invoke overhead; an explicit
    # integer is a static override (the historical knob).
    batch_max_steps: Optional[int] = None
    batch_linger_ms: float = 1.0      # wait for a partial batch to fill
    max_inflight_steps: int = 128     # global step window
    max_inflight_per_workflow: int = 4
    max_admitted_workflows: int = 2048  # backpressure: submit() blocks
    # adaptive-batching model: pick the batch size where the (amortized)
    # per-step share of one invocation's overhead stays under this fraction
    # of a step's own latency; clamped to [min, max]
    adaptive_overhead_frac: float = 0.25
    adaptive_batch_min: int = 2
    adaptive_batch_max: int = 64
    adaptive_ewma_alpha: float = 0.2


class PoolClosed(RuntimeError):
    """submit() after close()."""


class AdaptiveBatcher:
    """Batch-size model: big enough to amortize the invoke overhead, small
    enough not to serialize long step bodies behind one another.

    One batched invocation pays the platform's warm-start overhead ``o``
    once for ``b`` steps of mean latency ``s``; the per-step overhead share
    is ``o / (b·s)``.  The target is the smallest ``b`` that keeps that
    share under ``adaptive_overhead_frac`` — i.e. ``b = o / (frac·s)`` —
    clamped to ``[adaptive_batch_min, adaptive_batch_max]``.  Both ``o``
    (measured dispatch → first-body-start lead time, which also absorbs
    platform queueing) and ``s`` (measured body wall time) are EWMAs, so
    the pool tracks drifting workloads.  An explicit
    ``PoolConfig.batch_max_steps`` bypasses the model entirely (static
    override, the historical knob).
    """

    _INITIAL = 8  # the old static default, until measurements arrive

    def __init__(self, config: PoolConfig):
        self.config = config
        self._step_s: Optional[float] = None
        self._overhead_s: Optional[float] = None
        self._target = min(
            max(self._INITIAL, config.adaptive_batch_min),
            config.adaptive_batch_max,
        )

    @property
    def cap(self) -> int:
        """Current steps-per-invocation target (static override wins).
        The adaptive target never exceeds the in-flight window: the
        dispatch gates hold until a whole batch's capacity is free, so a
        cap above the window would stall dispatch whenever work is in
        flight."""
        if self.config.batch_max_steps is not None:
            return self.config.batch_max_steps
        return min(self._target, self.config.max_inflight_steps)

    def observe(self, body_s: Optional[float], lead_s: Optional[float]) -> None:
        if self.config.batch_max_steps is not None:
            return  # static override: nothing to learn
        a = self.config.adaptive_ewma_alpha

        def ewma(old: Optional[float], new: float) -> float:
            return new if old is None else (1.0 - a) * old + a * new

        if body_s is not None:
            self._step_s = ewma(self._step_s, max(body_s, 0.0))
        if lead_s is not None:
            self._overhead_s = ewma(self._overhead_s, max(lead_s, 0.0))
        if self._step_s is None or self._overhead_s is None:
            return
        cfg = self.config
        # sub-µs bodies make the ratio explode; the clamp is the answer
        denom = max(cfg.adaptive_overhead_frac * self._step_s, 1e-9)
        raw = self._overhead_s / denom
        self._target = int(
            min(max(raw, cfg.adaptive_batch_min), cfg.adaptive_batch_max)
        )


class PoolTicket:
    """Handle for one submitted workflow; resolves to a WorkflowResult."""

    def __init__(self, workflow_uuid: str):
        self.workflow_uuid = workflow_uuid
        self._future: "Future[WorkflowResult]" = Future()

    def result(self, timeout: Optional[float] = None) -> WorkflowResult:
        return self._future.result(timeout)

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        return self._future.exception(timeout)

    def done(self) -> bool:
        return self._future.done()

    def add_done_callback(self, fn) -> None:
        """Run ``fn(future)`` when the ticket resolves (success or failure);
        the chain consumer uses this for completion bookkeeping."""
        self._future.add_done_callback(fn)


class _RunState(Enum):
    STARTING = "starting"      # finisher is building session / loading memos
    RUNNING = "running"        # steps dispatching
    RETRY_WAIT = "retry-wait"  # backoff before next attempt
    ABANDONING = "abandoning"  # finisher is rolling back the failed attempt
    FINISHING = "finishing"    # finisher is committing
    DONE = "done"


# process-wide run sequence: folded into span IDs so a workflow re-driven
# under the same UUID (memo-resume in a fresh pool, already-finished dedup)
# cannot collide with the spans its first incarnation already emitted —
# attempt counters restart at 1 across pools, this seed never repeats
_RUN_SEQ = itertools.count(1)


@dataclass
class _Run:
    spec: WorkflowSpec
    uuid: str
    args: Any
    ticket: PoolTicket
    resume_eligible: bool
    # {"queue": ..., "entry": ...} when this run was started by a chain
    # trigger; recorded in the finish marker so GC can reclaim the entry
    chain_entry: Optional[Dict[str, str]] = None
    deduped: bool = False  # resolved from the finish marker, nothing ran
    state: _RunState = _RunState.RETRY_WAIT
    attempt: int = 0
    span_seed: int = field(default_factory=lambda: next(_RUN_SEQ))
    retry_at: float = 0.0
    t0: float = field(default_factory=time.perf_counter)
    session: Optional[WorkflowSession] = None
    memos: Dict[str, Tuple[Any, Dict[str, bytes]]] = field(default_factory=dict)
    indeg: Dict[str, int] = field(default_factory=dict)
    dependents: Dict[str, List[str]] = field(default_factory=dict)
    results: Dict[str, Any] = field(default_factory=dict)
    skipped: Set[str] = field(default_factory=set)
    ready: Deque[str] = field(default_factory=deque)
    inflight: int = 0
    ran: int = 0
    memoized: int = 0
    failure: Optional[StepFailure] = None
    in_rr: bool = False  # membership flag for the fairness queue

    @property
    def done_steps(self) -> int:
        return len(self.results) + len(self.skipped)


class WorkflowPool:
    def __init__(
        self,
        platform: LambdaPlatform,
        *,
        cluster: Optional[AftCluster] = None,
        storage: Optional[StorageEngine] = None,
        config: Optional[PoolConfig] = None,
        registry: Optional[Registry] = None,
    ):
        self.platform = platform
        self.cluster = cluster
        self.storage = storage
        self.config = config or PoolConfig()
        self._memo = (
            MemoStore(cluster, offload=self.config.commit_offload)
            if cluster is not None else None
        )
        self._memoizing = (
            self.config.memoize
            and self.config.scope is not TxnScope.NONE
            and self._memo is not None
        )
        self.stats: Dict[str, int] = {
            "workflows_submitted": 0,
            "workflows_completed": 0,
            "workflows_failed": 0,
            "workflow_retries": 0,
            "steps_run": 0,
            "steps_memoized": 0,
            "steps_skipped": 0,
            "batches_dispatched": 0,
            "batched_steps": 0,
            "max_admitted": 0,
            "batch_target": 0,  # gauge: current adaptive (or static) cap
            "chain_triggers_staged": 0,
            "late_memo_hits": 0,  # rival memo found at dispatch, body skipped
            "already_finished_dedups": 0,  # finish marker found at attempt start
            "commits_offloaded": 0,       # finish commits sent to the pipeline
            "commit_inflight": 0,         # gauge: offloaded commits in flight
            "commit_pipeline_depth": 0,   # high-water mark of the above
        }
        self.registry = registry or Registry(
            name="pool", time_scale=platform.config.time_scale
        )
        self.registry.attach_counters(self.stats)
        self._h_wf_wall = self.registry.histogram("workflow.wall")
        self._commit_inflight = 0
        self._batcher = AdaptiveBatcher(self.config)
        self.stats["batch_target"] = self._batcher.cap
        self._cond = threading.Condition()
        self._events: Deque[Tuple] = deque()
        self._rr: Deque[_Run] = deque()   # fairness queue: runs w/ ready steps
        self._retry: List[_Run] = []      # RETRY_WAIT runs (small; linear scan)
        self._admitted = 0
        self._inflight_steps = 0
        self._ready_total = 0
        self._ready_since: Optional[float] = None
        self._closed = False
        self._chain_consumers: List = []  # ChainConsumers bound to this pool
        self._stop = threading.Event()
        self._finisher = ThreadPoolExecutor(
            max_workers=4, thread_name_prefix="wfpool-io"
        )
        self._scheduler = threading.Thread(
            target=self._loop, name="wfpool-scheduler", daemon=True
        )
        self._scheduler.start()

    # ------------------------------------------------------------------ api
    def submit(
        self,
        spec: WorkflowSpec,
        *,
        uuid: Optional[str] = None,
        args: Any = None,
        chain_entry: Optional[Dict[str, str]] = None,
    ) -> PoolTicket:
        """Enqueue a workflow; blocks only for backpressure (admission).
        ``chain_entry`` marks a run driven from the trigger queue
        (``ChainConsumer``): its provenance rides the finish marker so the
        GC sweep reclaims the queue entry with the workflow."""
        spec.validate()
        resume_eligible = uuid is not None
        workflow_uuid = uuid or fresh_uuid()
        ticket = PoolTicket(workflow_uuid)
        run = _Run(
            spec=spec,
            uuid=workflow_uuid,
            args=args,
            ticket=ticket,
            resume_eligible=resume_eligible,
            chain_entry=chain_entry,
        )
        with self._cond:
            while (
                not self._closed
                and self._admitted >= self.config.max_admitted_workflows
            ):
                self._cond.wait()
            if self._closed:
                raise PoolClosed("WorkflowPool is closed")
            self._admitted += 1
            self.stats["workflows_submitted"] += 1
            self.stats["max_admitted"] = max(
                self.stats["max_admitted"], self._admitted
            )
            run.retry_at = 0.0  # start as soon as the scheduler sees it
            self._retry.append(run)
            self._cond.notify_all()
        tracer = obs_trace.get_tracer()
        if tracer.enabled:
            # trace propagation is structural: every layer derives the same
            # trace id from the workflow UUID it already holds.  A chain
            # child's UUID embeds its parent's (<parent>.chain.<edge>), so
            # the parent link falls out of the grammar with no plumbing.
            parent_uuid, sep, _ = workflow_uuid.rpartition(WF_CHAIN_INFIX)
            tracer.emit(
                "submit",
                name=spec.name,
                uuid=workflow_uuid,
                trace=obs_trace.trace_id(workflow_uuid),
                parent=obs_trace.txn_trace_id(parent_uuid) if sep else None,
                chain=dict(chain_entry) if chain_entry else None,
            )
        return ticket

    def run_all(
        self,
        specs: List[WorkflowSpec],
        *,
        args: Any = None,
        timeout: Optional[float] = None,
    ) -> List[WorkflowResult]:
        """Convenience: submit every spec, wait for all results (in order)."""
        tickets = [self.submit(s, args=args) for s in specs]
        return [t.result(timeout) for t in tickets]

    def attach_chain_consumer(self, registry, config=None, *, start=True):
        """Create (and by default start) a trigger-queue consumer loop bound
        to this pool: it claims ``q/`` entries with §3.3.1 UUID-reuse dedup
        and submits their child workflows here (``workflow/chain.py``).
        Stopped automatically by ``close()``."""
        from .chain import ChainConsumer

        consumer = ChainConsumer(self, registry, config)
        self._chain_consumers.append(consumer)
        if start:
            consumer.start()
        return consumer

    def close(self, wait: bool = True) -> None:
        # flip _closed BEFORE stopping consumers: a consumer thread blocked
        # in submit()'s admission wait is only woken by this notify — the
        # (caught, counted) PoolClosed it then sees is what lets stop()'s
        # join succeed instead of timing out against a stuck poll loop
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        for consumer in self._chain_consumers:
            consumer.stop()
        with self._cond:
            if wait:
                while self._admitted > 0:
                    self._cond.wait()
        if wait and self.cluster is not None:
            # tickets resolve on the FINAL commit; offloaded memo saves are
            # fire-and-forget, so settle the I/O pipelines before declaring
            # the pool closed — a re-drive right after close() must find
            # every memo the completed workflows earned
            for node in self.cluster.live_nodes():
                try:
                    node.drain_pipeline(timeout=30)
                except Exception:
                    pass  # crash-mid-drain: memos are an optimization
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        self._scheduler.join(timeout=10)
        self._finisher.shutdown(wait=True)

    def __enter__(self) -> "WorkflowPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close(wait=not any(exc))

    # ------------------------------------------------------------ scheduler
    def _loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                while self._events:
                    self._handle_event(self._events.popleft())
                now = time.perf_counter()
                self._start_due_attempts(now)
                batches = self._build_batches(now)
                timeout = self._wait_timeout(now)
            for batch in batches:
                self.platform.submit_batch(batch)
            if batches:
                continue  # new completions may already be queued
            with self._cond:
                if not self._events and not self._stop.is_set():
                    self._cond.wait(timeout)

    def _wait_timeout(self, now: float) -> float:
        timeout = 0.05
        for run in self._retry:
            timeout = min(timeout, max(run.retry_at - now, 0.0))
        # The linger deadline only matters when dispatch is actually waiting
        # on it.  When the window is capacity-blocked the next dispatch is
        # triggered by a completion event (which notifies the condition), so
        # honoring the long-expired linger here would spin the scheduler at
        # sub-millisecond wakeups exactly when the pool is busiest.
        free = self.config.max_inflight_steps - self._inflight_steps
        capacity_blocked = (
            self._inflight_steps > 0 and free < self._batcher.cap
        )
        if self._ready_since is not None and not capacity_blocked:
            linger = self.config.batch_linger_ms / 1e3
            timeout = min(timeout, max(self._ready_since + linger - now, 0.0))
        return max(timeout, 1e-4)

    # -- attempt lifecycle (finisher does the I/O) --------------------------
    def _start_due_attempts(self, now: float) -> None:
        due = [r for r in self._retry if r.retry_at <= now]
        if not due:
            return
        self._retry = [r for r in self._retry if r.retry_at > now]
        for run in due:
            run.state = _RunState.STARTING
            run.attempt += 1
            if run.attempt > 1:
                self.stats["workflow_retries"] += 1
            self._finisher.submit(self._begin_attempt_io, run, run.attempt)

    def _begin_attempt_io(self, run: _Run, epoch: int) -> None:
        try:
            if run.resume_eligible and self.cluster is not None:
                # last-moment dedup for re-driven uuids (replayed chain
                # triggers, crashed clients resubmitting): a rival drive may
                # have finished this workflow — and the GC sweep may already
                # have reclaimed its memos and derived u/ entries — between
                # the caller's decision to submit and this attempt.
                # Re-running bodies then would re-commit under STEP scope
                # (the §3.3.1 probe finds nothing), so honor the marker's
                # never-re-driven promise here, on every attempt.
                storage = self.cluster.storage
                if storage.get(workflow_finish_key(run.uuid)) is not None:
                    record = lookup_committed_record(storage, run.uuid)
                    self._emit((
                        "already_finished", run, epoch,
                        record.tid if record else None,
                    ))
                    return
            # memos load BEFORE the session: a resume/retry enriches its
            # placement hint with the memoized steps' recorded read sets,
            # so locality routing needs no manually declared Step.reads
            memos: Dict[str, Tuple[Any, Dict[str, bytes]]] = {}
            records: list = []
            hint_keys = run.spec.declared_reads()
            if self._memoizing and (run.attempt > 1 or run.resume_eligible):
                memos, records, memo_reads = self._memo.load_all_with_reads(
                    run.uuid, run.spec.steps, scope=self.config.scope
                )
                hint_keys = hint_keys + tuple(
                    k for k in memo_reads if k not in hint_keys
                )
            session = make_session(
                self.config.scope,
                run.uuid,
                cluster=self.cluster,
                storage=self.storage,
                cowritten_hint=self.config.declared_writes,
                hint=PlacementHint(uuid=run.uuid, keys=hint_keys),
                place_steps=self.config.place_steps,
                commit_offload=self.config.commit_offload,
                # first attempt of a UUID this pool minted: nobody else can
                # know it, so the §3.3.1 probes are skipped.  Retries and
                # chain/explicit re-drives (resume_eligible) must probe.
                fresh=(run.attempt == 1 and not run.resume_eligible),
            )
            if records:
                session.recover(records)
            self._emit(("attempt_ready", run, epoch, session, memos))
        except BaseException as exc:  # noqa: BLE001 - surfaces via retry path
            self._emit(("attempt_error", run, epoch, exc))

    def _finish_io(self, run: _Run, epoch: int) -> None:
        try:
            if run.spec.on_commit:
                # chaining: resolve on_commit edges against the completed
                # results and hand them to the scope — under WORKFLOW scope
                # the entries ride inside the commit below (atomic handoff)
                run.session.stage_triggers(run.spec.on_commit, run.results)
            if self.config.commit_offload:
                # commit offload: enqueue the scope's final commit on the
                # storage I/O pipeline and free this finisher thread — the
                # ticket resolves when the commit future lands, and many
                # workflows' commits coalesce into shared group flushes
                fut = run.session.finish_async()
                with self._cond:
                    self.stats["commits_offloaded"] += 1
                    self._commit_inflight += 1
                    self.stats["commit_inflight"] = self._commit_inflight
                    if self._commit_inflight > self.stats["commit_pipeline_depth"]:
                        self.stats["commit_pipeline_depth"] = self._commit_inflight
                fut.add_done_callback(
                    lambda f: self._commit_landed(run, epoch, f)
                )
                return
            tid = run.session.finish()
        except BaseException as exc:  # noqa: BLE001
            self._emit(("finish_error", run, epoch, exc))
            return
        self._after_commit(run, epoch, tid)

    def _commit_landed(self, run: _Run, epoch: int, fut) -> None:
        # runs on a pipeline worker thread: hop marker I/O back onto the
        # finisher pool (inline fallback if the pool is already shut down)
        with self._cond:
            self._commit_inflight -= 1
            self.stats["commit_inflight"] = self._commit_inflight
        exc = fut.exception()
        if exc is not None:
            self._emit(("finish_error", run, epoch, exc))
            return
        tid = fut.result()
        try:
            self._finisher.submit(self._after_commit, run, epoch, tid)
        except RuntimeError:  # close(wait=False) raced the landing
            self._after_commit(run, epoch, tid)

    def _after_commit(self, run: _Run, epoch: int, tid) -> None:
        if self._memoizing and self.config.declare_finished:
            try:
                extra = (
                    {"chain": run.chain_entry} if run.chain_entry else None
                )
                self._memo.mark_finished(run.uuid, extra)
            except Exception:
                pass  # advisory GC state; unmarked memos linger, nothing breaks
        self._emit(("finished", run, epoch, tid))

    def _abandon_io(self, run: _Run, epoch: int) -> None:
        try:
            run.session.abandon()
        finally:
            self._emit(("abandoned", run, epoch))

    def _emit(self, event: Tuple) -> None:
        with self._cond:
            self._events.append(event)
            self._cond.notify_all()

    # -- event handling (always under self._cond) ---------------------------
    def _handle_event(self, event: Tuple) -> None:
        kind, run, epoch = event[0], event[1], event[2]
        if epoch != run.attempt or run.state is _RunState.DONE:
            return  # stale event from a superseded attempt
        if kind == "attempt_ready":
            _, _, _, session, memos = event
            run.session = session
            run.memos = memos
            run.state = _RunState.RUNNING
            run.failure = None
            run.results.clear()
            run.skipped.clear()
            run.ready.clear()
            run.inflight = 0
            run.ran = 0
            run.memoized = 0
            run.indeg = {n: len(s.deps) for n, s in run.spec.steps.items()}
            run.dependents = run.spec.dependents_of()
            self._settle(run, [n for n, d in run.indeg.items() if d == 0])
            self._after_progress(run)
        elif kind == "step":
            _, _, _, name, ok, val, body_s, lead_s, memo_hit = event
            # Two kinds of dispatched step are NOT step-latency samples:
            # failed bodies die fast (a dead node raising immediately), and
            # memoized-resume hits (a rival attempt's memo found at dispatch
            # — see _make_thunk) return in microseconds without running the
            # body.  Feeding either near-zero reading into the EWMA during a
            # crash/resume burst drags the modeled step latency toward zero
            # and pins batch_target at adaptive_batch_max — over-batching
            # exactly when real bodies are about to run again.  Only
            # successful, actually-executed bodies update the model.
            self._batcher.observe(body_s if ok and not memo_hit else None, lead_s)
            self.stats["batch_target"] = self._batcher.cap
            run.inflight -= 1
            self._inflight_steps -= 1
            if ok and run.failure is None:
                run.results[name] = val
                if memo_hit:
                    run.memoized += 1
                    self.stats["late_memo_hits"] += 1
                else:
                    run.ran += 1
                self._settle(run, self._resolve(run, name))
            elif not ok:
                run.failure = run.failure or StepFailure(name, val)
            self._after_progress(run)
        elif kind == "already_finished":
            # a rival drive of this uuid already committed + marked
            # finished; resolve the ticket without running anything.  A
            # prior attempt's session (if any) staged nothing that this
            # completion should account for.
            run.session = None
            run.deduped = True
            self.stats["already_finished_dedups"] += 1
            self._complete(run, event[3])
        elif kind == "attempt_error":
            run.failure = run.failure or event[3]
            self._schedule_retry_or_fail(run)
        elif kind == "abandoned":
            self._schedule_retry_or_fail(run)
        elif kind == "finished":
            self._complete(run, event[3])
        elif kind == "finish_error":
            run.failure = run.failure or event[3]
            run.state = _RunState.ABANDONING
            self._finisher.submit(self._abandon_io, run, run.attempt)

    def _after_progress(self, run: _Run) -> None:
        """Advance a RUNNING workflow after any state change."""
        if run.state is not _RunState.RUNNING:
            return
        if run.failure is not None:
            # drain in-flight siblings before rolling back, so abandon()
            # cannot race their get/put calls (same rule as the executor)
            self._drop_ready(run)
            if run.inflight == 0:
                run.state = _RunState.ABANDONING
                self._finisher.submit(self._abandon_io, run, run.attempt)
            return
        if run.done_steps == len(run.spec.steps) and run.inflight == 0:
            self._drop_ready(run)
            run.state = _RunState.FINISHING
            self._finisher.submit(self._finish_io, run, run.attempt)
            return
        self._enqueue_rr(run)

    def _settle(self, run: _Run, newly_ready: List[str]) -> None:
        """Resolve skips / conditional edges / memo hits eagerly so
        ``run.ready`` only ever holds steps that truly need execution."""
        work = deque(newly_ready)
        while work:
            name = work.popleft()
            step = run.spec.steps[name]
            missing = [d for d in step.deps if d in run.skipped]
            if missing and not step.allow_skipped_deps:
                run.skipped.add(name)
                work.extend(self._resolve(run, name))
                continue
            inputs = {
                d: run.results[d] for d in step.deps if d not in run.skipped
            }
            if step.when is not None and not step.when(inputs):
                run.skipped.add(name)
                work.extend(self._resolve(run, name))
                continue
            if name in run.memos:
                # §3.3.1 extended to steps: already ran in a prior attempt —
                # feed the recorded result downstream, replay its writes
                result, writes = run.memos[name]
                run.session.replay(name, writes)
                run.results[name] = result
                run.memoized += 1
                work.extend(self._resolve(run, name))
                continue
            run.ready.append(name)
            self._ready_total += 1
            if self._ready_since is None:
                self._ready_since = time.perf_counter()

    def _resolve(self, run: _Run, name: str) -> List[str]:
        out = []
        for m in run.dependents[name]:
            run.indeg[m] -= 1
            if run.indeg[m] == 0:
                out.append(m)
        return out

    def _drop_ready(self, run: _Run) -> None:
        self._ready_total -= len(run.ready)
        run.ready.clear()
        if self._ready_total == 0:
            self._ready_since = None

    def _enqueue_rr(self, run: _Run) -> None:
        if (
            not run.in_rr
            and run.ready
            and run.inflight < self.config.max_inflight_per_workflow
        ):
            run.in_rr = True
            self._rr.append(run)

    def _schedule_retry_or_fail(self, run: _Run) -> None:
        cfg = self.config
        if run.attempt >= cfg.max_attempts:
            run.state = _RunState.DONE
            self._resolve_ticket(
                run,
                error=WorkflowError(
                    f"workflow {run.spec.name!r} ({run.uuid}) failed after "
                    f"{cfg.max_attempts} attempts"
                ),
                cause=run.failure,
            )
            return
        backoff_s = (
            cfg.retry_backoff_ms
            * run.attempt
            * self.platform.config.time_scale
            / 1e3
        )
        run.state = _RunState.RETRY_WAIT
        run.retry_at = time.perf_counter() + backoff_s
        self._retry.append(run)

    def _complete(self, run: _Run, tid) -> None:
        run.state = _RunState.DONE
        wall_s = time.perf_counter() - run.t0
        self._h_wf_wall.observe_s(wall_s)
        tracer = obs_trace.get_tracer()
        if tracer.enabled:
            trace = obs_trace.trace_id(run.uuid)
            tracer.emit(
                "span",
                name="wf",
                trace=trace,
                span=obs_trace.span_id(trace, "wf", f"{run.span_seed}.{run.attempt}"),
                parent=None,
                dur_ms=wall_s * 1e3,
                status="dedup" if run.deduped else "ok",
                attempts=run.attempt,
            )
            tracer.emit(
                "wf_finished",
                uuid=run.uuid,
                trace=trace,
                tid=(tid.encode() if hasattr(tid, "uuid") else tid),
                deduped=run.deduped,
            )
        self.stats["workflows_completed"] += 1
        if run.session is not None:  # deduped runs never staged anything
            self.stats["chain_triggers_staged"] += len(run.spec.on_commit)
        self.stats["steps_run"] += run.ran
        self.stats["steps_memoized"] += run.memoized
        self.stats["steps_skipped"] += len(run.skipped)
        result = WorkflowResult(
            workflow_uuid=run.uuid,
            results=dict(run.results),
            skipped=tuple(sorted(run.skipped)),
            attempts=run.attempt,
            steps_run=run.ran,
            steps_memoized=run.memoized,
            committed_tid=tid,
            wall_ms=(time.perf_counter() - run.t0) * 1e3,
            scope=self.config.scope.value,
            deduped=run.deduped,
        )
        self._resolve_ticket(run, result=result)

    def _resolve_ticket(
        self,
        run: _Run,
        *,
        result: Optional[WorkflowResult] = None,
        error: Optional[BaseException] = None,
        cause: Optional[BaseException] = None,
    ) -> None:
        self._admitted -= 1
        if error is not None:
            self.stats["workflows_failed"] += 1
            if cause is not None:
                error.__cause__ = cause
            run.ticket._future.set_exception(error)
        else:
            run.ticket._future.set_result(result)
        self._cond.notify_all()  # wake blocked submitters / close(wait=True)

    # -- batch construction -------------------------------------------------
    def _build_batches(self, now: float) -> List[List]:
        cfg = self.config
        batch_cap = self._batcher.cap
        if self._ready_total == 0:
            return []
        # When the window is saturated, dispatch in full-batch quanta:
        # completions free capacity one step at a time, and dispatching each
        # sliver immediately would degenerate into single-step batches
        # exactly when the backlog is deepest.  Holding until a whole
        # batch's worth of capacity is free keeps batches full under load;
        # an idle pool (nothing in flight) still dispatches at once.
        free = cfg.max_inflight_steps - self._inflight_steps
        if free < batch_cap and self._inflight_steps > 0:
            return []
        # linger: let a partial batch fill while other work is in flight
        if (
            self._ready_total < batch_cap
            and self._inflight_steps > 0
            and self._ready_since is not None
            and now - self._ready_since < cfg.batch_linger_ms / 1e3
        ):
            return []
        batches: List[List] = []
        batch: List = []
        batch_meta = {"dispatched": now}  # adaptive model: overhead probe
        while self._rr and self._inflight_steps < cfg.max_inflight_steps:
            run = self._rr.popleft()
            run.in_rr = False
            if (
                run.state is not _RunState.RUNNING
                or run.failure is not None
                or not run.ready
                or run.inflight >= cfg.max_inflight_per_workflow
            ):
                continue
            name = run.ready.popleft()
            self._ready_total -= 1
            batch.append(self._make_thunk(run, run.attempt, name, batch_meta))
            run.inflight += 1
            self._inflight_steps += 1
            self._enqueue_rr(run)  # round-robin: back of the queue
            if len(batch) >= batch_cap:
                batches.append(batch)
                batch = []
                batch_meta = {"dispatched": now}
        if batch:
            batches.append(batch)
        if self._ready_total == 0:
            self._ready_since = None
        else:
            self._ready_since = now
        self.stats["batches_dispatched"] += len(batches)
        self.stats["batched_steps"] += sum(len(b) for b in batches)
        return batches

    def _make_thunk(self, run: _Run, epoch: int, name: str, batch_meta: Dict):
        step = run.spec.steps[name]
        inputs = {d: run.results[d] for d in step.deps if d not in run.skipped}
        session = run.session
        # resumed runs can race a rival driving the SAME uuid (a replayed
        # chain trigger, a crashed consumer's double drive): the rival may
        # commit this step's memo after our attempt's load_all.  Worth a
        # late probe at dispatch; fresh first attempts cannot race this way.
        # Read-only-lane steps never persist memos, so probing is pointless.
        probe_memo = (
            self._memoizing
            and (run.attempt > 1 or run.resume_eligible)
            and not (
                self.config.read_only_lane and getattr(step, "read_only", False)
            )
        )

        def thunk() -> None:
            # bodies in one batch run sequentially inside invoke_batch, so
            # only the batch's FIRST body measures the dispatch → start lead
            # (the invocation overhead + queueing the whole batch paid once)
            t0 = time.perf_counter()
            lead_s = None
            if "lead_taken" not in batch_meta:
                batch_meta["lead_taken"] = True
                lead_s = t0 - batch_meta["dispatched"]
            memo_hit = False
            try:
                probe = (
                    self._memo.probe(session.uuid, name, self.config.scope)
                    if probe_memo else None
                )
                if probe is not None:
                    # §3.3.1: the step already committed under a rival
                    # attempt — recover its commit records into this
                    # session's node(s), replay its writes, never re-run
                    # the body
                    memo, records = probe
                    session.recover(records)
                    result, writes = memo
                    session.replay(name, writes)
                    memo_hit = True
                else:
                    result = execute_step(
                        step, session, self.platform, inputs, run.args,
                        memoizing=self._memoizing, memo_store=self._memo,
                        read_only_lane=self.config.read_only_lane,
                    )
                outcome: Tuple[bool, Any] = (True, result)
            except BaseException as exc:  # noqa: BLE001 - reported, not raised
                outcome = (False, exc)
            body_s = time.perf_counter() - t0
            tracer = obs_trace.get_tracer()
            if tracer.enabled:
                # span ids are attempt-qualified (…/step:x#seed.epoch): a
                # kill-and-retry re-runs the step under a NEW span, and the
                # checker's span-uniqueness pass holds even across a memo
                # re-drive of the same UUID in a fresh pool (span_seed)
                trace = obs_trace.trace_id(run.uuid)
                qual = f"{run.span_seed}.{epoch}"
                tracer.emit(
                    "span",
                    name=f"step:{name}",
                    trace=trace,
                    span=obs_trace.span_id(trace, f"step:{name}", qual),
                    parent=obs_trace.span_id(trace, "wf", qual),
                    dur_ms=body_s * 1e3,
                    status="ok" if outcome[0] else "error",
                    memo_hit=memo_hit,
                )
            self._emit(
                ("step", run, epoch, name, outcome[0], outcome[1],
                 body_s, lead_s, memo_hit)
            )

        def report_failure(exc: BaseException) -> None:
            # the platform killed this thunk's invocation slot before the
            # body ran (site-scoped injection inside invoke_batch): surface
            # it as a normal step failure so retry accounting stays exact
            self._emit(("step", run, epoch, name, False, exc, None, None, False))

        thunk.report_failure = report_failure
        return thunk
