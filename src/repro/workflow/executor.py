"""Workflow scheduler/executor: parallel DAG execution on the FaaS platform.

The executor walks a :class:`~repro.workflow.spec.WorkflowSpec` in dependency
order, submitting every *ready* step to the :class:`LambdaPlatform` pool so
independent branches run concurrently (each submission pays the platform's
warm-start overhead, like any other function invocation).  State access goes
through the attempt's :class:`WorkflowSession` (see ``txn.py``), so the same
DAG runs under whole-workflow, per-step, or no transaction scoping.

Failure model — the platform's retry-based model (§2.2, §7) lifted to DAGs:

* any step may die mid-body (``ctx.maybe_fail()`` failure points, or a real
  exception); the attempt drains in-flight branches, rolls back the scope's
  uncommitted state, and the **whole workflow retries** under the same
  workflow UUID;
* on retry, steps whose memo record exists are *not re-run*: their recorded
  result feeds dependents and their recorded writes are replayed into the
  fresh session (``TxnScope.WORKFLOW``) or are already durable
  (``TxnScope.STEP``).  Memo commits are idempotent by deterministic UUID
  (§3.3.1), so a step's effects survive into exactly one commit no matter
  how many attempts raced over it;
* the final workflow commit reuses the workflow UUID, so even a lost commit
  acknowledgement cannot double-apply the DAG's write set.
"""

from __future__ import annotations

import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future, wait
from dataclasses import dataclass
from typing import Any, Dict, Optional, Set, Tuple

from ..core import AftCluster, PlacementHint, TxnId
from ..core.ids import fresh_uuid
from ..faas.platform import LambdaPlatform
from ..storage.base import StorageEngine
from .spec import Step, WorkflowSpec
from .txn import (
    MemoStore,
    TxnScope,
    WorkflowSession,
    encode_memo,
    make_session,
)


class WorkflowError(RuntimeError):
    """The workflow exhausted its attempts."""


class StepFailure(RuntimeError):
    def __init__(self, step_name: str, cause: BaseException):
        super().__init__(f"step {step_name!r} failed: {cause!r}")
        self.step_name = step_name
        self.cause = cause


@dataclass
class WorkflowConfig:
    scope: TxnScope = TxnScope.WORKFLOW
    max_attempts: int = 6
    retry_backoff_ms: float = 5.0
    memoize: bool = True
    # keys the workflow intends to write — the unscoped baseline embeds this
    # as the cowritten set so auditors can score fractured states (§6.1.2)
    declared_writes: Tuple[str, ...] = ()
    # write a ``w/<uuid>`` finish marker after a successful run, licensing
    # GC of the workflow's memo records (core/gc.py).  Off by default here:
    # declaring finished promises the UUID is never re-driven, and a bare
    # executor cannot know that.  WorkflowPool, which owns workflow
    # lifecycle, turns it on by default.
    declare_finished: bool = False
    # STEP scope only: place every step's transaction independently at the
    # node the router scores best for its declared reads, instead of pinning
    # the whole workflow to one node (see workflow/txn.py StepTxnSession)
    place_steps: bool = False
    # STEP scope only: offload step commits to the node's storage I/O
    # pipeline so a commit overlaps the dispatch of dependent steps
    # (visibility barrier at the dependent's body start — see
    # workflow/txn.py).  Off by default here: the bare executor is the
    # simple blocking driver; WorkflowPool defaults it on.
    commit_offload: bool = False
    # honor ``Step.read_only`` declarations: such steps open their
    # transaction scope on the read-only fast lane (no version writes, no
    # commit record, no memo) — see core/node.py ``_commit_read_only``.
    # Disable to force every step through the full write path, e.g. to
    # measure the lane's benefit or when memoized resume of read-only
    # steps is worth more than their commit cost.
    read_only_lane: bool = True


@dataclass
class WorkflowResult:
    workflow_uuid: str
    results: Dict[str, Any]
    skipped: Tuple[str, ...]
    attempts: int
    steps_run: int
    steps_memoized: int
    committed_tid: Optional[TxnId]
    wall_ms: float
    scope: str
    # True when a re-driven uuid was resolved from its finish marker alone
    # (a rival drive already completed it): the workflow DID succeed, but
    # its memos may be GC'd, so ``results`` can be empty — callers needing
    # step outputs must persist them through AFT, not the ticket
    deduped: bool = False

    @property
    def resumed(self) -> bool:
        return self.steps_memoized > 0


class StepContext:
    """What a step body sees: upstream results, scoped state access, and the
    platform's failure-injection hook.  Writes are also recorded locally so
    the step can be memoized and replayed without re-running."""

    def __init__(
        self,
        step: Step,
        session: WorkflowSession,
        platform: LambdaPlatform,
        inputs: Dict[str, Any],
        args: Any,
    ):
        self._step = step
        self._session = session
        self._platform = platform
        self.inputs = inputs
        self.args = args
        self.writes: Dict[str, bytes] = {}
        # keys the body actually read, in first-touch order: memoized with
        # the result so a resume can infer its PlacementHint (routing
        # locality) without a manually declared Step.reads
        self.reads: list = []

    @property
    def step_name(self) -> str:
        return self._step.name

    @property
    def branch(self) -> Optional[int]:
        return self._step.branch

    @property
    def workflow_uuid(self) -> str:
        return self._session.uuid

    @property
    def placed_node(self) -> Optional[str]:
        """Node id this step's session was routed to (placement hints →
        router), or None when the session carries no node affinity
        (``place_steps`` resolves per step; unscoped sessions have none).
        Lets a step body reach node-local resources — e.g. the serving
        lane's per-node model replicas (``serve/lane.py``)."""
        session = self._session
        nodes = getattr(session, "_nodes", None)
        node = None
        if nodes and self._step.name in nodes:
            node = nodes[self._step.name]
        if node is None:
            node = getattr(session, "node", None)
        return getattr(node, "node_id", None) if node is not None else None

    def get(self, key: str) -> Optional[bytes]:
        if key not in self.reads:
            self.reads.append(key)
        return self._session.get(self._step.name, key)

    def put(self, key: str, value: bytes) -> None:
        self._session.put(self._step.name, key, value)
        self.writes[key] = value

    def maybe_fail(self, site: Optional[str] = None) -> None:
        """Mid-body failure point (fractional-execution hazard, §1)."""
        self._platform.maybe_fail(site=site or f"step:{self._step.name}")


def execute_step(
    step: Step,
    session: WorkflowSession,
    platform: LambdaPlatform,
    inputs: Dict[str, Any],
    args: Any,
    *,
    memoizing: bool,
    memo_store: Optional[MemoStore],
    read_only_lane: bool = True,
) -> Any:
    """Run one step body under a session — the unit every workflow driver
    shares.  ``WorkflowExecutor`` invokes it once per platform submission;
    ``WorkflowPool`` folds many of these (across workflows) into a single
    batched invocation.  Handles the begin-site failure point, memo encoding,
    and the inline-vs-separate memo commit split (see ``txn.py``).

    Steps declared ``read_only`` (when ``read_only_lane`` is on) skip memo
    encoding and persistence entirely: a memo's job is to make a *re-driven*
    step's writes replayable without re-execution, and a read-only step has
    no writes to replay — re-running its body against committed state is
    always safe, so the lane trades the memo write for a cheap re-read."""
    ro = read_only_lane and bool(getattr(step, "read_only", False))
    session.step_begin(step.name, step.reads, read_only=ro)
    ctx = StepContext(step, session, platform, inputs, args)
    platform.maybe_fail(site=f"step:{step.name}:begin")
    result = step.fn(ctx)
    if ro:
        session.step_commit(step.name, None)
        return result
    payload = (
        encode_memo(result, ctx.writes, reads=ctx.reads) if memoizing else None
    )
    inline = bool(getattr(session, "inline_memo", False))
    session.step_commit(step.name, payload if inline else None)
    if memoizing and not inline:
        assert memo_store is not None
        memo_store.save(
            session.uuid, step.name, payload,
            fresh=bool(getattr(session, "fresh", False)),
        )
    return result


class WorkflowExecutor:
    def __init__(
        self,
        platform: LambdaPlatform,
        *,
        cluster: Optional[AftCluster] = None,
        storage: Optional[StorageEngine] = None,
        config: Optional[WorkflowConfig] = None,
    ):
        self.platform = platform
        self.cluster = cluster
        self.storage = storage
        self.config = config or WorkflowConfig()
        self.stats = {
            "workflows": 0,
            "workflow_retries": 0,
            "steps_run": 0,
            "steps_memoized": 0,
            "steps_skipped": 0,
        }
        self._memo = MemoStore(cluster) if cluster is not None else None

    # ------------------------------------------------------------------ run
    def run(
        self,
        spec: WorkflowSpec,
        *,
        uuid: Optional[str] = None,
        args: Any = None,
    ) -> WorkflowResult:
        spec.validate()
        cfg = self.config
        # an explicit UUID is a cross-process resume/re-drive: consult memos
        # from the very first attempt, not just after an in-process failure
        resume_eligible = uuid is not None
        workflow_uuid = uuid or fresh_uuid()
        memoizing = (
            cfg.memoize and cfg.scope is not TxnScope.NONE and self._memo is not None
        )
        t0 = time.perf_counter()
        last_exc: Optional[BaseException] = None
        for attempt in range(1, cfg.max_attempts + 1):
            if attempt > 1:
                self.stats["workflow_retries"] += 1
                self.platform._sleep_ms(cfg.retry_backoff_ms * (attempt - 1))
            # memos load BEFORE the session exists: a resume/retry infers
            # its placement hint from the memoized steps' recorded read
            # sets, so locality routing works even when no Step.reads were
            # declared.  Declared reads stay first (deterministic ring
            # anchor); inferred keys extend them.
            memos: Dict[str, Tuple[Any, Dict[str, bytes]]] = {}
            records: list = []
            hint_keys = spec.declared_reads()
            if memoizing and (attempt > 1 or resume_eligible):
                memos, records, memo_reads = self._memo.load_all_with_reads(
                    workflow_uuid, spec.steps, scope=cfg.scope
                )
                hint_keys = hint_keys + tuple(
                    k for k in memo_reads if k not in hint_keys
                )
            session = make_session(
                cfg.scope,
                workflow_uuid,
                cluster=self.cluster,
                storage=self.storage,
                cowritten_hint=cfg.declared_writes,
                hint=PlacementHint(uuid=workflow_uuid, keys=hint_keys),
                place_steps=cfg.place_steps,
                commit_offload=cfg.commit_offload,
                # first attempt of a UUID minted just above: no rival can
                # have committed anything under it, so §3.3.1 probes are
                # pure overhead.  Retries and explicit re-drives must probe.
                fresh=(attempt == 1 and not resume_eligible),
            )
            if records:
                session.recover(records)
            try:
                results, skipped, ran, memoized = self._run_attempt(
                    spec, session, memos, args, memoizing
                )
                if spec.on_commit:
                    # chaining: trigger entries join the scope's commit
                    # story (atomic under WORKFLOW scope — see chain.py)
                    session.stage_triggers(spec.on_commit, results)
                tid = session.finish()
            except Exception as exc:
                # retry every *failure*; KeyboardInterrupt/SystemExit must
                # still interrupt the loop (BaseException stays fatal)
                last_exc = exc
                session.abandon()
                continue
            except BaseException:
                session.abandon()  # release the txn before dying
                raise
            self.stats["workflows"] += 1
            self.stats["steps_run"] += ran
            self.stats["steps_memoized"] += memoized
            self.stats["steps_skipped"] += len(skipped)
            if memoizing and cfg.declare_finished:
                assert self._memo is not None
                self._memo.mark_finished(workflow_uuid)
            return WorkflowResult(
                workflow_uuid=workflow_uuid,
                results=results,
                skipped=tuple(sorted(skipped)),
                attempts=attempt,
                steps_run=ran,
                steps_memoized=memoized,
                committed_tid=tid,
                wall_ms=(time.perf_counter() - t0) * 1e3,
                scope=cfg.scope.value,
            )
        raise WorkflowError(
            f"workflow {spec.name!r} ({workflow_uuid}) failed after "
            f"{cfg.max_attempts} attempts"
        ) from last_exc

    # -------------------------------------------------------------- attempt
    def _run_attempt(
        self,
        spec: WorkflowSpec,
        session: WorkflowSession,
        memos: Dict[str, Tuple[Any, Dict[str, bytes]]],
        args: Any,
        memoizing: bool,
    ) -> Tuple[Dict[str, Any], Set[str], int, int]:
        indeg = {name: len(s.deps) for name, s in spec.steps.items()}
        dependents = spec.dependents_of()
        results: Dict[str, Any] = {}
        skipped: Set[str] = set()
        ran = 0
        memoized = 0
        ready = deque(n for n, d in indeg.items() if d == 0)
        in_flight: Dict[Future, str] = {}

        def resolve(name: str) -> None:
            for m in dependents[name]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)

        def launch(name: str) -> None:
            nonlocal memoized
            step = spec.steps[name]
            missing = [d for d in step.deps if d in skipped]
            if missing and not step.allow_skipped_deps:
                skipped.add(name)
                resolve(name)
                return
            inputs = {d: results[d] for d in step.deps if d not in skipped}
            if step.when is not None and not step.when(inputs):
                skipped.add(name)
                resolve(name)
                return
            if name in memos:
                # §3.3.1 extended to steps: the body already ran to
                # completion in a prior attempt — feed its recorded result
                # downstream and replay its writes into this session.
                result, writes = memos[name]
                session.replay(name, writes)
                results[name] = result
                memoized += 1
                resolve(name)
                return
            fut = self.platform.submit(self._run_step, step, session, inputs, args, memoizing)
            in_flight[fut] = name

        while ready or in_flight:
            while ready:
                launch(ready.popleft())
            if not in_flight:
                break
            done, _ = wait(set(in_flight), return_when=FIRST_COMPLETED)
            failure: Optional[StepFailure] = None
            for fut in done:
                name = in_flight.pop(fut)
                exc = fut.exception()
                if exc is not None:
                    failure = failure or StepFailure(name, exc)
                    continue
                results[name] = fut.result()
                ran += 1
                resolve(name)
            if failure is not None:
                # drain sibling branches before rolling back the attempt so
                # abandon() can't race their in-flight get/put calls
                wait(set(in_flight))
                raise failure
        return results, skipped, ran, memoized

    def _run_step(
        self,
        step: Step,
        session: WorkflowSession,
        inputs: Dict[str, Any],
        args: Any,
        memoizing: bool,
    ) -> Any:
        return execute_step(
            step, session, self.platform, inputs, args,
            memoizing=memoizing, memo_store=self._memo,
            read_only_lane=self.config.read_only_lane,
        )
