"""Declarative workflow specs: DAGs of named FaaS steps.

AFT's request model (§2.2) is a *linear* composition of functions.  Real
serverless applications (Beldi, Cloudburst — see PAPERS.md) compose functions
into DAGs: fan-out over shards, fan-in aggregation, conditional routing.  A
``WorkflowSpec`` captures that shape declaratively:

* a **step** is a named function body taking a :class:`StepContext` (state
  access routed through the workflow's transaction scope, upstream results,
  failure-injection hook) and returning a JSON-serializable result;
* **data dependencies** (``deps``) order steps; everything whose deps are
  satisfied runs in parallel on the FaaS platform;
* **conditional edges**: a step with ``when=`` is evaluated against its
  upstream results and *skipped* when the predicate is false; skips propagate
  to exclusive dependents (a fan-in step can opt in to partial inputs with
  ``allow_skipped_deps``);
* **fan-out/fan-in** helpers stamp out indexed parallel branches
  (``shard[0..n)``) and their aggregation step.

Specs are pure data + callables; execution semantics (parallelism,
transaction scoping, retry, memoized resume) live in ``executor.py``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


class WorkflowSpecError(ValueError):
    """The spec is not a well-formed DAG (cycle, unknown dep, dup name)."""


@dataclass
class Step:
    """One node of the DAG.

    ``fn(ctx)`` receives a :class:`repro.workflow.executor.StepContext`.
    ``when(results)`` — if present — sees a dict of the step's *upstream*
    results (skipped deps absent) and gates execution.  ``branch`` is set on
    fan-out clones so one body can serve every branch.  ``reads`` is the
    step's *declared* read set — advisory placement metadata (most-important
    key first) that locality-aware routing (``core/routing.py``) uses to
    schedule the step near cached data; it never constrains what the body
    may actually read.  ``read_only`` *is* a contract: the step declares it
    will never ``ctx.put`` — its transaction rides the read-only fast lane
    (no version writes, no commit record, no §3.3.1 probe) and a write
    attempt raises ``ReadOnlyTransaction``, failing the step attempt.
    """

    name: str
    fn: Callable[..., Any]
    deps: Tuple[str, ...] = ()
    when: Optional[Callable[[Dict[str, Any]], bool]] = None
    allow_skipped_deps: bool = False
    branch: Optional[int] = None
    reads: Tuple[str, ...] = ()
    read_only: bool = False


class WorkflowSpec:
    def __init__(self, name: str):
        self.name = name
        self.steps: Dict[str, Step] = {}
        # cross-workflow chaining edges (``repro.workflow.chain.Trigger``):
        # fired atomically with the workflow's commit — the trigger entry is
        # folded into the commit record, so it exists iff the DAG committed
        self.on_commit: List[Any] = []

    # ------------------------------------------------------------ builders
    def add(self, step: Step) -> str:
        if step.name in self.steps:
            raise WorkflowSpecError(f"duplicate step name {step.name!r}")
        self.steps[step.name] = step
        return step.name

    def step(
        self,
        name: str,
        fn: Callable[..., Any],
        *,
        deps: Sequence[str] = (),
        when: Optional[Callable[[Dict[str, Any]], bool]] = None,
        allow_skipped_deps: bool = False,
        reads: Sequence[str] = (),
        read_only: bool = False,
    ) -> str:
        return self.add(
            Step(
                name=name,
                fn=fn,
                deps=tuple(deps),
                when=when,
                allow_skipped_deps=allow_skipped_deps,
                reads=tuple(reads),
                read_only=read_only,
            )
        )

    def fan_out(
        self,
        prefix: str,
        fn: Callable[..., Any],
        n: int,
        *,
        deps: Sequence[str] = (),
        when: Optional[Callable[[Dict[str, Any]], bool]] = None,
        reads: Optional[Callable[[int], Sequence[str]]] = None,
        read_only: bool = False,
    ) -> List[str]:
        """Stamp out ``n`` parallel branches ``prefix[i]`` sharing one body;
        the body distinguishes branches via ``ctx.branch``.  ``reads(i)``
        optionally declares branch ``i``'s read set for placement."""
        if n < 1:
            raise WorkflowSpecError(f"fan_out needs n >= 1, got {n}")
        names = []
        for i in range(n):
            names.append(
                self.add(
                    Step(
                        name=f"{prefix}[{i}]",
                        fn=fn,
                        deps=tuple(deps),
                        when=when,
                        branch=i,
                        reads=tuple(reads(i)) if reads is not None else (),
                        read_only=read_only,
                    )
                )
            )
        return names

    def fan_in(
        self,
        name: str,
        fn: Callable[..., Any],
        deps: Sequence[str],
        *,
        allow_skipped_deps: bool = True,
        reads: Sequence[str] = (),
        read_only: bool = False,
    ) -> str:
        """Aggregation step over parallel branches; by default tolerates
        conditionally-skipped inputs (it sees only the results that exist)."""
        return self.add(
            Step(
                name=name,
                fn=fn,
                deps=tuple(deps),
                allow_skipped_deps=allow_skipped_deps,
                reads=tuple(reads),
                read_only=read_only,
            )
        )

    def trigger(self, trigger: Any) -> Any:
        """Declare an ``on_commit`` chaining edge: when this workflow
        commits, the given :class:`repro.workflow.chain.Trigger` durably
        enqueues its child workflow, exactly once, through AFT's own commit
        protocol (see ``chain.py``).  Returns the trigger for chaining."""
        self.on_commit.append(trigger)
        return trigger

    # ---------------------------------------------------------- validation
    def validate(self) -> None:
        if self.on_commit:
            from .chain import validate_triggers

            validate_triggers(self)
        for step in self.steps.values():
            for dep in step.deps:
                if dep not in self.steps:
                    raise WorkflowSpecError(
                        f"step {step.name!r} depends on unknown step {dep!r}"
                    )
                if dep == step.name:
                    raise WorkflowSpecError(f"step {step.name!r} depends on itself")
        self.topological_order()  # raises on cycles

    def topological_order(self) -> List[str]:
        """Kahn's algorithm; deterministic (insertion-order tie-break)."""
        indeg = {name: len(s.deps) for name, s in self.steps.items()}
        dependents: Dict[str, List[str]] = {name: [] for name in self.steps}
        for name, s in self.steps.items():
            for dep in s.deps:
                if dep in dependents:
                    dependents[dep].append(name)
        ready = [n for n, d in indeg.items() if d == 0]
        order: List[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m in dependents[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(self.steps):
            stuck = sorted(set(self.steps) - set(order))
            raise WorkflowSpecError(f"cycle through steps {stuck}")
        return order

    # ------------------------------------------------------------- queries
    def roots(self) -> List[str]:
        return [n for n, s in self.steps.items() if not s.deps]

    def declared_reads(self) -> Tuple[str, ...]:
        """Union of every step's declared read set, first-declared first
        (deduped).  The workflow-level placement hint: under WORKFLOW scope
        the whole DAG runs on one node, so the session is routed by what the
        DAG as a whole intends to read."""
        seen: Dict[str, None] = {}
        for step in self.steps.values():
            for key in step.reads:
                seen.setdefault(key, None)
        return tuple(seen)

    def dependents_of(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {name: [] for name in self.steps}
        for name, s in self.steps.items():
            for dep in s.deps:
                out[dep].append(name)
        return out

    def __len__(self) -> int:
        return len(self.steps)

    def __contains__(self, name: str) -> bool:
        return name in self.steps
