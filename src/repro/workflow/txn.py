"""Transaction scoping for workflows: how a DAG maps onto AFT transactions.

Three scopes, chosen per run:

* ``TxnScope.WORKFLOW`` — the whole DAG is **one** AFT transaction.  Every
  branch's reads go through Algorithm 1 on the same session (read-atomic
  across the fan-out) and every write is buffered until the single commit at
  the end, so a crash anywhere in the DAG never persists a fractured subset
  of updates.  The transaction UUID is the workflow UUID; a retried workflow
  reopens it (§3.3.1) and the final commit is idempotent.

* ``TxnScope.STEP`` — each step is its own AFT transaction whose UUID is
  *derived deterministically* from (workflow UUID, step name), so a retried
  step recommits exactly once even across nodes.  Steps are individually
  atomic but the DAG as a whole is not (the Beldi-style middle ground).

* ``TxnScope.NONE`` — the unshimmed baseline: writes land in place on the
  storage engine immediately (with §6.1.2-style embedded metadata so anomaly
  detectors can see what happened).  A mid-branch crash leaves a fractured
  prefix visible, and a retry re-applies effects — this is the anomaly
  source ``benchmarks/fig_workflow`` measures.

The **memo store** rides on AFT itself: a completed step's result and write
set are committed under a reserved key (``.wf/<uuid>/<step>``) by a separate
transaction whose UUID derives from (workflow UUID, step name).  AFT's
idempotent commit (§3.3.1) makes memoization exactly-once, and a retried
workflow resumes by replaying memoized writes into its fresh session instead
of re-running step bodies.

Memo records are write-once, so the §5 supersedence GC can never reclaim
them.  Instead, when a driver (``WorkflowExecutor`` / ``WorkflowPool``)
declares a workflow **finished**, ``MemoStore.mark_finished`` persists a
``w/<uuid>`` marker; the finished-workflow sweep in ``core/gc.py`` then
deletes the workflow's ``.wf/`` memo records and derived ``u/`` index
entries.  Declaring finished is a promise that the UUID will never be
re-driven — see ``docs/WORKFLOWS.md``.
"""

from __future__ import annotations

import base64
import json
import threading
import time
from concurrent.futures import Future
from enum import Enum
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

from ..core import AftCluster, PlacementHint, TxnId
from ..core.ids import Clock, fresh_uuid
from ..core.records import (
    WF_MEMO_TXN_INFIX,
    WF_STEP_TXN_INFIX,
    WORKFLOW_MEMO_PREFIX,
    embed_metadata,
    enqueue_txn_uuid,
    extract_metadata,
    workflow_finish_key,
)
from ..storage.base import StorageEngine

MEMO_PREFIX = WORKFLOW_MEMO_PREFIX


class TxnScope(Enum):
    WORKFLOW = "workflow"
    STEP = "step"
    NONE = "none"


def memo_key(workflow_uuid: str, step_name: str) -> str:
    return f"{MEMO_PREFIX}{workflow_uuid}/{step_name}"


def step_txn_uuid(workflow_uuid: str, step_name: str) -> str:
    """Deterministic per-step transaction UUID (§3.3.1 idempotence unit)."""
    return f"{workflow_uuid}{WF_STEP_TXN_INFIX}{step_name}"


def memo_txn_uuid(workflow_uuid: str, step_name: str) -> str:
    return f"{workflow_uuid}{WF_MEMO_TXN_INFIX}{step_name}"


# ---------------------------------------------------------------------------
# memo records
# ---------------------------------------------------------------------------

def encode_memo(
    result: Any, writes: Dict[str, bytes], reads: Sequence[str] = ()
) -> bytes:
    """``reads`` — the keys the step body actually read, recorded so a
    resume/retry can infer a :class:`PlacementHint` from the memo instead
    of requiring a manually declared ``Step.reads`` set."""
    body: Dict[str, Any] = {
        "result": result,
        "writes": {
            k: base64.b64encode(v).decode("ascii")
            for k, v in writes.items()
        },
    }
    if reads:
        body["reads"] = list(reads)
    try:
        return json.dumps(body, separators=(",", ":")).encode()
    except (TypeError, ValueError) as exc:
        raise TypeError(
            "step results must be JSON-serializable to be memoized "
            f"(got {type(result).__name__}); return plain data or disable "
            "memoization"
        ) from exc


def decode_memo(raw: bytes) -> Tuple[Any, Dict[str, bytes]]:
    result, writes, _reads = decode_memo_full(raw)
    return result, writes


def decode_memo_full(
    raw: bytes,
) -> Tuple[Any, Dict[str, bytes], Tuple[str, ...]]:
    body = json.loads(raw)
    writes = {
        k: base64.b64decode(v.encode("ascii"))
        for k, v in body.get("writes", {}).items()
    }
    return body.get("result"), writes, tuple(body.get("reads", ()))


class MemoStore:
    """Per-step result persistence *through* AFT (exactly-once by UUID).

    With ``offload=True`` (set by drivers running commit offload) the memo
    commit rides the node's storage I/O pipeline and ``save`` returns
    without waiting for durability.  Losing an offloaded memo to a crash is
    safe by construction: the step simply re-runs on retry and its memo
    recommits under the same deterministic UUID (§3.3.1) — the memo is an
    optimization, never the correctness anchor."""

    def __init__(self, cluster: AftCluster, *, offload: bool = False):
        self.cluster = cluster
        self.offload = offload

    def save(
        self, workflow_uuid: str, step_name: str, payload: bytes,
        *, fresh: bool = False,
    ) -> None:
        """``fresh=True``: this memo's workflow UUID was minted this
        attempt (first attempt, not a re-drive), so no rival can have
        committed the memo — the §3.3.1 probe is skipped."""
        client = self.cluster.client()
        tx = client.start_transaction(
            memo_txn_uuid(workflow_uuid, step_name), fresh=fresh
        )
        client.put(tx, memo_key(workflow_uuid, step_name), payload)
        if not self.offload:
            client.commit_transaction(tx)
            return
        # fire-and-forget (see class docstring) — but a FAILED commit must
        # still abort the session, or its RUNNING context (and buffered
        # payload) would sit in node._txns until the §3.3.1 timeout sweep,
        # inflating the open-sessions load signal routing reads
        def _cleanup(f) -> None:
            if f.exception() is not None:
                try:
                    client.abort_transaction(tx)
                except Exception:
                    pass  # node died; the timeout sweep is the backstop

        client.commit_transaction_async(tx).add_done_callback(_cleanup)

    def mark_finished(
        self, workflow_uuid: str, extra: Optional[Dict[str, Any]] = None
    ) -> None:
        """Declare the workflow done: persist the ``w/<uuid>`` marker that
        licenses the GC sweep (``LocalGcAgent.gc_finished_workflows``) to
        reclaim this workflow's memo records and ``u/`` index entries.  A
        plain storage put, not a transaction: the marker is advisory GC
        state, and a crash before it lands merely defers reclamation.
        ``extra`` extends the marker payload — chaining records the
        ``{"chain": {"queue", "entry"}}`` provenance here so the sweep can
        reclaim the trigger-queue entry that spawned the workflow."""
        body = {"finished_at_ns": time.time_ns()}
        if extra:
            body.update(extra)
        self.cluster.storage.put(
            workflow_finish_key(workflow_uuid),
            json.dumps(body).encode(),
        )

    def probe(
        self,
        workflow_uuid: str,
        step_name: str,
        scope: Optional[TxnScope] = None,
    ):
        """Late memo re-check for ONE step: did a rival attempt commit this
        step's memo after our ``load_all``?  Two-to-three point reads
        through the ``u/`` index.  The pool probes this just before running
        a resumed step's body, closing the window a replayed chain trigger
        (or any concurrent re-drive of the same UUID) opens between memo
        load and dispatch.  Returns ``((result, writes), records)`` —
        the rival's commit records MUST be recovered into the session
        (``WorkflowSession.recover``) like load_all's, or a dependent step
        placed on another node could read NULL for the rival-committed
        write — or ``None`` when no memo exists."""
        found, records = self.load_all(workflow_uuid, [step_name], scope)
        memo = found.get(step_name)
        if memo is None:
            return None
        return memo, records

    def load_all(
        self,
        workflow_uuid: str,
        step_names: Iterable[str],
        scope: Optional[TxnScope] = None,
    ):
        """Recover every memoized step from durable storage (§3.1) — not
        through a node's metadata cache, because a retry may land before
        multicast has propagated the memo commits (§3.3.1's rare-path
        reasoning).  A missed memo is safe either way (the step re-runs and
        recommits idempotently), but reading the source of truth makes
        resume deterministic.  Cost is O(steps) point reads through the
        ``u/`` uuid index.

        Returns ``(memos, records)``: the decoded memo per step name, plus
        the workflow's commit records so the caller can merge them into
        whichever node the retry pins to (the §4.2 propagation multicast
        would eventually perform, done eagerly) — without this, a resumed
        step on a fresh node could read NULL for a sibling's committed write.
        """
        found, records, _reads = self.load_all_with_reads(
            workflow_uuid, step_names, scope
        )
        return found, records

    def load_all_with_reads(
        self,
        workflow_uuid: str,
        step_names: Iterable[str],
        scope: Optional[TxnScope] = None,
    ):
        """:meth:`load_all` plus the union of the memoized steps' recorded
        read sets (ordered, deduped) — the keys the workflow's bodies
        *actually* touched.  Drivers feed these into the resume attempt's
        :class:`PlacementHint` so locality routing works without a manually
        declared ``Step.reads``.  Returns ``(memos, records, reads)``."""
        from ..core.records import lookup_committed_record

        storage = self.cluster.storage
        found: Dict[str, Tuple[Any, Dict[str, bytes]]] = {}
        records = []
        reads: list = []
        seen_reads: set = set()
        for name in step_names:
            # a memo commit is either its own transaction (TxnScope.WORKFLOW)
            # or rides inside the step's transaction (TxnScope.STEP); when
            # the scope is known, probe only the UUID that can exist
            if scope is TxnScope.WORKFLOW:
                candidates = (memo_txn_uuid(workflow_uuid, name),)
            elif scope is TxnScope.STEP:
                candidates = (step_txn_uuid(workflow_uuid, name),)
            else:
                candidates = (
                    memo_txn_uuid(workflow_uuid, name),
                    step_txn_uuid(workflow_uuid, name),
                )
            record = None
            for u in candidates:
                record = lookup_committed_record(storage, u)
                if record is not None:
                    break
            if record is None:
                continue
            records.append(record)
            payload = storage.get(
                record.storage_key_for(memo_key(workflow_uuid, name))
            )
            if payload is not None:
                result, writes, step_reads = decode_memo_full(payload)
                found[name] = (result, writes)
                for key in step_reads:
                    if key not in seen_reads:
                        seen_reads.add(key)
                        reads.append(key)
        return found, records, tuple(reads)


# ---------------------------------------------------------------------------
# scoped sessions
# ---------------------------------------------------------------------------

class WorkflowSession:
    """State-access surface handed to steps, one per workflow *attempt*.

    ``get``/``put`` are called concurrently from parallel branches; every
    implementation below is safe for that (the AFT node itself is
    thread-safe per session, the unscoped baseline writes through to the
    engine).
    """

    uuid: str
    # True ⇒ the memo payload rides inside the step's own transaction (so
    # "memo exists" ⇔ "step committed"); False ⇒ the executor persists the
    # memo as a separate idempotent transaction after the body returns.
    inline_memo = False
    # True ⇒ this attempt's workflow UUID was minted locally this attempt
    # (first attempt, not a resume/re-drive), so no rival commit can exist
    # anywhere and the §3.3.1 probes are skipped (core/node.py fresh=)
    fresh = False

    def get(self, step_name: str, key: str) -> Optional[bytes]:
        raise NotImplementedError

    def put(self, step_name: str, key: str, value: bytes) -> None:
        raise NotImplementedError

    def step_begin(self, step_name: str, reads: Sequence[str] = (),
                   read_only: bool = False) -> None:
        """Called before a step body runs.  ``reads`` is the step's declared
        read set — per-step scopes may use it to place the step's
        transaction near cached data (``core/routing.py``).  ``read_only``
        declares the step will never write: per-step scopes open its
        transaction on the read-only fast lane (no version writes, commit
        record or §3.3.1 probe); scopes whose transactions span steps
        ignore it (the enclosing transaction may still write)."""

    def step_commit(self, step_name: str, memo_payload: Optional[bytes]) -> None:
        """Called after a step body returns; per-step scopes commit here."""

    def replay(self, step_name: str, writes: Dict[str, bytes]) -> None:
        """Re-apply a memoized step's writes without re-running its body."""
        for key, value in writes.items():
            self.put(step_name, key, value)

    def recover(self, records) -> None:
        """Merge the workflow's prior commit records (from the durable
        Commit Set) into this attempt's node, closing the multicast window."""

    def stage_triggers(self, triggers, results: Dict[str, Any]) -> None:
        """Resolve the spec's ``on_commit`` edges against the completed
        results and make their trigger-queue entries part of this scope's
        commit story (``repro/workflow/chain.py``).  Called by the driver
        after the DAG's last step, before ``finish()``.  Scope determines
        the handoff guarantee: WORKFLOW folds entries into the single
        atomic commit, STEP enqueues via standalone deterministic
        transactions at finish, NONE is the lose/duplicate baseline."""
        raise NotImplementedError

    def finish(self) -> Optional[TxnId]:
        """Commit whatever the scope holds open; idempotent on retry."""
        return None

    def finish_async(self) -> "Future[Optional[TxnId]]":
        """Commit-offload variant of :meth:`finish`: returns a future that
        resolves when the scope's final commit is durable.  The base
        implementation degrades to the blocking path; sessions backed by a
        storage I/O pipeline override it."""
        fut: "Future[Optional[TxnId]]" = Future()
        try:
            fut.set_result(self.finish())
        except BaseException as exc:  # noqa: BLE001 - delivered via future
            fut.set_exception(exc)
        return fut

    def abandon(self) -> None:
        """Attempt failed: roll back anything uncommitted."""


class WorkflowTxnSession(WorkflowSession):
    """One AFT transaction spanning the whole DAG (``TxnScope.WORKFLOW``).

    The whole workflow stays pinned to one node per §3.1, but *which* node
    is a routing decision: the placement hint (workflow uuid + declared
    read set) lets locality-aware policies pick the node whose cache
    already holds the DAG's reads.
    """

    def __init__(
        self,
        cluster: AftCluster,
        workflow_uuid: str,
        hint: Optional[PlacementHint] = None,
        fresh: bool = False,
    ):
        self.client = cluster.client()
        self.fresh = fresh
        self.txid = self.client.start_transaction(
            workflow_uuid, hint=hint, fresh=fresh
        )
        self.uuid = self.txid
        self.node = self.client.node_of(self.txid)

    def get(self, step_name: str, key: str) -> Optional[bytes]:
        return self.node.get(self.txid, key)

    def put(self, step_name: str, key: str, value: bytes) -> None:
        self.node.put(self.txid, key, value)

    def recover(self, records) -> None:
        if records:
            self.node.merge_remote_commits(records)

    def stage_triggers(self, triggers, results: Dict[str, Any]) -> None:
        # the exactly-once handoff (§3.3.1 extended to chaining): entries
        # are ordinary buffered writes of THIS transaction, so they become
        # durable atomically with the DAG's effects at commit — no commit,
        # no trigger; retried commit, same entries, still one trigger
        from .chain import build_entries

        for _entry_id, entry_key, payload in build_entries(
            self.uuid, triggers, results
        ):
            self.node.put(self.txid, entry_key, payload)

    def finish(self) -> Optional[TxnId]:
        return self.client.commit_transaction(self.txid)

    def finish_async(self) -> "Future[Optional[TxnId]]":
        # the DAG's single commit rides the node's I/O pipeline: version
        # writes group-commit with other in-flight workflows' commits, and
        # the caller (pool finisher) is free the moment it is enqueued
        return self.client.commit_transaction_async(self.txid)

    def abandon(self) -> None:
        try:
            self.client.abort_transaction(self.txid)
        except Exception:
            pass  # node may have died; timeout sweep is the backstop


class StepTxnSession(WorkflowSession):
    """One AFT transaction per step (``TxnScope.STEP``).

    The memo record is written *inside* the step's transaction, so "step
    committed" and "memo exists" are the same event — a retry that finds the
    memo knows the step's writes are already durable and atomic.

    Commit offload (``commit_offload=True``): a step's commit is submitted
    to the node's storage I/O pipeline and the body returns immediately, so
    the *dispatch* of dependent steps (batching, platform invocation,
    queueing) overlaps the commit flush.  The §3.1 visibility contract is
    preserved by a drain barrier: ``step_begin`` waits for every earlier
    offloaded commit of this workflow before the new step's body reads, so
    a dependent can never observe a predecessor's pre-commit state — the
    wait happens on the platform worker *after* dispatch overhead is paid.
    A failed offloaded commit surfaces at that barrier (or at ``finish``)
    and fails the attempt, which retries under the same UUIDs (§3.3.1).

    Placement: by default (§3.1 extended to DAGs) every step transaction of
    one workflow pins to a single node, so a step's commit is locally
    visible to its dependents immediately — no multicast round in the
    critical path.  With ``place_steps=True`` each step is instead routed
    *independently* by its declared read set (Cloudburst-style locality,
    ``core/routing.py``); dependent-visibility is preserved by eagerly
    merging the workflow's earlier commit records into each step's node
    (the §4.2 propagation done synchronously for just this workflow), so a
    dependent scheduled on a different node still reads its upstream's
    committed writes.  Either way, if a node dies mid-workflow the attempt
    fails and the retry routes to live nodes; deterministic UUIDs + the
    §3.3.1 commit-set verify keep recommits exactly-once across nodes.
    """

    inline_memo = True

    def __init__(
        self,
        cluster: AftCluster,
        workflow_uuid: str,
        hint: Optional[PlacementHint] = None,
        place_steps: bool = False,
        commit_offload: bool = False,
        fresh: bool = False,
    ):
        self.cluster = cluster
        self.uuid = workflow_uuid
        self.place_steps = place_steps
        self.commit_offload = commit_offload
        self.fresh = fresh
        self._lock = threading.Lock()
        self._txids: Dict[str, str] = {}
        self._nodes: Dict[str, "object"] = {}  # step_name → AftNode
        self._records: list = []  # this workflow's commit records so far
        self._pending: Dict[str, Future] = {}  # offloaded commits in flight
        self._commit_failure: Optional[BaseException] = None  # latched
        self._staged_triggers: list = []  # (entry_id, key, payload) at finish
        self.node = None if place_steps else cluster.pick_node(hint)

    def _drain_commits(self) -> None:
        """Visibility barrier for commit offload: block until every
        offloaded step commit of this workflow has landed, surfacing the
        first failure (which fails the attempt → whole-workflow retry).
        Failures are latched, so a commit that failed *between* barriers is
        still reported at the next one, never silently dropped."""
        with self._lock:
            pending = list(self._pending.values())
            failure = self._commit_failure
        if failure is not None:
            raise failure
        for fut in pending:
            exc = fut.exception()  # waits for completion
            if exc is not None:
                raise exc

    def step_begin(self, step_name: str, reads: Sequence[str] = (),
                   read_only: bool = False) -> None:
        self._drain_commits()
        if self.place_steps:
            node = self.cluster.pick_node(
                PlacementHint(
                    uuid=step_txn_uuid(self.uuid, step_name),
                    keys=tuple(reads),
                )
            )
            with self._lock:
                records = list(self._records)
            if records:
                # close the multicast window for THIS workflow: the chosen
                # node may not have heard siblings'/upstreams' commits yet
                node.merge_remote_commits(records)
        else:
            node = self.node
        txid = node.start_transaction(
            step_txn_uuid(self.uuid, step_name), fresh=self.fresh,
            read_only=read_only,
        )
        with self._lock:
            self._txids[step_name] = txid
            self._nodes[step_name] = node

    def _bound(self, step_name: str):
        with self._lock:
            return self._nodes[step_name], self._txids[step_name]

    def get(self, step_name: str, key: str) -> Optional[bytes]:
        node, txid = self._bound(step_name)
        return node.get(txid, key)

    def put(self, step_name: str, key: str, value: bytes) -> None:
        node, txid = self._bound(step_name)
        node.put(txid, key, value)

    def step_commit(self, step_name: str, memo_payload: Optional[bytes]) -> None:
        node, txid = self._bound(step_name)
        if memo_payload is not None:
            node.put(txid, memo_key(self.uuid, step_name), memo_payload)
        if self.commit_offload:
            self._step_commit_async(step_name, node, txid)
            return
        tid = node.commit_transaction(txid)
        self._step_committed(step_name, node, txid, tid)

    def _step_committed(self, step_name: str, node, txid: str, tid) -> None:
        if self.place_steps:
            record = node.cache.get(tid)  # None for read-only steps
            if record is not None:
                with self._lock:
                    self._records.append(record)
        node.release_transaction(txid)
        with self._lock:
            self._txids.pop(step_name, None)
            self._nodes.pop(step_name, None)

    def _step_commit_async(self, step_name: str, node, txid: str) -> None:
        # The barrier waits on a GATE future resolved only after this
        # session's post-commit bookkeeping ran — waiting on the node's
        # future directly would race it: Future.set_result wakes waiters
        # BEFORE running callbacks, so a dependent could pass the barrier,
        # snapshot self._records without the upstream's record, and (under
        # place_steps) read stale state on its node.
        gate: Future = Future()
        with self._lock:
            self._pending[step_name] = gate
            # unbind now: the step is done dispatching; the commit's fate is
            # carried by the pending gate (drained before dependents read)
            self._txids.pop(step_name, None)
            self._nodes.pop(step_name, None)

        def _done(f: Future) -> None:
            exc = f.exception()
            if exc is None:
                self._step_committed_async_record(node, txid, f.result())
            else:
                # the commit REPORTED failure (it may still have landed —
                # the lost-ack window): abort so the RUNNING context is not
                # leaked until the §3.3.1 timeout sweep.  Abort is safe
                # either way: once a commit reached storage it never
                # deletes spilled bytes (core/node.py), and the retry's
                # idempotence probe resolves the true outcome.
                try:
                    node.abort_transaction(txid)
                    node.release_transaction(txid)
                except Exception:
                    pass  # node died; the timeout sweep is the backstop
            with self._lock:
                self._pending.pop(step_name, None)
                if exc is not None and self._commit_failure is None:
                    self._commit_failure = exc
            if exc is None:
                gate.set_result(None)
            else:
                gate.set_exception(exc)

        node.commit_transaction_async(txid).add_done_callback(_done)

    def _step_committed_async_record(self, node, txid: str, tid) -> None:
        if self.place_steps:
            record = node.cache.get(tid)
            if record is not None:
                with self._lock:
                    self._records.append(record)
        node.release_transaction(txid)

    def replay(self, step_name: str, writes: Dict[str, bytes]) -> None:
        pass  # memo present ⇔ the step's transaction already committed

    def recover(self, records) -> None:
        with self._lock:
            self._records.extend(records)
        if not self.place_steps and records:
            self.node.merge_remote_commits(records)

    def stage_triggers(self, triggers, results: Dict[str, Any]) -> None:
        from .chain import build_entries

        self._staged_triggers = build_entries(self.uuid, triggers, results)

    def finish(self) -> Optional[TxnId]:
        # commit-offload barrier: the DAG is only done when every offloaded
        # step commit is durable (a straggler failure fails the attempt)
        self._drain_commits()
        # STEP scope has no single DAG commit to fold entries into; each
        # entry gets its own *deterministic* enqueue transaction
        # ("<entry>.enq"), so a retried finish recommits idempotently
        # (§3.3.1) — exactly-once, though not atomic with the step writes
        # (the DAG as a whole never was under this scope).
        for entry_id, entry_key, payload in self._staged_triggers:
            node = self.node or self.cluster.pick_node(
                PlacementHint(uuid=entry_id)
            )
            txid = node.start_transaction(enqueue_txn_uuid(entry_id))
            node.put(txid, entry_key, payload)
            node.commit_transaction(txid)
            node.release_transaction(txid)
        return None

    def abandon(self) -> None:
        # let offloaded commits settle first: an in-flight §3.3 commit
        # cannot be revoked, and racing an abort against it would be wrong
        # either way (the retry's idempotence probe resolves the outcome)
        try:
            self._drain_commits()
        except BaseException:  # noqa: BLE001 - already abandoning
            pass
        with self._lock:
            pending = [
                (self._nodes[name], txid)
                for name, txid in self._txids.items()
                if name in self._nodes
            ]
            self._txids.clear()
            self._nodes.clear()
        for node, txid in pending:
            try:
                node.abort_transaction(txid)
                node.release_transaction(txid)
            except Exception:
                pass


class UnscopedSession(WorkflowSession):
    """No shim (``TxnScope.NONE``): in-place writes, immediately visible.

    Embeds §6.1.2 metadata (timestamp, UUID, the workflow's declared
    cowritten key set) in every value so external auditors can score the
    fractured states this scope produces.  ``cowritten_hint`` is the set of
    keys the workflow intends to write — the baseline equivalent of a commit
    record's write set.
    """

    _clock = Clock()

    def __init__(
        self,
        storage: StorageEngine,
        workflow_uuid: str,
        cowritten_hint: Sequence[str] = (),
    ):
        self.storage = storage
        self.uuid = workflow_uuid
        self.cowritten = tuple(sorted(cowritten_hint))
        self.tid = TxnId(self._clock.now_ns(), fresh_uuid())

    def get(self, step_name: str, key: str) -> Optional[bytes]:
        raw = self.storage.get(key)
        if raw is None:
            return None
        value, _tid, _cow = extract_metadata(raw)
        return value

    def put(self, step_name: str, key: str, value: bytes) -> None:
        cow = self.cowritten or (key,)
        self.storage.put(key, embed_metadata(value, self.tid, cow))

    def stage_triggers(self, triggers, results: Dict[str, Any]) -> None:
        # the anomaly baseline: the handoff is a separate, non-atomic,
        # non-idempotent put to a RAW ``q/...`` key (no ``d/`` version
        # namespace — unscoped writes never have one, so ``ChainConsumer``'s
        # versioned discovery deliberately cannot see these; a baseline
        # consumer lists the raw prefix, as benchmarks/fig_chain.py does).
        # A crash between the DAG's effects and this write LOSES the
        # trigger; a retried attempt enqueues ANOTHER entry (fresh suffix —
        # nothing dedups it), so a baseline consumer double-fires.  That
        # lose/duplicate pair is what fig_chain quantifies against the
        # AFT-scoped queue.
        from .chain import build_entries

        for _entry_id, entry_key, payload in build_entries(
            self.uuid, triggers, results
        ):
            self.storage.put(f"{entry_key}/{fresh_uuid()}", payload)


def make_session(
    scope: TxnScope,
    workflow_uuid: str,
    *,
    cluster: Optional[AftCluster] = None,
    storage: Optional[StorageEngine] = None,
    cowritten_hint: Sequence[str] = (),
    hint: Optional[PlacementHint] = None,
    place_steps: bool = False,
    commit_offload: bool = False,
    fresh: bool = False,
) -> WorkflowSession:
    """``hint`` routes the session's node(s) (``core/routing.py``);
    ``place_steps`` additionally lets STEP scope place every step's
    transaction independently by its declared reads (ignored by the other
    scopes, which are single-node by construction); ``commit_offload``
    routes STEP-scope step commits through the node's storage I/O pipeline
    (WORKFLOW scope always exposes its single commit via ``finish_async``
    — whether it is *used* is the driver's choice); ``fresh`` marks the
    workflow UUID as minted this attempt, skipping §3.3.1 probes."""
    if scope is TxnScope.WORKFLOW:
        if cluster is None:
            raise ValueError("TxnScope.WORKFLOW requires an AftCluster")
        return WorkflowTxnSession(cluster, workflow_uuid, hint=hint,
                                  fresh=fresh)
    if scope is TxnScope.STEP:
        if cluster is None:
            raise ValueError("TxnScope.STEP requires an AftCluster")
        return StepTxnSession(
            cluster, workflow_uuid, hint=hint, place_steps=place_steps,
            commit_offload=commit_offload, fresh=fresh,
        )
    if storage is None:
        raise ValueError("TxnScope.NONE requires a StorageEngine")
    return UnscopedSession(storage, workflow_uuid, cowritten_hint)
