"""Durable cross-workflow chaining: exactly-once triggers through AFT.

AFT (§3.3.1) makes ONE request atomic and idempotent.  Real serverless
applications chain requests: a committed workflow's result should durably
start the next workflow — pipelines, sagas, cron fan-out (Beldi's logged
intent tables, Cloudburst's compositional pipelines; see PAPERS.md).  The
hard part is the *handoff*: a node that commits workflow A and then dies
before enqueueing the trigger for B silently drops the chain, and a node
that enqueues and dies before recording that it did double-fires on retry.

This module gets exactly-once handoff with **no new infrastructure**, by
threading the trigger queue through AFT's own commit protocol:

* **enqueue is the parent's commit** — a :class:`Trigger` edge declared via
  ``WorkflowSpec.trigger(...)`` materializes as an ordinary write to the
  logical key ``q/<queue>/<seq>`` *inside the parent's WORKFLOW-scope
  transaction* (``WorkflowSession.stage_triggers``).  The entry is durable
  iff the parent's effects are: no commit, no trigger; retried commit, same
  deterministic entry (§3.3.1), still one trigger.  STEP-scope parents fall
  back to a standalone deterministic-UUID enqueue transaction (exactly-once
  but not atomic with the DAG — STEP scope never was); the unscoped
  baseline enqueues with a *fresh* suffix per attempt, which is precisely
  the lose/duplicate anomaly ``benchmarks/fig_chain.py`` measures;

* **claim is §3.3.1 UUID reuse** — a :class:`ChainConsumer` claims an entry
  by committing ``q/<queue>/<seq>/claim`` under the deterministic UUID
  ``<seq>.claim`` (``AftNode.claim_queue_entry``: select+insert under the
  per-session lock).  Racing claimants collapse into one idempotent
  transaction; a claimant that dies mid-handoff leaves a claim any consumer
  may take over after ``reclaim_after_s``;

* **drive is idempotent by construction** — the child workflow's UUID *is*
  the entry id, so a replayed trigger (crash between commit and
  enqueue-visible, between claim and child-start, or a pool restart)
  resubmits the same logical workflow: memoized steps replay, the final
  commit recommits, and the child's effects land exactly once.  A consumer
  that finds the child's ``w/<seq>`` finish marker (or committed record)
  skips the drive entirely, honoring the marker's never-re-driven promise;

* **GC rides the ``w/`` marker sweep** — a finished child's marker carries
  its ``{queue, entry}`` provenance, and ``core/gc.py`` reclaims the entry
  + claim versions and their bookkeeping transactions alongside the child's
  memo records, so a long-running chain's queue footprint plateaus.

See ``docs/WORKFLOWS.md`` ("Chaining") for the DSL and the dedup contract.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from ..core import PlacementHint
from ..core.ids import fresh_uuid
from ..core.records import (
    DATA_PREFIX,
    TRIGGER_PREFIX,
    WF_CHAIN_INFIX,
    claim_txn_uuid,
    enqueue_txn_uuid,
    lookup_committed_record,
    trigger_claim_key,
    trigger_entry_id,
    trigger_key,
    workflow_finish_key,
)
from ..obs import trace as obs_trace
from ..obs.registry import Registry
from .spec import WorkflowSpec, WorkflowSpecError


@dataclass(frozen=True)
class Trigger:
    """One ``on_commit`` chaining edge of a :class:`WorkflowSpec`.

    ``workflow`` — the child: a :class:`WorkflowSpec` (its ``name`` is
    recorded; the consumer still resolves it through its registry, because a
    replaying consumer in a fresh process only has the durable name) or a
    bare spec name.  ``args_from`` — a parent step whose *result* becomes
    the child's ``args`` (resolved at commit time); ``args`` is a literal
    fallback.  ``queue`` namespaces independent consumers.  ``name`` is the
    edge label (defaults to the child name) — it keys the deterministic
    entry id, so two edges of one parent must use distinct names.
    """

    workflow: Any  # WorkflowSpec | str
    queue: str = "default"
    args_from: Optional[str] = None
    args: Any = None
    name: Optional[str] = None

    def spec_name(self) -> str:
        return getattr(self.workflow, "name", self.workflow)

    def edge_name(self) -> str:
        return self.name or self.spec_name()

    def resolve_args(self, results: Dict[str, Any]) -> Any:
        if self.args_from is not None:
            return results.get(self.args_from)
        return self.args


def validate_triggers(spec: "WorkflowSpec") -> None:
    """Spec-validation hook: edge names must be unique, slash-free (they
    embed into storage keys), and ``args_from`` must name a real step."""
    seen: Set[str] = set()
    for trigger in spec.on_commit:
        edge = trigger.edge_name()
        if not edge or "/" in edge:
            raise WorkflowSpecError(
                f"trigger edge name {edge!r} must be non-empty and slash-free"
            )
        if WF_CHAIN_INFIX in edge:
            # the entry id is parsed back as <parent>.chain.<edge> (spill
            # fallback, GC): an edge embedding the infix breaks the parse
            raise WorkflowSpecError(
                f"trigger edge name {edge!r} must not contain "
                f"{WF_CHAIN_INFIX!r}"
            )
        if not trigger.queue or "/" in trigger.queue:
            # queue names delimit the q/<queue>/<entry> key layout; a slash
            # would make one queue's entries parse as another's
            raise WorkflowSpecError(
                f"trigger queue {trigger.queue!r} must be non-empty and "
                "slash-free"
            )
        if edge in seen:
            raise WorkflowSpecError(f"duplicate trigger edge {edge!r}")
        seen.add(edge)
        if trigger.args_from is not None and trigger.args_from not in spec.steps:
            raise WorkflowSpecError(
                f"trigger {edge!r} takes args from unknown step "
                f"{trigger.args_from!r}"
            )


# ---------------------------------------------------------------------------
# entry payloads
# ---------------------------------------------------------------------------

def encode_entry(
    parent_uuid: str, trigger: Trigger, results: Dict[str, Any]
) -> bytes:
    entry_id = trigger_entry_id(parent_uuid, trigger.edge_name())
    try:
        return json.dumps(
            {
                "workflow": trigger.spec_name(),
                "queue": trigger.queue,
                "edge": trigger.edge_name(),
                "parent": parent_uuid,
                "child_uuid": entry_id,
                "args": trigger.resolve_args(results),
            },
            separators=(",", ":"),
        ).encode()
    except (TypeError, ValueError) as exc:
        raise TypeError(
            f"trigger args for edge {trigger.edge_name()!r} must be "
            "JSON-serializable to ride the commit record"
        ) from exc


def decode_entry(raw: bytes) -> Dict[str, Any]:
    return json.loads(raw)


def build_entries(
    parent_uuid: str, triggers: Sequence[Trigger], results: Dict[str, Any]
) -> List[Tuple[str, str, bytes]]:
    """Resolve every ``on_commit`` edge at commit time.

    Returns ``(entry_id, entry_logical_key, payload)`` triples — what the
    scope-specific ``stage_triggers`` implementations in ``txn.py`` write.
    """
    out = []
    for trigger in triggers:
        entry_id = trigger_entry_id(parent_uuid, trigger.edge_name())
        out.append(
            (
                entry_id,
                trigger_key(trigger.queue, entry_id),
                encode_entry(parent_uuid, trigger, results),
            )
        )
    return out


def list_queue_entries(storage, queue: str) -> List[str]:
    """Entry ids (logical keys) currently durable in ``q/<queue>/``.

    Versioned storage makes discovery a prefix listing of version bytes:
    an entry exists iff some transaction persisted it, and it stops
    existing when the finished-child sweep deletes its versions.  A
    saturated parent's write buffer may have SPILLED the entry bytes to
    ``<entry>/.spill/<uuid>/<n>`` (§3.3; the commit record's storage-key
    map addresses them) — those count as evidence too, or a spilling
    parent's committed trigger would silently vanish from discovery.
    Uncommitted (orphan) spills are filtered later: ``read_entry`` resolves
    payloads only through committed records, and the claim's Algorithm-1
    read returns nothing for an uncommitted entry.  Claims are skipped.
    """
    prefix = f"{DATA_PREFIX}{TRIGGER_PREFIX}{queue}/"
    seen: Dict[str, None] = {}
    for skey in storage.list_keys(prefix):
        rest = skey[len(prefix):]  # <entry_id>[/claim]/<txnid> | + /.spill/…
        if "/.spill/" in rest:
            logical = rest.split("/.spill/", 1)[0]
        else:
            logical, _, _tid = rest.rpartition("/")
        if not logical or logical.endswith("/claim"):
            continue
        seen.setdefault(logical, None)
    return list(seen)


def read_entry(storage, queue: str, entry_id: str) -> Optional[Dict[str, Any]]:
    """Fetch + decode an entry's payload from durable storage.

    Fast path: any default-keyed version (deterministic enqueue means all
    versions are identical).  Fallback: resolve through the enqueueing
    transaction's commit record — a saturated parent may have spilled the
    entry bytes to a uuid-derived key only the record's storage-key map
    addresses (§3.3)."""
    prefix = f"{DATA_PREFIX}{trigger_key(queue, entry_id)}/"
    for skey in storage.list_keys(prefix):
        rest = skey[len(prefix):]
        if "/" in rest:  # claim/spill versions live deeper
            continue
        raw = storage.get(skey)
        if raw is not None:
            try:
                return decode_entry(raw)
            except (ValueError, UnicodeDecodeError):
                return None
    # spilled (or listing-lagged) entry: go through the committed record
    parent_uuid, sep, _ = entry_id.rpartition(WF_CHAIN_INFIX)
    entry_key = trigger_key(queue, entry_id)
    for uuid in ((parent_uuid,) if sep else ()) + (enqueue_txn_uuid(entry_id),):
        record = lookup_committed_record(storage, uuid)
        if record is None or entry_key not in record.write_set:
            continue
        raw = storage.get(record.storage_key_for(entry_key))
        if raw is not None:
            try:
                return decode_entry(raw)
            except (ValueError, UnicodeDecodeError):
                return None
    return None


# ---------------------------------------------------------------------------
# the consumer loop
# ---------------------------------------------------------------------------

@dataclass
class ChainConsumerConfig:
    queues: Tuple[str, ...] = ("default",)
    poll_interval_s: float = 0.05
    # take over another consumer's unfinished claim after this long — the
    # crash-recovery knob (a dead claimant's children must still run).  The
    # takeover drive is safe at any setting; the wait only limits redundant
    # (idempotent) double-drives while the claimant is merely slow.  The
    # durable claim timestamp is write-once, so the same knob also paces
    # each consumer's REPEAT takeovers of a still-unfinished entry.
    reclaim_after_s: float = 5.0
    consumer_id: str = field(default_factory=fresh_uuid)
    # re-drive children whose previous drive exhausted its attempts (off by
    # default: a deterministically-failing child would hot-loop forever)
    redrive_failed: bool = False


class ChainConsumer:
    """Claims trigger-queue entries and drives their child workflows.

    One consumer serves a :class:`~repro.workflow.pool.WorkflowPool`; the
    ``registry`` maps durable spec names to a :class:`WorkflowSpec` or a
    ``factory(args) -> WorkflowSpec`` (the replay path runs in a process
    that only has the entry's JSON payload, so specs are resolved by name).
    ``step()`` is one deterministic poll pass — tests drive it directly;
    ``start()`` runs it on a daemon thread.

    Exactly-once contract (see module docstring): discovery is at-least-once
    (entries persist until the child's finish marker licenses their GC),
    claims dedup concurrent consumers via §3.3.1 UUID reuse, and drives are
    idempotent because the child UUID is the entry id.
    """

    def __init__(
        self,
        pool,
        registry: Dict[str, Any],
        config: Optional[ChainConsumerConfig] = None,
        *,
        metrics: Optional[Registry] = None,
    ):
        if pool.cluster is None:
            raise ValueError("ChainConsumer requires a cluster-backed pool")
        self.pool = pool
        self.cluster = pool.cluster
        self.platform = pool.platform
        self.registry = dict(registry)
        self.config = config or ChainConsumerConfig()
        # `registry` was taken by the spec-name registry long before the
        # metrics registry existed, hence `metrics`; defaults to sharing the
        # pool's so one snapshot covers scheduler + consumer
        self.metrics = metrics or getattr(pool, "registry", None) or Registry(
            name="chain"
        )
        self.stats: Dict[str, int] = {
            "polls": 0,
            "entries_seen": 0,
            "already_finished_skips": 0,
            "claims_committed": 0,
            "claims_deferred": 0,
            "claims_taken_over": 0,
            "children_started": 0,
            "children_completed": 0,
            "children_failed": 0,
            "handoff_crashes": 0,
            "unknown_workflows": 0,
        }
        self.metrics.attach_counters(self.stats, "chain.")
        self._inflight: Dict[str, Any] = {}   # entry_id → PoolTicket
        self._done: Set[str] = set()
        self._failed: Set[str] = set()
        self._unknown: Set[str] = set()  # unresolvable specs: parked
        # last takeover per entry: the claim's write-once timestamp can
        # never be refreshed (deterministic UUID ⇒ re-commit is a no-op),
        # so each consumer rate-limits its own takeovers instead — without
        # this, every drive longer than reclaim_after_s would be re-driven
        # on every poll pass by every other consumer
        self._takeover_at: Dict[str, float] = {}
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------- one pass
    def step(self) -> int:
        """Poll every queue once; returns the number of children started."""
        from ..faas.platform import FunctionFailure

        self.stats["polls"] += 1
        started = 0
        live: Set[str] = set()
        for queue in self.config.queues:
            for entry_id in list_queue_entries(self.cluster.storage, queue):
                live.add(entry_id)
                try:
                    if self._drive_entry(queue, entry_id):
                        started += 1
                except FunctionFailure:
                    # injected kill-mid-handoff: this pass abandons the
                    # entry; the claim (if committed) plus the entry's
                    # durability guarantee a later pass replays it
                    self.stats["handoff_crashes"] += 1
                except Exception:
                    # a dying node mid-claim etc.; the entry stays durable
                    # and the next pass retries against live nodes
                    self.stats["handoff_crashes"] += 1
        # bookkeeping stays bounded by the LIVE queue: once the GC sweep
        # reclaims a consumed entry it stops being listed, and remembering
        # it further would grow consumer memory forever (the same pruning
        # rule LocalGcAgent applies to its swept-marker set)
        with self._lock:
            self._done &= live
            self._failed &= live
            self._unknown &= live
            for entry_id in list(self._takeover_at):
                if entry_id not in live:
                    del self._takeover_at[entry_id]
        return started

    def _drive_entry(self, queue: str, entry_id: str) -> bool:
        with self._lock:
            if entry_id in self._inflight or entry_id in self._done:
                return False
            if entry_id in self._unknown:
                return False  # parked: registry lacked its spec
            if entry_id in self._failed and not self.config.redrive_failed:
                return False
        self.stats["entries_seen"] += 1
        storage = self.cluster.storage
        # never-re-driven promise: a finished (or durably committed) child
        # must not be resubmitted — its memo records may already be GC'd
        if storage.get(workflow_finish_key(entry_id)) is not None:
            self.stats["already_finished_skips"] += 1
            with self._lock:
                self._done.add(entry_id)
            return False
        payload = read_entry(storage, queue, entry_id)
        if payload is None:
            return False  # discovery raced the finished-child sweep
        # resolve the spec BEFORE claiming: an unresolvable entry must not
        # burn a claim transaction per poll pass forever — park it (a
        # consumer restart, with a presumably fixed registry, retries).  A
        # raising factory is just as unresolvable as a missing name.
        try:
            spec = self._resolve_spec(payload)
        except Exception:
            spec = None
        if spec is None:
            self.stats["unknown_workflows"] += 1
            with self._lock:
                self._unknown.add(entry_id)
            return False
        if not self._claim(queue, entry_id, payload):
            return False
        # the kill-mid-handoff window: claimed, child not yet submitted.
        # Like the invoke:* sites, consumer-loop sites are opt-in by name:
        # an anonymous failure_rate targets function bodies, and letting it
        # also crash the client-side poll loop would change historical
        # semantics (and stall chains at rate 1.0).
        if self.platform.config.failure_sites is not None:
            self.platform.maybe_fail(site=f"chain:handoff:{queue}")
        # re-check the finish marker right before submitting: a rival drive
        # may have finished the child while we were claiming (the pool
        # repeats this check at every attempt start, closing the remaining
        # check-then-act window against the GC sweep)
        if storage.get(workflow_finish_key(entry_id)) is not None:
            self.stats["already_finished_skips"] += 1
            with self._lock:
                self._done.add(entry_id)
            return False
        ticket = self.pool.submit(
            spec,
            uuid=entry_id,
            args=payload.get("args"),
            chain_entry={"queue": queue, "entry": entry_id},
        )
        with self._lock:
            self._inflight[entry_id] = ticket
            self._failed.discard(entry_id)
        self.stats["children_started"] += 1
        tracer = obs_trace.get_tracer()
        if tracer.enabled:
            tracer.emit(
                "chain_child",
                queue=queue,
                entry=entry_id,
                parent=payload.get("parent"),
                parent_trace=obs_trace.txn_trace_id(payload["parent"])
                if payload.get("parent") else None,
                trace=obs_trace.trace_id(entry_id),
            )
        ticket.add_done_callback(
            lambda fut, eid=entry_id: self._on_child_done(eid, fut)
        )
        return True

    def _emit_claim(self, queue: str, entry_id: str, outcome: str) -> None:
        tracer = obs_trace.get_tracer()
        if tracer.enabled:
            # the claim transaction's UUID is <entry>.claim, so
            # txn_trace_id(claim uuid) == trace_id(entry) — the claim lands
            # in the CHILD workflow's trace with zero plumbing
            tracer.emit(
                "claim",
                queue=queue,
                entry=entry_id,
                consumer=self.config.consumer_id,
                outcome=outcome,
                trace=obs_trace.trace_id(entry_id),
                txn=claim_txn_uuid(entry_id),
            )

    def _claim(self, queue: str, entry_id: str, payload: Dict[str, Any]) -> bool:
        """Commit (or adopt) the entry's claim; False defers to its owner."""
        # the injected claim-crash fires BEFORE the transaction opens: a
        # consumer killed here has touched nothing, so the failure path
        # below never has to abort a context a co-located rival might be
        # sharing (the deterministic claim UUID makes contexts shared).
        # Opt-in by site name, like every consumer-loop/invoke-level site.
        if self.platform.config.failure_sites is not None:
            self.platform.maybe_fail(site=f"chain:claim:{queue}")
        client = self.cluster.client()
        txid = client.start_transaction(
            claim_txn_uuid(entry_id), hint=PlacementHint(uuid=entry_id)
        )
        node = client.node_of(txid)
        # close the multicast window for the enqueueing commit: the claim's
        # node may not have heard it yet (the §4.2 propagation done eagerly,
        # same as MemoStore.load_all's recover step)
        for enq_uuid in (payload.get("parent"), enqueue_txn_uuid(entry_id)):
            if not enq_uuid:
                continue
            record = lookup_committed_record(self.cluster.storage, enq_uuid)
            if record is not None and any(
                k.startswith(TRIGGER_PREFIX) for k in record.write_set
            ):
                node.merge_remote_commits([record])
        try:
            entry, prior, prior_buffered = node.claim_queue_entry(
                txid,
                trigger_key(queue, entry_id),
                trigger_claim_key(queue, entry_id),
                json.dumps(
                    {"consumer": self.config.consumer_id, "ts": time.time()}
                ).encode(),
            )
            if entry is None:
                client.abort_transaction(txid)
                self._emit_claim(queue, entry_id, "swept")
                return False  # swept (or not yet visible) — nothing to drive
            if prior is not None:
                if prior_buffered:
                    # a co-located sharer of this very transaction context
                    # buffered the claim between our reads: the context is
                    # THEIRS to commit — touching it (abort) would kill
                    # their in-flight claim.  Defer; their drive covers it.
                    self.stats["claims_deferred"] += 1
                    self._emit_claim(queue, entry_id, "deferred")
                    return False
                try:
                    claim = json.loads(prior)
                except ValueError:
                    claim = {}
                mine = claim.get("consumer") == self.config.consumer_id
                stale = (
                    time.time() - float(claim.get("ts", 0.0))
                    >= self.config.reclaim_after_s
                )
                # the prior claim is durably committed, so aborting this
                # context is safe even against a racing sharer: their
                # commit resolves through the §3.3.1 already-committed probe
                client.abort_transaction(txid)
                if mine:
                    self._emit_claim(queue, entry_id, "adopted")
                    return True
                if stale:
                    now = time.time()
                    with self._lock:
                        recently = (
                            now - self._takeover_at.get(entry_id, -1e18)
                            < self.config.reclaim_after_s
                        )
                        if not recently:
                            self._takeover_at[entry_id] = now
                    if recently:
                        self.stats["claims_deferred"] += 1
                        self._emit_claim(queue, entry_id, "deferred")
                        return False
                    self.stats["claims_taken_over"] += 1
                    self._emit_claim(queue, entry_id, "taken_over")
                    return True
                self.stats["claims_deferred"] += 1
                self._emit_claim(queue, entry_id, "deferred")
                return False
            client.commit_transaction(txid)
            self.stats["claims_committed"] += 1
            self._emit_claim(queue, entry_id, "committed")
            return True
        except BaseException:
            try:
                client.abort_transaction(txid)
            except Exception:
                pass
            raise

    def _resolve_spec(self, payload: Dict[str, Any]) -> Optional[WorkflowSpec]:
        entry = self.registry.get(payload.get("workflow"))
        if entry is None:
            return None
        if isinstance(entry, WorkflowSpec):
            return entry
        return entry(payload.get("args"))  # factory(args) → spec

    def _on_child_done(self, entry_id: str, fut) -> None:
        with self._lock:
            self._inflight.pop(entry_id, None)
            if fut.exception() is None:
                self._done.add(entry_id)
            else:
                self._failed.add(entry_id)
        if fut.exception() is None:
            self.stats["children_completed"] += 1
        else:
            self.stats["children_failed"] += 1

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ChainConsumer":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                try:
                    self.step()
                except Exception:
                    pass  # next poll rebuilds everything it needs
                self._stop.wait(self.config.poll_interval_s)

        self._thread = threading.Thread(
            target=loop, name="chain-consumer", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            if self._thread.is_alive():
                return  # keep the handle: start() must not double-spawn
            self._thread = None

    def pending(self) -> int:
        with self._lock:
            return len(self._inflight)

    def drain(self, timeout_s: float = 30.0, poll_s: float = 0.005) -> bool:
        """Step until the queue is quiescent: nothing new to drive and no
        children in flight.  Deterministic alternative to ``start()`` for
        tests and benchmarks; returns False on timeout."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            started = self.step()
            if started == 0 and self.pending() == 0 and self.step() == 0:
                return True
            time.sleep(poll_s)
        return False
