"""Transactional workflow orchestration over AFT.

DAG-composed FaaS requests with exactly-once semantics: declarative specs
(``spec.py``), a parallel scheduler/executor on ``LambdaPlatform``
(``executor.py``), and transaction scoping + memoized idempotent resume
through AFT itself (``txn.py``).
"""

from .executor import (
    StepContext,
    StepFailure,
    WorkflowConfig,
    WorkflowError,
    WorkflowExecutor,
    WorkflowResult,
)
from .spec import Step, WorkflowSpec, WorkflowSpecError
from .txn import (
    MEMO_PREFIX,
    MemoStore,
    TxnScope,
    WorkflowSession,
    memo_key,
    memo_txn_uuid,
    step_txn_uuid,
)

__all__ = [
    "Step",
    "WorkflowSpec",
    "WorkflowSpecError",
    "WorkflowExecutor",
    "WorkflowConfig",
    "WorkflowResult",
    "WorkflowError",
    "StepContext",
    "StepFailure",
    "TxnScope",
    "WorkflowSession",
    "MemoStore",
    "MEMO_PREFIX",
    "memo_key",
    "memo_txn_uuid",
    "step_txn_uuid",
]
