"""Transactional workflow orchestration over AFT.

DAG-composed FaaS requests with exactly-once semantics, at two scales:

* ``WorkflowExecutor`` (``executor.py``) — drive ONE workflow to completion:
  walks a declarative :class:`WorkflowSpec` (``spec.py``), fans ready steps
  out on :class:`LambdaPlatform`, and retries the whole DAG under the same
  UUID with per-step memoized resume;
* ``WorkflowPool`` (``pool.py``) — drive THOUSANDS of concurrent workflows:
  ``submit()`` returns a ticket immediately, ready steps from different
  workflows are batched into shared platform invocations (amortizing the
  per-invoke overhead), with round-robin fairness, bounded in-flight
  windows, and backpressure.  Completed workflows are declared *finished*,
  which lets the §5 GC (``repro/core/gc.py``) reclaim their ``.wf/`` memo
  records so a long-running pool's storage footprint stays bounded.

Transaction scoping + the memo store both live in ``txn.py``: a DAG runs as
one AFT transaction (``TxnScope.WORKFLOW``), one per step (``TxnScope.STEP``),
or unshimmed (``TxnScope.NONE``, the anomaly baseline).

Workflows chain: ``chain.py`` adds ``on_commit`` :class:`Trigger` edges — a
committed workflow durably enqueues its successor through the AFT-backed
``q/`` trigger queue (the entry rides the parent's commit record), and a
:class:`ChainConsumer` claims entries with §3.3.1 UUID-reuse dedup so a
crashed handoff replays without dropping or double-firing the child.

Docs: ``docs/WORKFLOWS.md`` (DSL, scopes, exactly-once resume, pool tuning)
and ``docs/ARCHITECTURE.md`` (how this layer maps onto the paper).
"""

from .chain import (
    ChainConsumer,
    ChainConsumerConfig,
    Trigger,
    build_entries,
    list_queue_entries,
)
from .executor import (
    StepContext,
    StepFailure,
    WorkflowConfig,
    WorkflowError,
    WorkflowExecutor,
    WorkflowResult,
    execute_step,
)
from .pool import AdaptiveBatcher, PoolClosed, PoolConfig, PoolTicket, WorkflowPool
from .spec import Step, WorkflowSpec, WorkflowSpecError
from .txn import (
    MEMO_PREFIX,
    MemoStore,
    TxnScope,
    WorkflowSession,
    memo_key,
    memo_txn_uuid,
    step_txn_uuid,
)

__all__ = [
    "ChainConsumer",
    "ChainConsumerConfig",
    "Trigger",
    "build_entries",
    "list_queue_entries",
    "Step",
    "WorkflowSpec",
    "WorkflowSpecError",
    "WorkflowExecutor",
    "WorkflowConfig",
    "WorkflowResult",
    "WorkflowError",
    "WorkflowPool",
    "PoolConfig",
    "PoolTicket",
    "PoolClosed",
    "AdaptiveBatcher",
    "StepContext",
    "StepFailure",
    "TxnScope",
    "WorkflowSession",
    "MemoStore",
    "MEMO_PREFIX",
    "memo_key",
    "memo_txn_uuid",
    "step_txn_uuid",
    "execute_step",
]
