"""FaaS platform emulation (AWS-Lambda-shaped).

Models the properties of commodity FaaS that AFT's design responds to:

* a logical request is a *linear composition* of functions (§2.2), each
  potentially on a different machine, all funneling their state operations to
  one AFT node through the request's transaction session;
* functions are retried on failure (at-least-once); a retry may re-run with
  the same transaction UUID to continue/recommit idempotently (§3.3.1), which
  with AFT's atomicity yields exactly-once effects;
* per-invocation overhead (warm-start latency) is simulated so end-to-end
  numbers are Lambda-shaped (§6.1.2).

Failure injection kills a function at a configurable point mid-body, which is
how tests/benchmarks produce the fractional-execution hazards of §1.
"""

from __future__ import annotations

import random
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence, Tuple

from ..obs.registry import Registry


class FunctionFailure(Exception):
    """A function instance died mid-execution (injected)."""


@dataclass
class FaasConfig:
    warm_latency_ms: float = 4.0      # per-invocation overhead (warm start)
    latency_sigma: float = 0.3
    time_scale: float = 1.0
    failure_rate: float = 0.0         # probability a function dies mid-body
    # restrict injection to named sites (prefix match, e.g. "step:shard");
    # None ⇒ every maybe_fail() call is a candidate
    failure_sites: Optional[Tuple[str, ...]] = None
    max_retries: int = 5
    retry_backoff_ms: float = 5.0
    reuse_uuid_on_retry: bool = True  # §3.3.1 continue-the-transaction
    max_workers: int = 64
    seed: int = 0


class LambdaPlatform:
    def __init__(self, config: Optional[FaasConfig] = None, *,
                 registry: Optional[Registry] = None):
        self.config = config or FaasConfig()
        self._rng = random.Random(self.config.seed)
        self._rng_lock = threading.Lock()
        self._pool = ThreadPoolExecutor(max_workers=self.config.max_workers)
        self.invocations = 0
        self.batched_invocations = 0
        self.batched_steps = 0
        self.failures_injected = 0
        self.retries = 0
        self.on_failure_errors = 0
        self.last_on_failure_error: Optional[BaseException] = None
        # counters are bumped from many pool threads at once (submit/map);
        # bare += would drop updates
        self._stats_lock = threading.Lock()
        self.registry = registry or Registry(
            name="faas", time_scale=self.config.time_scale)
        self.registry.attach_provider(self._counters)
        self._h_invoke = self.registry.histogram("site:invoke:single")
        self._h_invoke_batch = self.registry.histogram("site:invoke:batch")

    def _counters(self) -> dict:
        with self._stats_lock:
            return {
                "invocations": self.invocations,
                "batched_invocations": self.batched_invocations,
                "batched_steps": self.batched_steps,
                "failures_injected": self.failures_injected,
                "retries": self.retries,
                "on_failure_errors": self.on_failure_errors,
            }

    # -- simulation hooks ------------------------------------------------
    def _sleep_ms(self, ms: float) -> None:
        scaled = ms * self.config.time_scale / 1e3
        if scaled > 0:
            time.sleep(scaled)

    def _sample_overhead(self) -> float:
        with self._rng_lock:
            return self.config.warm_latency_ms * self._rng.lognormvariate(
                0.0, self.config.latency_sigma
            )

    def maybe_fail(self, site: Optional[str] = None) -> None:
        """Called by instrumented functions at their failure points.  When
        ``failure_sites`` is configured, only calls whose ``site`` matches one
        of the configured prefixes are candidates — this is how tests and
        benchmarks target a crash at a specific step of a workflow DAG."""
        if self.config.failure_rate <= 0:
            return
        sites = self.config.failure_sites
        if sites is not None:
            if site is None or not any(site.startswith(p) for p in sites):
                return
        with self._rng_lock:
            die = self._rng.random() < self.config.failure_rate
        if die:
            with self._stats_lock:
                self.failures_injected += 1
            raise FunctionFailure(
                f"injected mid-function crash at {site or 'anonymous site'}"
            )

    # -- execution ---------------------------------------------------------
    def invoke(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Any:
        """Invoke one function with warm-start overhead (no retry).

        When ``failure_sites`` is configured, the invocation itself is a
        failure point (site ``invoke:single``): the Lambda instance can die
        before the body runs.  Only evaluated under site-scoped injection so
        historical anonymous-rate configs keep their exact semantics."""
        with self._stats_lock:
            self.invocations += 1
        if self.config.failure_sites is not None:
            self.maybe_fail(site="invoke:single")
        t0 = time.perf_counter()
        try:
            self._sleep_ms(self._sample_overhead())
            return fn(*args, **kwargs)
        finally:
            self._h_invoke.observe_s(time.perf_counter() - t0)

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Future:
        """Schedule one function invocation on the platform pool — the
        parallel-branch primitive workflow executors fan out with.  The
        invocation pays the same warm-start overhead as ``invoke``."""
        return self._pool.submit(self.invoke, fn, *args, **kwargs)

    def invoke_batch(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        """Run several pre-bound function bodies inside ONE invocation.

        This is the scheduler-level batching primitive (`WorkflowPool`): many
        compatible small steps — typically from *different* workflows — share
        a single warm start, so the per-invocation overhead sampled above is
        paid once for the whole batch instead of once per step.  Bodies run
        sequentially, exactly as if a driver function called them in order;
        exception isolation is the caller's job (pool thunks never raise —
        they capture their own outcome and report it to the scheduler).

        Site-scoped fault injection is evaluated **per thunk** (site
        ``invoke:batch``), mirroring ``invoke``'s ``invoke:single``: without
        this, batched execution would silently dodge invocation-level kills
        and benchmarks would overstate batched-mode robustness.  An injected
        kill takes out exactly the thunk it landed on — delivered through
        the thunk's ``report_failure`` hook when it has one (the pool's
        thunks do, keeping retry/error accounting exact) — and the rest of
        the batch still runs, like a per-slot crash in a shared container."""
        if not thunks:
            return []
        with self._stats_lock:
            self.invocations += 1
            self.batched_invocations += 1
            self.batched_steps += len(thunks)
        t0 = time.perf_counter()
        self._sleep_ms(self._sample_overhead())
        out: List[Any] = []
        for thunk in thunks:
            if self.config.failure_sites is not None:
                try:
                    self.maybe_fail(site="invoke:batch")
                except FunctionFailure as exc:
                    reporter = getattr(thunk, "report_failure", None)
                    if reporter is not None:
                        reporter(exc)
                    out.append(exc)
                    continue
            out.append(thunk())
        self._h_invoke_batch.observe_s(time.perf_counter() - t0)
        return out

    def submit_batch(self, thunks: Sequence[Callable[[], Any]]) -> Future:
        """Schedule one *batched* invocation on the platform pool."""
        return self._pool.submit(self.invoke_batch, thunks)

    def run_request(
        self,
        functions: Sequence[Callable[..., Any]],
        *,
        begin: Callable[[Optional[str]], Any],
        finish: Callable[[Any], Any],
        on_failure: Callable[[Any], None],
    ) -> Any:
        """Run a logical request: ``begin`` opens the session (optionally
        with a prior UUID on retry), each function runs in order receiving
        the session, ``finish`` commits.  On any failure the whole request
        retries from scratch (the platform's retry-based model, §7)."""
        uuid: Optional[str] = None
        last_exc: Optional[BaseException] = None
        attempts = self.config.max_retries + 1
        for attempt in range(attempts):
            if attempt:
                with self._stats_lock:
                    self.retries += 1
                self._sleep_ms(self.config.retry_backoff_ms * attempt)
            session = begin(uuid if self.config.reuse_uuid_on_retry else None)
            if self.config.reuse_uuid_on_retry and uuid is None:
                uuid = getattr(session, "uuid", None)
            try:
                for fn in functions:
                    self.invoke(fn, session)
                return finish(session)
            except BaseException as exc:  # noqa: BLE001 - retry everything
                last_exc = exc
                try:
                    on_failure(session)
                except Exception as cleanup_exc:
                    # cleanup is best-effort, but never silent: the node's
                    # timeout sweep is the functional backstop
                    with self._stats_lock:
                        self.on_failure_errors += 1
                        self.last_on_failure_error = cleanup_exc
        raise RuntimeError(
            f"request failed after {attempts} attempts "
            f"({self.config.max_retries} retries)"
        ) from last_exc

    def map(self, fn: Callable[[int], Any], n: int) -> List[Any]:
        """Run ``fn(0..n-1)`` on the platform pool (parallel clients)."""
        return list(self._pool.map(fn, range(n)))

    def shutdown(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)
