from .platform import FaasConfig, FunctionFailure, LambdaPlatform
from .workload import (
    WorkloadConfig,
    WorkloadResult,
    ZipfSampler,
    run_workload,
)

__all__ = [
    "LambdaPlatform",
    "FaasConfig",
    "FunctionFailure",
    "WorkloadConfig",
    "WorkloadResult",
    "ZipfSampler",
    "run_workload",
]
