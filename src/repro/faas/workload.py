"""The paper's evaluation workload (§6).

Transactions are linear compositions of ``F`` functions, each performing
``R`` reads and ``W`` writes of ~4KB objects over a Zipf-distributed key
space.  The same specs drive three execution modes:

* ``aft``      — through the AFT shim (cluster client): buffered writes,
                 Algorithm-1 reads, atomic commit.
* ``plain``    — direct to storage, overwriting keys in place, with AFT's
                 metadata (~70 B: timestamp, UUID, cowritten set) embedded in
                 each value so anomalies are observable (§6.1.2).
* ``dynamo_txn`` — DynamoDB transaction-mode shape (§6.1.2): per-function
                 read-only batches + one write-only atomic batch at the end,
                 with conflict-abort + retry behavior; atomic per API call
                 but *not* across functions, so fractured reads remain.

Every transaction is scored by the Table-2 anomaly detectors; latency,
throughput, abort and retry counts come back in a ``WorkloadResult``.
"""

from __future__ import annotations

import random
import threading
import time
from bisect import bisect_left
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import (
    AftCluster,
    AnomalyAggregator,
    ReadAbortError,
    TransactionObserver,
    TxnId,
    embed_metadata,
    extract_metadata,
)
from ..core.ids import Clock, fresh_uuid
from ..storage.base import StorageEngine
from .platform import FaasConfig, LambdaPlatform


# ---------------------------------------------------------------------------
# key-space sampling
# ---------------------------------------------------------------------------

class ZipfSampler:
    """Bounded Zipf over ``num_keys`` keys with coefficient ``theta``."""

    def __init__(self, num_keys: int, theta: float, seed: int = 0):
        self.num_keys = num_keys
        ranks = np.arange(1, num_keys + 1, dtype=np.float64)
        weights = ranks ** (-theta) if theta > 0 else np.ones_like(ranks)
        self._cdf = np.cumsum(weights / weights.sum())
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def sample(self) -> int:
        with self._lock:
            u = self._rng.random()
        return int(bisect_left(self._cdf, u))

    def key(self) -> str:
        return f"key{self.sample():06d}"


# ---------------------------------------------------------------------------
# workload spec
# ---------------------------------------------------------------------------

@dataclass
class WorkloadConfig:
    num_keys: int = 1_000
    zipf: float = 1.0
    functions_per_txn: int = 2
    reads_per_function: int = 2
    writes_per_function: int = 1
    value_bytes: int = 4_096
    faas: FaasConfig = field(default_factory=FaasConfig)
    seed: int = 0


@dataclass
class WorkloadResult:
    mode: str
    latencies_ms: List[float]
    anomalies: Dict[str, int]
    wall_s: float
    committed: int
    client_count: int
    retries: int = 0
    conflict_aborts: int = 0
    staleness_aborts: int = 0

    @property
    def throughput_tps(self) -> float:
        return self.committed / self.wall_s if self.wall_s > 0 else 0.0

    def percentile(self, p: float) -> float:
        if not self.latencies_ms:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_ms), p))

    def summary(self) -> Dict[str, float]:
        return {
            "mode": self.mode,
            "txns": self.committed,
            "median_ms": round(self.percentile(50), 3),
            "p99_ms": round(self.percentile(99), 3),
            "tps": round(self.throughput_tps, 1),
            "ryw_anomalies": self.anomalies.get("ryw_anomalies", 0),
            "fr_anomalies": self.anomalies.get("fr_anomalies", 0),
            "retries": self.retries,
            "conflict_aborts": self.conflict_aborts,
            "staleness_aborts": self.staleness_aborts,
        }


@dataclass
class TxnSpec:
    """Pre-sampled IO sequence: per-function list of ('r'|'w', key)."""

    functions: List[List[Tuple[str, str]]]
    write_set: Tuple[str, ...]


def build_txn_spec(cfg: WorkloadConfig, sampler: ZipfSampler) -> TxnSpec:
    functions: List[List[Tuple[str, str]]] = []
    writes: List[str] = []
    for _ in range(cfg.functions_per_txn):
        ops: List[Tuple[str, str]] = []
        for _ in range(cfg.reads_per_function):
            ops.append(("r", sampler.key()))
        for _ in range(cfg.writes_per_function):
            key = sampler.key()
            ops.append(("w", key))
            writes.append(key)
        functions.append(ops)
    return TxnSpec(functions=functions, write_set=tuple(sorted(set(writes))))


def _payload(uuid: str, counter: int, size: int) -> bytes:
    head = f"{uuid}:{counter}|".encode()
    return head + b"x" * max(0, size - len(head))


# ---------------------------------------------------------------------------
# AFT-mode execution
# ---------------------------------------------------------------------------

class _AftSession:
    def __init__(self, cluster: AftCluster, uuid: Optional[str]):
        self.client = cluster.client()
        self.txid = self.client.start_transaction(uuid)
        self.uuid = self.txid
        self.node = self.client.node_of(self.txid)
        self.observer = TransactionObserver()
        self.counter = 0


def run_aft_transaction(
    cluster: AftCluster,
    platform: LambdaPlatform,
    spec: TxnSpec,
    cfg: WorkloadConfig,
    agg: AnomalyAggregator,
) -> float:
    def make_function(ops: Sequence[Tuple[str, str]]):
        def body(session: _AftSession) -> None:
            for op, key in ops:
                platform.maybe_fail()  # fractional-execution hazard point
                if op == "r":
                    value, tid = session.node.get_versioned(session.txid, key)
                    cowritten: Tuple[str, ...] = ()
                    if tid is not None:
                        record = session.node.cache.get(tid)
                        if record is not None:
                            cowritten = record.write_set
                    session.observer.observe_read(key, value, tid, cowritten)
                else:
                    session.counter += 1
                    value = _payload(session.uuid, session.counter, cfg.value_bytes)
                    session.node.put(session.txid, key, value)
                    session.observer.observe_write(key, value)
        return body

    t0 = time.perf_counter()

    def begin(uuid: Optional[str]) -> _AftSession:
        return _AftSession(cluster, uuid)

    def finish(session: _AftSession):
        session.client.commit_transaction(session.txid)
        agg.record(session.observer)
        return None

    def on_failure(session: _AftSession) -> None:
        try:
            session.client.abort_transaction(session.txid)
        except Exception:
            pass

    platform.run_request(
        [make_function(ops) for ops in spec.functions],
        begin=begin,
        finish=finish,
        on_failure=on_failure,
    )
    return (time.perf_counter() - t0) * 1e3


# ---------------------------------------------------------------------------
# plain-storage execution (§6.1.2 baselines)
# ---------------------------------------------------------------------------

class _PlainSession:
    def __init__(self, storage: StorageEngine, spec: TxnSpec, clock: Clock):
        self.storage = storage
        self.uuid = fresh_uuid()
        self.tid = TxnId(clock.now_ns(), self.uuid)
        self.spec = spec
        self.observer = TransactionObserver()
        self.counter = 0


_plain_clock = Clock()


def run_plain_transaction(
    storage: StorageEngine,
    platform: LambdaPlatform,
    spec: TxnSpec,
    cfg: WorkloadConfig,
    agg: AnomalyAggregator,
) -> float:
    """No shim: every write lands immediately, in place; reads see whatever
    the engine returns.  Metadata embedded per §6.1.2."""

    def make_function(ops: Sequence[Tuple[str, str]]):
        def body(session: _PlainSession) -> None:
            for op, key in ops:
                platform.maybe_fail()
                if op == "r":
                    raw = session.storage.get(key)
                    if raw is None:
                        session.observer.observe_read(key, None, None)
                        continue
                    value, tid, cowritten = extract_metadata(raw)
                    session.observer.observe_read(key, value, tid, cowritten)
                else:
                    session.counter += 1
                    value = _payload(session.uuid, session.counter, cfg.value_bytes)
                    session.storage.put(
                        key,
                        embed_metadata(value, session.tid, spec.write_set),
                    )
                    session.observer.observe_write(key, value)
        return body

    t0 = time.perf_counter()
    platform.run_request(
        [make_function(ops) for ops in spec.functions],
        begin=lambda uuid: _PlainSession(storage, spec, _plain_clock),
        finish=lambda s: agg.record(s.observer),
        on_failure=lambda s: None,
    )
    return (time.perf_counter() - t0) * 1e3


# ---------------------------------------------------------------------------
# DynamoDB-transaction-mode execution (§6.1.2)
# ---------------------------------------------------------------------------

class _ConflictTable:
    """Write-key reservations: DynamoDB's transaction mode proactively aborts
    conflicting transactions; clients retry."""

    def __init__(self) -> None:
        self._held: Dict[str, str] = {}
        self._lock = threading.Lock()

    def try_acquire(self, keys: Sequence[str], owner: str) -> bool:
        with self._lock:
            if any(k in self._held for k in keys):
                return False
            for k in keys:
                self._held[k] = owner
            return True

    def release(self, keys: Sequence[str], owner: str) -> None:
        with self._lock:
            for k in keys:
                if self._held.get(k) == owner:
                    del self._held[k]


def run_dynamo_txn_transaction(
    storage: StorageEngine,
    platform: LambdaPlatform,
    spec: TxnSpec,
    cfg: WorkloadConfig,
    agg: AnomalyAggregator,
    conflicts: _ConflictTable,
    stats: Dict[str, int],
) -> float:
    """§6.1.2's adapted workload: function i does a read-only transaction
    (one atomic batch); the last function additionally issues one write-only
    transaction containing *all* the request's writes."""
    t0 = time.perf_counter()
    session = _PlainSession(storage, spec, _plain_clock)

    def read_batch(keys: Sequence[str]) -> None:
        raws = storage.get_batch(list(keys))
        for key in keys:
            raw = raws.get(key)
            if raw is None:
                session.observer.observe_read(key, None, None)
                continue
            value, tid, cowritten = extract_metadata(raw)
            session.observer.observe_read(key, value, tid, cowritten)

    for i, ops in enumerate(spec.functions):
        platform.invoke(lambda _=None: None)  # per-function overhead
        read_batch([k for op, k in ops if op == "r"])
    # single write-only transaction with conflict-abort/retry semantics
    write_keys = list(spec.write_set)
    if write_keys:
        backoff = 2.0
        while not conflicts.try_acquire(write_keys, session.uuid):
            stats["conflict_aborts"] = stats.get("conflict_aborts", 0) + 1
            time.sleep(backoff * cfg.faas.time_scale / 1e3)
            backoff = min(backoff * 2, 64.0)
        try:
            batch = {}
            counter = 0
            for key in write_keys:
                counter += 1
                value = _payload(session.uuid, counter, cfg.value_bytes)
                batch[key] = embed_metadata(value, session.tid, spec.write_set)
                session.observer.observe_write(key, value)
            storage.put_batch(batch)
        finally:
            conflicts.release(write_keys, session.uuid)
    agg.record(session.observer)
    return (time.perf_counter() - t0) * 1e3


# ---------------------------------------------------------------------------
# workload driver
# ---------------------------------------------------------------------------

def run_workload(
    mode: str,
    *,
    cfg: WorkloadConfig,
    clients: int,
    txns_per_client: int,
    cluster: Optional[AftCluster] = None,
    storage: Optional[StorageEngine] = None,
) -> WorkloadResult:
    """Run ``clients`` synchronous closed-loop clients (§6.5: each client
    invokes a transaction, waits, repeats) and tally latency + anomalies."""
    sampler = ZipfSampler(cfg.num_keys, cfg.zipf, seed=cfg.seed)
    platform = LambdaPlatform(cfg.faas)
    agg = AnomalyAggregator(mode)
    latencies: List[List[float]] = [[] for _ in range(clients)]
    stats: Dict[str, int] = {}
    spec_rng = random.Random(cfg.seed + 1)
    conflicts = _ConflictTable()

    if mode == "aft" and cluster is None:
        raise ValueError("aft mode requires a cluster")
    if mode in ("plain", "dynamo_txn") and storage is None:
        raise ValueError(f"{mode} mode requires a storage engine")

    def client_loop(ci: int) -> None:
        local_sampler = ZipfSampler(cfg.num_keys, cfg.zipf, seed=cfg.seed + 97 * ci)
        for _ in range(txns_per_client):
            spec = build_txn_spec(cfg, local_sampler)
            try:
                if mode == "aft":
                    ms = run_aft_transaction(cluster, platform, spec, cfg, agg)
                elif mode == "plain":
                    ms = run_plain_transaction(storage, platform, spec, cfg, agg)
                elif mode == "dynamo_txn":
                    ms = run_dynamo_txn_transaction(
                        storage, platform, spec, cfg, agg, conflicts, stats
                    )
                else:
                    raise ValueError(f"unknown mode {mode!r}")
            except RuntimeError:
                continue  # request exhausted its retries
            latencies[ci].append(ms)

    t0 = time.perf_counter()
    platform.map(client_loop, clients)
    wall = time.perf_counter() - t0
    platform.shutdown()

    flat = [ms for per_client in latencies for ms in per_client]
    staleness = 0
    if cluster is not None:
        staleness = sum(n.stats["staleness_aborts"] for n in cluster.all_nodes())
    return WorkloadResult(
        mode=mode,
        latencies_ms=flat,
        anomalies=agg.summary(),
        wall_s=wall,
        committed=len(flat),
        client_count=clients,
        retries=platform.retries,
        conflict_aborts=stats.get("conflict_aborts", 0),
        staleness_aborts=staleness,
    )
