"""AFT-backed atomic checkpointing of sharded pytrees."""

from .serializer import leaf_from_bytes, leaf_to_bytes, tree_paths
from .checkpointer import AftCheckpointer, CheckpointNotFound

__all__ = ["AftCheckpointer", "CheckpointNotFound", "leaf_to_bytes",
           "leaf_from_bytes", "tree_paths"]
