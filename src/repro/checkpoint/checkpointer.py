"""Atomic checkpointing through the AFT shim.

A checkpoint is **one AFT transaction**: every pytree leaf (optionally split
into fixed-size chunks — one storage key per chunk, matching AFT's
unique-key-per-version layout) plus a manifest key, committed atomically.
This is exactly the paper's "logical request spanning multiple functions":
in a real deployment each host writes its leaf partition through the same
transaction ID, and the write-ordering protocol (§3.3) guarantees a reader
can never observe a *torn* checkpoint — either the whole step is visible or
none of it.

Restores run inside one read transaction, so read-atomic isolation (§3.4)
guarantees the manifest and every leaf come from the same committed save
even while a newer save is concurrently committing — the property
hand-rolled ``commit_success`` markers in production checkpointing libraries
approximate, generalized to concurrent writers and multi-version reads.

Idempotence: the save transaction's UUID is derived from (run_id, step), so
a retried save after a crash commits exactly once (§3.1).
"""

from __future__ import annotations

import json
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.errors import ReadAbortError

from .serializer import leaf_from_bytes, leaf_to_bytes, tree_paths

PyTree = Any


class CheckpointNotFound(Exception):
    pass


@dataclass
class SaveResult:
    step: int
    txid: str
    num_keys: int
    bytes_written: int
    deduped: bool = False          # retry found a prior commit


class AftCheckpointer:
    """Checkpoint pytrees through an AFT client/node (Table-1 API object)."""

    def __init__(self, client: Any, *, prefix: str = "ckpt",
                 run_id: str = "run0", chunk_bytes: int = 4 << 20,
                 writers: int = 8):
        self.client = client
        self.prefix = prefix
        self.run_id = run_id
        self.chunk_bytes = max(1, chunk_bytes)
        self.writers = writers

    # -------------------------------------------------------------- helpers
    def _manifest_key(self) -> str:
        return f"{self.prefix}/{self.run_id}/MANIFEST"

    def _leaf_key(self, path: str, chunk: int) -> str:
        return f"{self.prefix}/{self.run_id}/leaf/{path}/{chunk}"

    def _save_uuid(self, step: int, attempt_salt: str = "") -> str:
        return f"ckpt-{self.run_id}-step{step}{attempt_salt}"

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None,
             failpoint: Optional[Any] = None) -> SaveResult:
        """Atomically persist ``tree`` as the checkpoint for ``step``.

        ``failpoint`` (tests): callable invoked after each leaf put; raising
        simulates a mid-save crash — the transaction is aborted and nothing
        becomes visible.
        """
        uuid = self._save_uuid(step)
        prior = getattr(self.client, "committed_tid_for_uuid", None)
        if prior is not None:
            tid = prior(uuid)
            if tid is not None:
                return SaveResult(step, uuid, 0, 0, deduped=True)

        txid = self.client.start_transaction(uuid=uuid)
        manifest: Dict[str, Any] = {"step": step, "leaves": {},
                                    "extra": extra or {}}
        total = 0
        nkeys = 0
        try:
            pairs = tree_paths(tree)
            encoded: List[Tuple[str, List[bytes]]] = []
            for path, leaf in pairs:
                blob = leaf_to_bytes(leaf)
                chunks = [blob[i:i + self.chunk_bytes]
                          for i in range(0, max(1, len(blob)),
                                         self.chunk_bytes)]
                encoded.append((path, chunks))
                manifest["leaves"][path] = len(chunks)

            def put_leaf(item):
                path, chunks = item
                n = 0
                for ci, chunk in enumerate(chunks):
                    self.client.put(txid, self._leaf_key(path, ci), chunk)
                    if failpoint is not None:
                        failpoint(path, ci)
                    n += len(chunk)
                return len(chunks), n

            if self.writers > 1 and failpoint is None:
                with ThreadPoolExecutor(self.writers) as pool:
                    for c, n in pool.map(put_leaf, encoded):
                        nkeys += c
                        total += n
            else:
                for item in encoded:
                    c, n = put_leaf(item)
                    nkeys += c
                    total += n

            self.client.put(txid, self._manifest_key(),
                            json.dumps(manifest).encode())
            self.client.commit_transaction(txid)
        except Exception:
            try:
                self.client.abort_transaction(txid)
            except Exception:
                pass
            raise
        return SaveResult(step, txid, nkeys + 1, total)

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        txid = self.client.start_transaction()
        try:
            raw = self.client.get(txid, self._manifest_key())
        finally:
            self.client.abort_transaction(txid)
        if raw is None:
            return None
        return int(json.loads(raw.decode())["step"])

    def restore(self, like: Optional[PyTree] = None) -> Tuple[int, PyTree,
                                                              Dict[str, Any]]:
        """Read-atomic restore of the latest committed checkpoint.

        Returns (step, tree, extra).  ``like`` supplies the tree structure
        (leaves may be arrays or ShapeDtypeStructs); without it the tree is
        returned as a flat {path: array} dict.
        """
        txid = self.client.start_transaction()
        try:
            raw = self.client.get(txid, self._manifest_key())
            if raw is None:
                raise CheckpointNotFound(self._manifest_key())
            manifest = json.loads(raw.decode())
            leaves: Dict[str, np.ndarray] = {}
            for path, nchunks in manifest["leaves"].items():
                blob = b"".join(
                    self.client.get(txid, self._leaf_key(path, ci))
                    for ci in range(nchunks))
                leaves[path] = leaf_from_bytes(blob)
        finally:
            try:
                self.client.abort_transaction(txid)
            except Exception:
                pass

        step = int(manifest["step"])
        extra = manifest.get("extra", {})
        if like is None:
            return step, leaves, extra
        flat = tree_paths(like)
        restored = []
        for path, leaf in flat:
            if path not in leaves:
                raise CheckpointNotFound(f"leaf {path} missing from manifest")
            arr = leaves[path]
            want_shape = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"leaf {path}: shape {arr.shape} != expected {want_shape}")
            restored.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        return step, jax.tree_util.tree_unflatten(treedef, restored), extra
