"""Leaf (de)serialization: jax/numpy arrays ↔ self-describing bytes.

Format: 16-byte header (magic, dtype code, rank) + dims (u32 each) + raw
little-endian data.  No pickle — checkpoints must be readable across python
versions and safe to load from shared storage.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

_MAGIC = b"AFTL"

_DTYPES: List[str] = [
    "float32", "float64", "float16", "bfloat16", "int8", "int16", "int32",
    "int64", "uint8", "uint32", "uint64", "bool",
]
_DTYPE_CODE = {name: i for i, name in enumerate(_DTYPES)}


def leaf_to_bytes(x: Any) -> bytes:
    arr = np.asarray(jax.device_get(x))
    name = arr.dtype.name if arr.dtype.name in _DTYPE_CODE else None
    if name is None:
        # bfloat16 prints as 'bfloat16' via ml_dtypes; fall back via jnp
        name = str(arr.dtype)
    code = _DTYPE_CODE[name]
    header = _MAGIC + struct.pack("<BBHI", code, arr.ndim, 0, 0)
    dims = struct.pack(f"<{arr.ndim}I", *arr.shape) if arr.ndim else b""
    if name == "bfloat16":
        payload = arr.view(np.uint16).tobytes()
    else:
        payload = arr.tobytes()
    return header + dims + payload


def leaf_from_bytes(data: bytes) -> np.ndarray:
    assert data[:4] == _MAGIC, "bad leaf magic"
    code, ndim, _, _ = struct.unpack("<BBHI", data[4:12])
    name = _DTYPES[code]
    off = 12
    shape: Tuple[int, ...] = ()
    if ndim:
        shape = struct.unpack(f"<{ndim}I", data[off:off + 4 * ndim])
        off += 4 * ndim
    if name == "bfloat16":
        import ml_dtypes

        raw = np.frombuffer(data, np.uint16, offset=off)
        return raw.view(ml_dtypes.bfloat16).reshape(shape)
    return np.frombuffer(data, np.dtype(name), offset=off).reshape(shape).copy()


def tree_paths(tree: Any) -> List[Tuple[str, Any]]:
    """Stable (path, leaf) pairs; path is '/'-joined dict keys/indices."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out
