"""Inference as a first-class AFT workload: the serving lane.

Each request is a workflow — ``tokenize → generate`` — driven through a
``WorkflowPool`` on the read-only fast lane (``TxnScope.STEP`` +
``read_only=True``): no memo writes, no commit, just read-atomic reads.
Session affinity comes from placement: both steps declare the session key
in ``Step.reads``, so the session's ``PlacementHint`` pins every request
of a session to one node, where ``StepContext.placed_node`` resolves the
node-local model replica (a ``ContinuousEngine``).  A consistent-hash or
cache-aware router therefore keeps a session's KV/weight locality without
any serving-specific routing code.  When a node dies mid-request the step
raises, the pool re-drives the workflow, and the fresh session routes to a
live replica — read-only re-execution is always safe.

Weights flow through AFT end to end:

* ``params_to_shards`` / ``shards_to_params`` pack a jax parameter tree
  into N byte shards (each embeds the publishing step, so torn assemblies
  are detectable even if isolation were broken);
* ``publish_params`` runs ``serve/refresh.py``'s fan-out/fan-in publish
  DAG — one ``TxnScope.WORKFLOW`` transaction, all-or-nothing under
  crashes, exactly-once on re-drive (UUID = ``publish.{run_id}.{step}``);
* ``read_params`` assembles the latest set in ONE read transaction
  (read-atomic ⇒ never torn) and raises ``TornWeightSet`` if the embedded
  shard steps disagree anyway — the benchmark's torn-read audit;
* ``InferenceLane.poll_weights`` probes the manifest with a
  bounded-staleness ``snapshot_read`` first (no transaction, answered from
  the gossip-fed watermark cache) and only pays the full read transaction
  when the snapshot shows — or cannot rule out — a newer step, then swaps
  every replica via ``install_weights`` (which spans the swap with the
  publish UUID for the offline checker).
"""

from __future__ import annotations

import json
import struct
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax

from ..checkpoint.serializer import leaf_from_bytes, leaf_to_bytes, tree_paths
from ..core import SnapshotUnavailable
from ..obs.registry import Registry
from ..workflow import WorkflowSpec
from .refresh import (
    build_publish_workflow,
    manifest_key,
    publish_uuid,
    read_weight_set,
)


class TornWeightSet(RuntimeError):
    """Assembled weight shards disagree on their publishing step — a torn
    read.  Read-atomic isolation makes this unreachable through AFT; the
    class exists so audits can count it reaching zero."""


# ---------------------------------------------------------------------------
# parameter tree ↔ byte shards
# ---------------------------------------------------------------------------

def _pack_shard(step: int, items: List[Tuple[str, Any]]) -> bytes:
    parts = [struct.pack("<II", step, len(items))]
    for path, leaf in items:
        blob = leaf_to_bytes(leaf)
        enc = path.encode("utf-8")
        parts.append(struct.pack("<I", len(enc)))
        parts.append(enc)
        parts.append(struct.pack("<I", len(blob)))
        parts.append(blob)
    return b"".join(parts)


def _unpack_shard(raw: bytes) -> Tuple[int, Dict[str, Any]]:
    step, count = struct.unpack_from("<II", raw, 0)
    off = 8
    leaves: Dict[str, Any] = {}
    for _ in range(count):
        (plen,) = struct.unpack_from("<I", raw, off)
        off += 4
        path = raw[off:off + plen].decode("utf-8")
        off += plen
        (blen,) = struct.unpack_from("<I", raw, off)
        off += 4
        leaves[path] = leaf_from_bytes(raw[off:off + blen])
        off += blen
    return step, leaves


def params_to_shards(params: Any, *, step: int,
                     shards: int = 4) -> Dict[str, bytes]:
    """Round-robin the tree's leaves into ``shards`` named byte blobs.
    Every blob embeds ``step`` so a torn assembly is self-evident."""
    pairs = tree_paths(params)
    buckets: List[List[Tuple[str, Any]]] = [[] for _ in range(shards)]
    for i, pair in enumerate(pairs):
        buckets[i % shards].append(pair)
    return {f"part{i}": _pack_shard(step, bucket)
            for i, bucket in enumerate(buckets)}


def shards_to_params(blobs: Mapping[str, bytes], like: Any) -> Tuple[Any, int]:
    """Reassemble a parameter tree shaped like ``like``.  Raises
    ``TornWeightSet`` when shard headers disagree on the publishing step."""
    steps = set()
    leaves: Dict[str, Any] = {}
    for name in sorted(blobs):
        step, part = _unpack_shard(blobs[name])
        steps.add(step)
        leaves.update(part)
    if len(steps) != 1:
        raise TornWeightSet(f"shard steps disagree: {sorted(steps)}")
    paths = tree_paths(like)
    missing = [p for p, _ in paths if p not in leaves]
    if missing:
        raise TornWeightSet(f"weight set missing leaves: {missing[:4]}")
    treedef = jax.tree_util.tree_structure(like)
    flat = [leaves[p] for p, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, flat), steps.pop()


# ---------------------------------------------------------------------------
# publish / read through AFT
# ---------------------------------------------------------------------------

def publish_params(driver, params: Any, *, run_id: str, step: int,
                   shards: int = 4, prefix: str = "weights"):
    """Publish a parameter tree through the atomic publish DAG.  ``driver``
    is a ``WorkflowExecutor`` (``run``) or ``WorkflowPool`` (``submit`` —
    returns the ticket; the publish commits when it resolves)."""
    blobs = params_to_shards(params, step=step, shards=shards)
    spec = build_publish_workflow(
        sorted(blobs), lambda name, _step: blobs[name],
        run_id=run_id, step=step, prefix=prefix)
    uuid = publish_uuid(run_id, step)
    if hasattr(driver, "run"):
        return driver.run(spec, uuid=uuid)
    return driver.submit(spec, uuid=uuid)


def read_params(client, like: Any, *, run_id: str,
                prefix: str = "weights") -> Optional[Tuple[int, Any]]:
    """Read-atomically assemble the latest published parameter tree.
    Returns ``(step, params)`` or None when nothing is published; raises
    ``TornWeightSet`` if the embedded shard steps disagree with each other
    or with the manifest (impossible through AFT — the audit hook)."""
    got = read_weight_set(client, run_id=run_id, prefix=prefix)
    if got is None:
        return None
    manifest_step, blobs = got
    params, embedded_step = shards_to_params(blobs, like)
    if embedded_step != manifest_step:
        raise TornWeightSet(
            f"manifest step {manifest_step} != shard step {embedded_step}")
    return manifest_step, params


# ---------------------------------------------------------------------------
# the lane
# ---------------------------------------------------------------------------

@dataclass
class LaneConfig:
    run_id: str = "serve"
    prefix: str = "weights"
    max_new_default: int = 16
    request_timeout_s: float = 120.0
    poll_every_s: float = 0.25        # replica weight-refresh cadence
    snapshot_probe: bool = True       # probe manifest via snapshot_read
    snapshot_staleness_s: float = 30.0


class InferenceLane:
    """Routes inference requests as read-only workflows over per-node
    model replicas, and keeps every replica's weights fresh through AFT.

    ``replicas`` maps node id → engine (anything with ``submit`` /
    ``install_weights`` / ``weights_step`` — in practice a
    ``ContinuousEngine``).  The caller owns engine lifecycles but
    ``lane.stop()`` stops them for convenience; ``detach`` drops a
    replica whose node was killed (in-flight requests re-route via the
    pool's retry, because a missing replica makes the step raise)."""

    def __init__(self, pool, cluster, replicas: Mapping[str, Any], *,
                 config: Optional[LaneConfig] = None, like: Any = None,
                 registry: Optional[Registry] = None):
        self.pool = pool
        self.cluster = cluster
        self.replicas: Dict[str, Any] = dict(replicas)
        self.config = config or LaneConfig()
        if like is None:
            engine = next(iter(self.replicas.values()))
            like = engine.model.abstract_params()
        self.like = like
        self.registry = registry or Registry(name="serve-lane")
        self.stats = {"requests": 0, "completed": 0, "rerouted": 0,
                      "torn_reads": 0, "refresh_polls": 0,
                      "refresh_installs": 0, "snapshot_skips": 0}
        self.registry.attach_counters(self.stats, "lane.")
        self._h_request = self.registry.histogram("lane.request.wall")
        self._stop = threading.Event()
        self._poller: Optional[threading.Thread] = None

    # ------------------------------------------------------------- requests
    @staticmethod
    def session_key(session_id) -> str:
        return f"serve/session/{session_id}"

    def spec_for(self, session_id, prompt, max_new: int) -> WorkflowSpec:
        """Build the request workflow.  Both steps read the session key
        first, so ``declared_reads()`` leads with it and the placement hint
        pins the whole request (and every request of the session) to the
        session's node."""
        cfg = self.config
        skey = self.session_key(session_id)
        mkey = manifest_key(cfg.prefix, cfg.run_id)

        def tokenize(ctx):
            ctx.maybe_fail()
            p = ctx.args["prompt"]
            if isinstance(p, str):
                return [1 + (b % 250) for b in p.encode("utf-8")]
            return [int(t) for t in p]

        def generate(ctx):
            node = ctx.placed_node
            engine = self.replicas.get(node)
            if engine is None:
                # node died (or carries no replica): raising sends the
                # workflow back through the pool, which re-routes it
                self.stats["rerouted"] += 1
                raise RuntimeError(f"no model replica on node {node!r}")
            raw = ctx.get(mkey)  # read-atomic freshness marker for the span
            manifest_step = json.loads(raw)["step"] if raw is not None else None
            ticket = engine.submit(ctx.inputs["tokenize"],
                                   ctx.args["max_new"])
            tokens = ticket.result(timeout=cfg.request_timeout_s)
            return {"tokens": tokens, "node": node,
                    "weights_step": engine.weights_step,
                    "manifest_step": manifest_step}

        spec = WorkflowSpec(f"infer-{session_id}")
        spec.step("tokenize", tokenize, reads=(skey,), read_only=True)
        spec.step("generate", generate, deps=("tokenize",),
                  reads=(skey, mkey), read_only=True)
        return spec

    def submit(self, session_id, prompt, *, max_new: Optional[int] = None,
               uuid: Optional[str] = None):
        """Submit one request; returns the pool ticket.  ``ticket.result()``
        is the usual ``WorkflowResult`` — the generate step's payload dict
        lives at ``result.results["generate"]`` (see :func:`payload`)."""
        cfg = self.config
        self.stats["requests"] += 1
        t0 = time.perf_counter()
        spec = self.spec_for(session_id, prompt,
                             max_new or cfg.max_new_default)
        ticket = self.pool.submit(
            spec, uuid=uuid,
            args={"prompt": prompt, "max_new": max_new or cfg.max_new_default})

        def _done(_):
            self._h_request.observe_s(time.perf_counter() - t0)
            self.stats["completed"] += 1

        ticket.add_done_callback(_done)
        return ticket

    @staticmethod
    def payload(result) -> Dict[str, Any]:
        """The generate step's payload from a resolved request ticket."""
        return result.results["generate"]

    # -------------------------------------------------------------- weights
    def publish(self, params: Any, step: int, *, driver=None, shards: int = 4):
        """Publish a new weight set (atomic, exactly-once).  Uses ``driver``
        when given (a WORKFLOW-scoped executor or pool — the request pool's
        STEP scope would tear the publish into per-shard transactions)."""
        if driver is None:
            driver = self._publisher()
        return publish_params(driver, params, run_id=self.config.run_id,
                              step=step, shards=shards,
                              prefix=self.config.prefix)

    def _publisher(self):
        from ..workflow import TxnScope, WorkflowConfig, WorkflowExecutor
        return WorkflowExecutor(
            self.pool.platform, cluster=self.cluster,
            config=WorkflowConfig(scope=TxnScope.WORKFLOW, max_attempts=8))

    def poll_weights(self) -> bool:
        """One refresh round over every replica: snapshot-probe the
        manifest, and when a newer step is (or may be) out there, read the
        set atomically and swap.  Returns True if any replica swapped."""
        cfg = self.config
        self.stats["refresh_polls"] += 1
        client = self.cluster.client()
        mkey = manifest_key(cfg.prefix, cfg.run_id)
        installed = False
        for node_id, engine in list(self.replicas.items()):
            if cfg.snapshot_probe:
                try:
                    snap = client.snapshot_read(mkey, cfg.snapshot_staleness_s)
                    if (snap.value is not None
                            and json.loads(snap.value)["step"]
                            <= engine.weights_step):
                        # the watermark already covers a step we have —
                        # skip the read transaction entirely
                        self.stats["snapshot_skips"] += 1
                        continue
                except SnapshotUnavailable:
                    pass  # gossip lag: fall through to the full read
            try:
                got = read_params(client, self.like, run_id=cfg.run_id,
                                  prefix=cfg.prefix)
            except TornWeightSet:
                self.stats["torn_reads"] += 1
                continue
            if got is None:
                continue
            step, params = got
            if engine.install_weights(
                    params, step,
                    publish_uuid=publish_uuid(cfg.run_id, step)):
                self.stats["refresh_installs"] += 1
                installed = True
        return installed

    def start_refresher(self) -> None:
        def loop():
            while not self._stop.wait(self.config.poll_every_s):
                try:
                    self.poll_weights()
                except Exception:
                    pass  # storage/gossip blips retry next round

        self._poller = threading.Thread(target=loop, daemon=True,
                                        name="lane-refresher")
        self._poller.start()

    # ------------------------------------------------------------ lifecycle
    def detach(self, node_id: str):
        """Drop (and stop) the replica on a dead node; in-flight requests
        routed there fail fast and re-route through the pool."""
        engine = self.replicas.pop(node_id, None)
        if engine is not None:
            engine.stop()
        return engine

    def stop(self) -> None:
        self._stop.set()
        if self._poller is not None:
            self._poller.join(timeout=5)
            self._poller = None
        for engine in self.replicas.values():
            engine.stop()
