"""Workflow-driven atomic weight publication for serving.

The serving-side instance of the DAG problem: a publisher produces N weight
shards *in parallel* (one FaaS function per shard — quantize, re-shard,
fetch from a training host) and then flips a manifest.  Without a shim, a
crash between shard writes — or a reader racing the publisher — assembles a
torn weight set.  Here the whole publish DAG is one AFT transaction
(``TxnScope.WORKFLOW``): shards fan out, the manifest fans in, and the
commit is all-or-nothing with exactly-once semantics on retry (the publish
UUID derives from ``(run_id, step)``, §3.3.1).

``publish_weights`` takes any workflow driver with a ``run(spec, uuid=)``
surface.  A single publisher hands it a ``WorkflowExecutor``; a fleet
publishing many runs/steps concurrently should instead ``submit`` the spec
from :func:`build_publish_workflow` to a shared ``WorkflowPool``
(``repro/workflow/pool.py``), which batches publish steps across runs into
shared platform invocations and hands finished publishes to the memo-record
GC.  Note the pool declares workflows finished by default — fine here, as a
publish UUID is never re-driven after its ticket resolves.  See
``docs/WORKFLOWS.md``.

``read_weight_set`` is the consumer half: one read transaction over the
manifest and every shard, so read-atomic isolation (§3.4) guarantees the
assembled set is from a single publish even while the next one is mid-commit.

This module is deliberately framework-free (raw bytes per shard); the
jax-facing ``ServeEngine.refresh_weights`` achieves the same guarantee for
checkpoints via ``AftCheckpointer``.
"""

from __future__ import annotations

import json
from typing import Callable, Dict, Optional, Sequence, Tuple

from ..workflow import WorkflowExecutor, WorkflowResult, WorkflowSpec


def shard_key(prefix: str, run_id: str, shard: str) -> str:
    return f"{prefix}/{run_id}/shard/{shard}"

def manifest_key(prefix: str, run_id: str) -> str:
    return f"{prefix}/{run_id}/manifest"

def publish_uuid(run_id: str, step: int) -> str:
    return f"publish.{run_id}.{step}"


def build_publish_workflow(
    shard_names: Sequence[str],
    produce: Callable[[str, int], bytes],
    *,
    run_id: str,
    step: int,
    prefix: str = "weights",
) -> WorkflowSpec:
    """Fan-out one step per shard (``produce(shard_name, step)`` → bytes),
    fan-in a manifest naming every shard key and the step."""
    spec = WorkflowSpec(f"publish-{run_id}-{step}")
    names = list(shard_names)

    def make_shard_step(shard: str):
        def body(ctx) -> int:
            ctx.maybe_fail()
            data = produce(shard, step)
            ctx.put(shard_key(prefix, run_id, shard), data)
            return len(data)
        return body

    step_names = [
        spec.step(f"shard:{shard}", make_shard_step(shard)) for shard in names
    ]

    def manifest(ctx) -> int:
        ctx.maybe_fail()
        ctx.put(
            manifest_key(prefix, run_id),
            json.dumps(
                {
                    "step": step,
                    "shards": {s: shard_key(prefix, run_id, s) for s in names},
                },
                separators=(",", ":"),
            ).encode(),
        )
        return step

    spec.fan_in("manifest", manifest, step_names, allow_skipped_deps=False)
    return spec


def publish_weights(
    executor: WorkflowExecutor,
    shard_names: Sequence[str],
    produce: Callable[[str, int], bytes],
    *,
    run_id: str,
    step: int,
    prefix: str = "weights",
) -> WorkflowResult:
    """Run the publish DAG with a deterministic UUID so a re-driven publish
    of the same (run_id, step) commits exactly once."""
    spec = build_publish_workflow(
        shard_names, produce, run_id=run_id, step=step, prefix=prefix
    )
    return executor.run(spec, uuid=publish_uuid(run_id, step))


def read_weight_set(
    client,
    *,
    run_id: str,
    prefix: str = "weights",
) -> Optional[Tuple[int, Dict[str, bytes]]]:
    """Assemble the latest published weight set in ONE read transaction.

    Returns ``(step, {shard_name: bytes})`` or None if nothing is published.
    Read-atomic isolation makes a torn result impossible: every shard joins
    the manifest's Atomic Readset or the read aborts (§3.4/§3.6).
    """
    tx = client.start_transaction()
    try:
        raw = client.get(tx, manifest_key(prefix, run_id))
        if raw is None:
            return None
        body = json.loads(raw)
        shards: Dict[str, bytes] = {}
        for shard, skey in body["shards"].items():
            data = client.get(tx, skey)
            if data is None:
                raise LookupError(
                    f"manifest names shard {shard!r} but {skey!r} read NULL "
                    "(read-atomicity violated?)"
                )
            shards[shard] = data
        return int(body["step"]), shards
    finally:
        client.abort_transaction(tx)  # read-only session
