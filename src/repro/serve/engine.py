"""Serving engines with AFT-backed atomic weight refresh.

The serving-side instance of the paper's problem: a trainer (or fine-tuning
job) publishes new weights as multi-key checkpoint transactions while
replicas serve traffic.  Without atomic visibility a replica hot-swapping
weights can assemble a *torn* parameter set — layer 7 from step 1000,
layer 8 from step 900 (a fractured read, §2.1).  The engines' refreshers
restore inside one AFT read transaction, so read-atomic isolation makes
the swap all-or-nothing; ``benchmarks/table2.py`` measures exactly this
anomaly class on plain storage.

Two engines share that refresh contract:

* ``ServeEngine`` — the static baseline: prompts bucketed by length, one
  batch decoded to completion before the next is admitted.  Every distinct
  (batch, prompt-length) shape recompiles the jitted prefill, and every
  request in a bucket decodes until the *longest* request finishes.
* ``ContinuousEngine`` — a continuous-batching decode loop: a fixed-slot,
  shape-stable decode state that requests join and leave mid-flight.
  Prompts prefill in fixed-size chunks interleaved between decode
  iterations (long prompts never stall the batch), admission is by free
  slots, and the one jitted decode/prefill pair compiles exactly once —
  shapes never change.  Free slots ride through decode with a sentinel
  position of ``max_len``, which the masked cache write turns into a
  no-op.

Both engines swap weights only **between** iterations (the loop snapshots
``self._params`` once per iteration under the lock), so a forward pass
never mixes two weight versions.  ``install_weights`` emits a
``weight_refresh`` trace span carrying the publishing transaction's UUID,
letting ``obs/checker.py`` correlate a replica's swap with the publish
commit in replayed traces.
"""

from __future__ import annotations

import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AftCheckpointer, CheckpointNotFound
from repro.models import Model
from repro.obs import trace as obs_trace
from repro.obs.registry import Registry

_stats_deprecation_warned = False


class EngineStats(dict):
    """Counter map that is also callable (the ``AftNode.stats`` shim):
    dict access keeps the historical ``engine.stats["tokens_out"]``
    surface, calling it returns the engine registry's full snapshot.
    New code should read ``engine.registry.snapshot()`` directly."""

    def __init__(self, counters: Dict[str, int], snapshot_fn):
        super().__init__(counters)
        self._snapshot_fn = snapshot_fn

    def __call__(self) -> Dict[str, object]:
        global _stats_deprecation_warned
        if not _stats_deprecation_warned:
            _stats_deprecation_warned = True
            warnings.warn(
                "engine.stats() is a deprecated read path; use "
                "engine.registry.snapshot() (repro.obs.registry) instead",
                DeprecationWarning, stacklevel=2)
        return self._snapshot_fn()


@dataclass
class ServeConfig:
    max_batch: int = 8                # static path: prompts per bucket
    max_len: int = 256                # KV-cache rows per request/slot
    temperature: float = 0.0          # 0 → greedy
    refresh_every_s: float = 1.0
    # --- continuous batching (ContinuousEngine) ---
    slots: int = 8                    # fixed decode-state width
    prefill_chunk: int = 16           # prompt tokens fed per prefill chunk
    prefill_chunks_per_iter: int = 1  # chunks interleaved per decode iter
    seed: int = 0                     # sampling seed (temperature > 0)


def _jit_cache_size(fn) -> int:
    """Number of compiled variants behind a jitted callable (-1 when the
    running jax has no counter).  The continuous engine's tests assert this
    stays at 1 — shape-stable means compile-once."""
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


class _WeightedEngine:
    """Shared weight/refresh/observability plumbing for both engines."""

    def __init__(self, model: Model, checkpointer: Optional[AftCheckpointer],
                 config: Optional[ServeConfig], params: Optional[Any],
                 registry: Optional[Registry], name: str):
        self.model = model
        self.ckpt = checkpointer
        # fresh default per engine — a dataclass default instance would be
        # shared (and mutated through) every engine built without a config
        self.config = config if config is not None else ServeConfig()
        self.name = name
        self._params = params
        self._weights_step = -1
        self._lock = threading.Lock()
        self._stop_refresh = threading.Event()
        self._refresher: Optional[threading.Thread] = None
        self.registry = registry or Registry(name=name)
        self.stats = EngineStats(
            {"refreshes": 0, "requests": 0, "tokens_out": 0},
            self.registry.snapshot)
        self.registry.attach_counters(self.stats)
        self._h_prefill = self.registry.histogram("prefill.latency")
        self._h_decode = self.registry.histogram("decode.latency")
        self._h_refresh = self.registry.histogram("refresh.latency")

    # ------------------------------------------------------------- weights
    def install_weights(self, params: Any, step: int,
                        publish_uuid: Optional[str] = None,
                        dur_ms: float = 0.0) -> bool:
        """Swap the serving weights (between iterations — the decode loop
        reads ``self._params`` once per iteration).  Returns False when
        ``step`` is not newer than the installed set.  Emits a
        ``weight_refresh`` span carrying the publishing transaction's UUID
        so the offline checker can correlate the swap with the publish."""
        with self._lock:
            if step <= self._weights_step:
                return False
            self._params = params
            self._weights_step = step
            self.stats["refreshes"] += 1
        tracer = obs_trace.get_tracer()
        if tracer.enabled:
            trace = (obs_trace.txn_trace_id(publish_uuid) if publish_uuid
                     else obs_trace.trace_id(self.name))
            tracer.emit(
                "span", name="weight_refresh", trace=trace,
                span=obs_trace.span_id(trace, "weight_refresh",
                                       f"{self.name}@{step}"),
                parent=None, dur_ms=round(dur_ms, 3), status="ok",
                publish_uuid=publish_uuid, step=step, engine=self.name)
        return True

    def refresh_weights(self) -> bool:
        """Atomically load the latest committed checkpoint.  Returns True
        if a newer weight set was installed."""
        if self.ckpt is None:
            return False
        t0 = time.perf_counter()
        try:
            like = {"params": self.model.abstract_params()}
            step, tree, _ = self.ckpt.restore(like=like)
        except CheckpointNotFound:
            return False
        dur = time.perf_counter() - t0
        self._h_refresh.observe_s(dur)
        return self.install_weights(tree["params"], step,
                                    publish_uuid=self.ckpt._save_uuid(step),
                                    dur_ms=dur * 1e3)

    def start_refresher(self) -> None:
        def loop():
            while not self._stop_refresh.wait(self.config.refresh_every_s):
                try:
                    self.refresh_weights()
                except Exception:
                    pass  # storage blips are retried next round

        self._refresher = threading.Thread(target=loop, daemon=True)
        self._refresher.start()

    def stop(self) -> None:
        self._stop_refresh.set()
        if self._refresher is not None:
            self._refresher.join(timeout=5)
            self._refresher = None

    @property
    def weights_step(self) -> int:
        return self._weights_step

    def current_params(self):
        with self._lock:
            return self._params, self._weights_step


class ServeEngine(_WeightedEngine):
    """Static length-bucketed batch serving (the baseline the continuous
    engine is measured against in ``benchmarks/fig_serve.py``)."""

    def __init__(self, model: Model, checkpointer: Optional[AftCheckpointer],
                 config: Optional[ServeConfig] = None,
                 params: Optional[Any] = None, *,
                 registry: Optional[Registry] = None, name: str = "serve"):
        super().__init__(model, checkpointer, config, params, registry, name)
        max_len = self.config.max_len

        def prefill(params, tokens):
            return model.prefill(params, tokens, max_len)

        def decode(params, state, tokens, position):
            logits, state = model.decode_step(params, state, tokens, position)
            return logits, state

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def compile_counts(self) -> Dict[str, int]:
        return {"prefill": _jit_cache_size(self._prefill),
                "decode": _jit_cache_size(self._decode)}

    # ------------------------------------------------------------- serving
    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        t = self.config.temperature
        if t <= 0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        return jax.random.categorical(key, logits[:, -1, :] / t, axis=-1)

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int,
                 seed: int = 0) -> List[List[int]]:
        """Batched generation.  Prompts in one call must share a length
        (callers bucket by length — standard prefill bucketing)."""
        assert prompts, "empty batch"
        plen = len(prompts[0])
        assert all(len(p) == plen for p in prompts), "bucket by length"
        assert plen + max_new <= self.config.max_len
        with self._lock:
            params = self._params
        assert params is not None, "no weights loaded"
        self.stats["requests"] += len(prompts)

        tokens = jnp.asarray(np.asarray(prompts, np.int32))
        t0 = time.perf_counter()
        _, state = self._prefill(params, tokens)
        self._h_prefill.observe_s(time.perf_counter() - t0)
        # the last prompt token's logits come from decode of that token at
        # its position: re-run the final position for the first new token
        out: List[List[int]] = [[] for _ in prompts]
        key = jax.random.key(seed)
        cur = tokens[:, -1:]
        position = plen - 1
        for i in range(max_new):
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            logits, state = self._decode(params, state, cur,
                                         jnp.int32(position + i))
            nxt = self._sample(logits, sub)
            cur = nxt[:, None].astype(jnp.int32)
            toks = np.asarray(nxt).tolist()
            self._h_decode.observe_s(time.perf_counter() - t0)
            for b, tok in enumerate(toks):
                out[b].append(int(tok))
            self.stats["tokens_out"] += len(prompts)
        return out


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

class GenTicket:
    """Handle for one in-flight request; resolves when it leaves the batch."""

    __slots__ = ("tokens", "prompt_len", "submitted_at", "finished_at",
                 "error", "_done")

    def __init__(self, prompt_len: int):
        self.tokens: List[int] = []
        self.prompt_len = prompt_len
        self.submitted_at = time.perf_counter()
        self.finished_at: Optional[float] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> List[int]:
        if not self._done.wait(timeout):
            raise TimeoutError("generation still in flight")
        if self.error is not None:
            raise self.error
        return list(self.tokens)


class _SlotReq:
    __slots__ = ("ticket", "prompt", "max_new", "offset")

    def __init__(self, ticket: GenTicket, prompt: List[int], max_new: int):
        self.ticket = ticket
        self.prompt = prompt
        self.max_new = max_new
        self.offset = 0  # prompt tokens already prefilled


def _slice_slot(state, slot):
    """One slot's decode state: the stacked pattern carries batch on axis 1
    (axis 0 is layers), tail blocks carry batch on axis 0."""
    out = {"pattern": jax.tree.map(
        lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
        state["pattern"])}
    if "tail" in state:
        out["tail"] = jax.tree.map(
            lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=0),
            state["tail"])
    return out


def _update_slot(state, sub, slot):
    out = {"pattern": jax.tree.map(
        lambda l, s: jax.lax.dynamic_update_slice_in_dim(
            l, s.astype(l.dtype), slot, axis=1),
        state["pattern"], sub["pattern"])}
    if "tail" in state:
        out["tail"] = jax.tree.map(
            lambda l, s: jax.lax.dynamic_update_slice_in_dim(
                l, s.astype(l.dtype), slot, axis=0),
            state["tail"], sub["tail"])
    return out


class ContinuousEngine(_WeightedEngine):
    """Continuous-batching decode loop over a fixed-slot decode state.

    Requests join free slots mid-flight and leave as soon as their own
    ``max_new`` is reached; prompts prefill in fixed ``prefill_chunk``-sized
    chunks interleaved between decode iterations.  All jitted shapes are
    functions of (slots, prefill_chunk, max_len) only, so the decode/prefill
    pair compiles exactly once per engine — ``compile_counts()`` exposes the
    jit cache sizes for tests to assert on.

    Drive it either manually (``step()`` per iteration — deterministic, used
    by tests) or with the background loop (``start()`` / ``stop()``).  The
    prompt's padded prefill footprint (``ceil(len(prompt)/chunk) * chunk``)
    and ``len(prompt) + max_new`` must both fit in ``max_len``.
    """

    def __init__(self, model: Model, checkpointer: Optional[AftCheckpointer]
                 = None, config: Optional[ServeConfig] = None,
                 params: Optional[Any] = None, *,
                 registry: Optional[Registry] = None,
                 name: str = "continuous"):
        super().__init__(model, checkpointer, config, params, registry, name)
        if not model.supports_chunked_prefill:
            raise NotImplementedError(
                "continuous batching needs chunked prefill; block kinds "
                f"{sorted(set(model.cfg.pattern) | set(model.cfg.tail))} "
                "include non-attention state")
        cfg = self.config
        S, L, C = int(cfg.slots), int(cfg.max_len), int(cfg.prefill_chunk)
        assert 0 < C <= L, "prefill_chunk must fit max_len"
        self._S, self._L, self._C = S, L, C
        temp = float(cfg.temperature)

        def sample(logits, key):
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits / temp, axis=-1).astype(jnp.int32)

        def decode(params, state, tokens, positions, key):
            logits, state = model.decode_step(params, state,
                                              tokens[:, None], positions)
            return sample(logits[:, -1, :], key), state

        def prefill(params, state, slot, tokens, offset, last_index, key):
            sub = _slice_slot(state, slot)
            logits, sub = model.prefill_chunk(params, sub,
                                              tokens[None, :], offset)
            state = _update_slot(state, sub, slot)
            nxt = sample(jnp.take(logits[0], last_index, axis=0), key)
            return nxt, state

        # donate the decode state: it is rewritten in place every iteration
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._prefill = jax.jit(prefill, donate_argnums=(1,))

        self._state = model.init_decode_state(S, L)
        self._tokens = np.zeros((S,), np.int32)
        # position == max_len is the free-slot sentinel: the masked cache
        # write touches nothing and the row attends an empty prefix
        self._positions = np.full((S,), L, np.int32)
        self._slots: List[Optional[_SlotReq]] = [None] * S
        self._queue: deque = deque()
        self._qlock = threading.Lock()
        self._work = threading.Event()
        self._loop_stop = threading.Event()
        self._loop: Optional[threading.Thread] = None
        self._base_key = jax.random.key(int(cfg.seed))
        self._iter = 0
        self.stats.update({"decode_iters": 0, "prefill_chunks": 0,
                           "completed": 0, "queue_peak": 0})
        self.registry.gauge("active_slots").set_fn(
            lambda: int(np.sum(self._positions < self._L)))

    def compile_counts(self) -> Dict[str, int]:
        return {"prefill": _jit_cache_size(self._prefill),
                "decode": _jit_cache_size(self._decode)}

    # ------------------------------------------------------------- requests
    def submit(self, prompt: Sequence[int], max_new: int) -> GenTicket:
        prompt = [int(t) for t in prompt]
        assert prompt and max_new >= 1, "need a prompt and max_new >= 1"
        footprint = -(-len(prompt) // self._C) * self._C
        assert footprint <= self._L and len(prompt) + max_new <= self._L, (
            f"prompt {len(prompt)} (+{max_new} new) does not fit "
            f"max_len {self._L} with chunk {self._C}")
        ticket = GenTicket(len(prompt))
        with self._qlock:
            self._queue.append(_SlotReq(ticket, prompt, int(max_new)))
            self.stats["requests"] += 1
            self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                           len(self._queue))
        self._work.set()
        return ticket

    def _key_for(self, n: int) -> jax.Array:
        if self.config.temperature <= 0:
            return self._base_key  # unused by greedy sampling
        return jax.random.fold_in(self._base_key, n)

    def _finish(self, slot: int) -> None:
        req = self._slots[slot]
        self._slots[slot] = None
        self._tokens[slot] = 0
        self._positions[slot] = self._L
        req.ticket.finished_at = time.perf_counter()
        req.ticket._done.set()
        self.stats["completed"] += 1

    # ------------------------------------------------------------- the loop
    def step(self) -> bool:
        """One engine iteration: admit queued requests into free slots,
        advance up to ``prefill_chunks_per_iter`` prompt chunks, then run
        one batched decode over every active slot.  Returns True if any
        work was done.  Weights are read once at iteration start — a swap
        mid-iteration takes effect next iteration, never mid-forward."""
        with self._lock:
            params = self._params
        if params is None:
            return False
        did = False
        with self._qlock:
            for s in range(self._S):
                if self._slots[s] is None and self._queue:
                    self._slots[s] = self._queue.popleft()

        budget = int(self.config.prefill_chunks_per_iter)
        for s in range(self._S):
            if budget <= 0:
                break
            req = self._slots[s]
            if req is None or req.offset >= len(req.prompt):
                continue
            did = True
            budget -= 1
            plen = len(req.prompt)
            off = req.offset
            chunk = req.prompt[off:off + self._C]
            is_final = off + len(chunk) >= plen
            last_index = len(chunk) - 1
            if len(chunk) < self._C:  # pad the final chunk to fixed shape
                chunk = chunk + [0] * (self._C - len(chunk))
            t0 = time.perf_counter()
            nxt, self._state = self._prefill(
                params, self._state, jnp.int32(s),
                jnp.asarray(chunk, jnp.int32), jnp.int32(off),
                jnp.int32(last_index), self._key_for(self._iter * 2 + 1))
            req.offset = min(off + self._C, plen)
            if is_final:
                # final chunk yields the first generated token (logits at
                # the last prompt position); the request turns active
                tok = int(np.asarray(nxt))
                req.ticket.tokens.append(tok)
                self.stats["tokens_out"] += 1
                if len(req.ticket.tokens) >= req.max_new:
                    self._finish(s)
                else:
                    self._tokens[s] = tok
                    self._positions[s] = plen
            self._h_prefill.observe_s(time.perf_counter() - t0)
            self.stats["prefill_chunks"] += 1

        active = [s for s in range(self._S) if self._positions[s] < self._L]
        if active:
            did = True
            t0 = time.perf_counter()
            nxt, self._state = self._decode(
                params, self._state, jnp.asarray(self._tokens),
                jnp.asarray(self._positions), self._key_for(self._iter * 2))
            nxt = np.asarray(nxt)
            self._h_decode.observe_s(time.perf_counter() - t0)
            self.stats["decode_iters"] += 1
            for s in active:
                req = self._slots[s]
                tok = int(nxt[s])
                req.ticket.tokens.append(tok)
                self.stats["tokens_out"] += 1
                if (len(req.ticket.tokens) >= req.max_new
                        or self._positions[s] + 1 >= self._L):
                    self._finish(s)
                else:
                    self._tokens[s] = tok
                    self._positions[s] += 1
        self._iter += 1
        return did

    def start(self) -> None:
        """Run the decode loop on a background thread."""
        if self._loop is not None:
            return
        self._loop_stop.clear()

        def loop():
            while not self._loop_stop.is_set():
                if not self.step():
                    self._work.clear()
                    self._work.wait(timeout=0.02)

        self._loop = threading.Thread(target=loop, daemon=True,
                                      name=f"{self.name}-decode")
        self._loop.start()

    def stop(self) -> None:
        self._loop_stop.set()
        self._work.set()
        if self._loop is not None:
            self._loop.join(timeout=30)
            self._loop = None
        # fail whatever is still in flight so waiters unblock
        with self._qlock:
            pending = list(self._queue)
            self._queue.clear()
        for req in pending + [r for r in self._slots if r is not None]:
            if not req.ticket.done():
                req.ticket.error = RuntimeError(
                    f"engine {self.name} stopped mid-request")
                req.ticket._done.set()
        super().stop()
