"""Batched serving engine with AFT-backed atomic weight refresh.

The serving-side instance of the paper's problem: a trainer (or fine-tuning
job) publishes new weights as multi-key checkpoint transactions while
replicas serve traffic.  Without atomic visibility a replica hot-swapping
weights can assemble a *torn* parameter set — layer 7 from step 1000,
layer 8 from step 900 (a fractured read, §2.1).  The engine's refresher
restores inside one AFT read transaction, so read-atomic isolation makes
the swap all-or-nothing; ``benchmarks/table2.py`` measures exactly this
anomaly class on plain storage.

Requests are batched per decode loop iteration (prompts bucketed by length;
greedy or temperature sampling), and weights swap between iterations — the
engine never mixes two weight versions inside one forward pass.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AftCheckpointer, CheckpointNotFound
from repro.models import Model


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0          # 0 → greedy
    refresh_every_s: float = 1.0


class ServeEngine:
    def __init__(self, model: Model, checkpointer: Optional[AftCheckpointer],
                 config: ServeConfig = ServeConfig(),
                 params: Optional[Any] = None):
        self.model = model
        self.ckpt = checkpointer
        self.config = config
        self._params = params
        self._weights_step = -1
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._refresher: Optional[threading.Thread] = None
        self.stats = {"refreshes": 0, "requests": 0, "tokens_out": 0}

        max_len = config.max_len

        def prefill(params, tokens):
            return model.prefill(params, tokens, max_len)

        def decode(params, state, tokens, position):
            logits, state = model.decode_step(params, state, tokens, position)
            return logits, state

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    # ------------------------------------------------------------- weights
    def refresh_weights(self) -> bool:
        """Atomically load the latest committed checkpoint.  Returns True if
        a newer weight set was installed."""
        if self.ckpt is None:
            return False
        try:
            like = {"params": self.model.abstract_params()}
            step, tree, _ = self.ckpt.restore(like=like)
        except CheckpointNotFound:
            return False
        with self._lock:
            if step <= self._weights_step:
                return False
            self._params = tree["params"]
            self._weights_step = step
            self.stats["refreshes"] += 1
        return True

    def start_refresher(self) -> None:
        def loop():
            while not self._stop.wait(self.config.refresh_every_s):
                try:
                    self.refresh_weights()
                except Exception:
                    pass  # storage blips are retried next round

        self._refresher = threading.Thread(target=loop, daemon=True)
        self._refresher.start()

    def stop(self) -> None:
        self._stop.set()
        if self._refresher is not None:
            self._refresher.join(timeout=5)

    @property
    def weights_step(self) -> int:
        return self._weights_step

    # ------------------------------------------------------------- serving
    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        t = self.config.temperature
        if t <= 0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        return jax.random.categorical(key, logits[:, -1, :] / t, axis=-1)

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int,
                 seed: int = 0) -> List[List[int]]:
        """Batched generation.  Prompts in one call must share a length
        (callers bucket by length — standard prefill bucketing)."""
        assert prompts, "empty batch"
        plen = len(prompts[0])
        assert all(len(p) == plen for p in prompts), "bucket by length"
        assert plen + max_new <= self.config.max_len
        with self._lock:
            params = self._params
        assert params is not None, "no weights loaded"
        self.stats["requests"] += len(prompts)

        tokens = jnp.asarray(np.asarray(prompts, np.int32))
        _, state = self._prefill(params, tokens)
        # the last prompt token's logits come from decode of that token at
        # its position: re-run the final position for the first new token
        out = [[] for _ in prompts]
        key = jax.random.key(seed)
        cur = tokens[:, -1:]
        position = plen - 1
        for i in range(max_new):
            key, sub = jax.random.split(key)
            logits, state = self._decode(params, state, cur,
                                         jnp.int32(position + i))
            nxt = self._sample(logits, sub)
            cur = nxt[:, None].astype(jnp.int32)
            for b, tok in enumerate(np.asarray(nxt).tolist()):
                out[b].append(int(tok))
            self.stats["tokens_out"] += len(prompts)
        return out
