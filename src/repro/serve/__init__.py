"""Serving: continuous-batching decode engines with read-atomic weight
refresh, and the AFT serving lane (inference requests as read-only
workflows).

``refresh`` (workflow-driven atomic weight publication) is framework-free;
the jax-backed engines (``ServeEngine``, ``ContinuousEngine``) and the
``lane`` module (parameter-tree sharding + ``InferenceLane``) are imported
lazily so environments without jax can still drive publish/read workflows.
"""

from .refresh import (
    build_publish_workflow,
    manifest_key,
    publish_uuid,
    publish_weights,
    read_weight_set,
    shard_key,
)

__all__ = [
    "ContinuousEngine",
    "EngineStats",
    "GenTicket",
    "InferenceLane",
    "LaneConfig",
    "ServeConfig",
    "ServeEngine",
    "TornWeightSet",
    "build_publish_workflow",
    "manifest_key",
    "params_to_shards",
    "publish_params",
    "publish_uuid",
    "publish_weights",
    "read_params",
    "read_weight_set",
    "shard_key",
    "shards_to_params",
]

_ENGINE = ("ServeEngine", "ServeConfig", "ContinuousEngine", "EngineStats",
           "GenTicket")
_LANE = ("InferenceLane", "LaneConfig", "TornWeightSet", "params_to_shards",
         "publish_params", "read_params", "shards_to_params")


def __getattr__(name):
    if name in _ENGINE:
        from . import engine  # heavy: imports jax

        return getattr(engine, name)
    if name in _LANE:
        from . import lane  # heavy: imports jax via the serializer

        return getattr(lane, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
