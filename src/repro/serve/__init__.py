"""Serving: batched decode engine with read-atomic weight refresh."""

from .engine import ServeEngine, ServeConfig

__all__ = ["ServeEngine", "ServeConfig"]
