"""Serving: batched decode engine with read-atomic weight refresh.

``refresh`` (workflow-driven atomic weight publication) is framework-free;
the jax-backed ``ServeEngine`` is imported lazily so environments without
jax can still drive publish/read workflows.
"""

from .refresh import (
    build_publish_workflow,
    publish_weights,
    read_weight_set,
)

__all__ = [
    "ServeEngine",
    "ServeConfig",
    "build_publish_workflow",
    "publish_weights",
    "read_weight_set",
]


def __getattr__(name):
    if name in ("ServeEngine", "ServeConfig"):
        from .engine import ServeConfig, ServeEngine  # heavy: imports jax
        return {"ServeEngine": ServeEngine, "ServeConfig": ServeConfig}[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
