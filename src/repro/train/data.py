"""Deterministic synthetic data pipeline.

The pipeline is *stateless*: ``batch_at(step)`` is a pure function of
(seed, step), so the only pipeline state the checkpoint must carry is the
step counter itself — restart-safe exactly-once sample accounting falls out
of determinism rather than cursor logging.  (A file-backed pipeline would
checkpoint its shard cursor through the same AFT transaction; the interface
is the same.)

The token stream is a Zipf-ish unigram mixture with a repeated-ngram
structure, so small models actually reduce loss on it (quickstart/examples
show learning curves, not flat noise).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_seq: int = 0        # stub modality tokens (audio/vlm archs)
    d_model: int = 0


class SyntheticLM:
    """Deterministic pseudo-corpus: next-token-predictable structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._key = jax.random.key(cfg.seed)
        # fixed "grammar": each token deterministically suggests a successor
        rng = np.random.default_rng(cfg.seed)
        self._successor = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(cfg.vocab_size,)),
            jnp.int32)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        key = jax.random.fold_in(self._key, step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s = cfg.global_batch, cfg.seq_len
        # start tokens ~ zipf-ish (squared uniform → low ids more likely)
        u = jax.random.uniform(k1, (b, 1))
        start = (u * u * cfg.vocab_size).astype(jnp.int32)

        # follow the grammar with 10% noise
        def step_fn(tok, k):
            nxt = self._successor[tok[:, 0]][:, None]
            noise = jax.random.randint(k, tok.shape, 0, cfg.vocab_size)
            use_noise = jax.random.bernoulli(k, 0.1, tok.shape)
            return jnp.where(use_noise, noise, nxt), None

        def scan_body(carry, k):
            nxt, _ = step_fn(carry, k)
            return nxt, nxt

        keys = jax.random.split(k2, s)
        _, toks = jax.lax.scan(scan_body, start, keys)
        tokens = jnp.concatenate([start, toks[:, :, 0].T], axis=1)  # (b, s+1)
        batch = {"tokens": tokens[:, :-1],
                 "labels": tokens[:, 1:]}
        if cfg.frontend_seq:
            batch["frontend"] = jax.random.normal(
                k3, (b, cfg.frontend_seq, cfg.d_model), jnp.float32
            ).astype(jnp.bfloat16).astype(jnp.float32)
        return batch


def data_for_model(cfg_model, global_batch: int, seq_len: int,
                   seed: int = 0) -> SyntheticLM:
    frontend = 0
    if cfg_model.is_encoder_decoder:
        frontend = cfg_model.encoder_seq
    elif cfg_model.vision_seq:
        frontend = cfg_model.vision_seq
    return SyntheticLM(DataConfig(
        vocab_size=cfg_model.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed, frontend_seq=frontend,
        d_model=cfg_model.d_model))
