"""Optimizers with declarative state.

Unlike optax-style opaque states, state *definitions* here mirror the model's
``ParamDef`` tree, so the dry-run can derive abstract optimizer states and
their PartitionSpecs exactly like parameters (same logical axes ⇒ same
sharding ⇒ ZeRO-style fully sharded optimizer state under the FSDP rules).

Two families:

* ``adamw``     — classic AdamW; ``m``/``v`` in fp32 (or bf16 — a
                  distributed-memory trick for the largest archs).
* ``adafactor`` — factored second moment (row/col statistics) with optional
                  momentum; the state for a (d_in, d_out) matrix is
                  O(d_in + d_out).  Used for the 1T-param MoE cell where
                  full AdamW state cannot fit a single pod.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, is_def

PyTree = Any


def _like(d: ParamDef, dtype: str) -> ParamDef:
    return ParamDef(d.shape, d.axes, dtype, init="zeros")


def _global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


@dataclass(frozen=True)
class Optimizer:
    name: str
    state_defs: Callable[[PyTree], PyTree]
    init: Callable[[PyTree], PyTree]
    # update(grads, state, params, step) -> (new_params, new_state)
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], Tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
    moment_dtype: str = "float32",
    warmup_steps: int = 100,
) -> Optimizer:
    def state_defs(param_defs: PyTree) -> PyTree:
        return {
            "m": jax.tree.map(lambda d: _like(d, moment_dtype), param_defs,
                              is_leaf=is_def),
            "v": jax.tree.map(lambda d: _like(d, moment_dtype), param_defs,
                              is_leaf=is_def),
        }

    def init(params: PyTree) -> PyTree:
        zeros = lambda p: jnp.zeros(p.shape, jnp.dtype(moment_dtype))  # noqa
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def schedule(step: jax.Array) -> jax.Array:
        warm = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        return lr * warm

    def update(grads, state, params, step):
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12)) \
            if grad_clip > 0 else 1.0
        t = step.astype(jnp.float32) + 1.0
        lr_t = schedule(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def leaf(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
            upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)
            return newp, m32.astype(m.dtype), v32.astype(v.dtype)

        out = jax.tree.map(leaf, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer("adamw", state_defs, init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; memory O(rows+cols) per matrix)
# ---------------------------------------------------------------------------

def adafactor(
    lr: float = 1e-3,
    decay: float = 0.99,
    eps: float = 1e-30,
    weight_decay: float = 0.0,
    grad_clip: float = 1.0,
    warmup_steps: int = 100,
) -> Optimizer:
    def _factored(d_or_p) -> bool:
        return len(d_or_p.shape) >= 2

    def state_defs(param_defs: PyTree) -> PyTree:
        def leaf(d: ParamDef):
            if _factored(d):
                row = ParamDef(d.shape[:-1], d.axes[:-1], "float32",
                               init="zeros")
                col = ParamDef(d.shape[:-2] + d.shape[-1:],
                               d.axes[:-2] + d.axes[-1:], "float32",
                               init="zeros")
                return {"vr": row, "vc": col}
            return {"v": _like(d, "float32")}

        return {"f": jax.tree.map(leaf, param_defs, is_leaf=is_def)}

    def init(params: PyTree) -> PyTree:
        def leaf(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(leaf, params)}

    def update(grads, state, params, step):
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12)) \
            if grad_clip > 0 else 1.0
        warm = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        lr_t = lr * warm

        def leaf(p, g, s):
            g = g.astype(jnp.float32) * scale
            g2 = g * g + eps
            if _factored(p):
                vr = decay * s["vr"] + (1 - decay) * g2.mean(axis=-1)
                vc = decay * s["vc"] + (1 - decay) * g2.mean(axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)[..., None]
                )
                upd = g / jnp.maximum(denom, 1e-12)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                upd = g / (jnp.sqrt(v) + 1e-12)
                new_s = {"v": v}
            # relative step-size clipping (Adafactor's d=1.0 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["f"])
        outs = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_state = {"f": jax.tree.unflatten(treedef, [o[1] for o in outs])}
        return new_params, new_state

    return Optimizer("adafactor", state_defs, init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adamw-bf16":
        return adamw(moment_dtype="bfloat16", **kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
