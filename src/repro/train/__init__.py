"""Fault-tolerant training substrate.

``optim.py``    — AdamW / Adafactor with declarative (ParamDef-mirrored)
                  optimizer state so the dry-run can lower abstract states
                  with correct shardings.
``data.py``     — deterministic, stateless synthetic data pipeline whose
                  cursor is part of the AFT-checkpointed training state
                  (exactly-once sample accounting across restarts).
``loop.py``     — the AFT-transactional training loop: every checkpoint is
                  one atomic AFT transaction spanning all state leaves.
"""

from .optim import Optimizer, adafactor, adamw, get_optimizer
