"""The AFT-transactional training loop.

Fault model (matches the paper's retry-based FaaS model, §3.3.1): a training
job is a sequence of *logical requests* — N optimizer steps followed by one
checkpoint transaction.  Workers are stateless between checkpoints; any
crash (preemption, OOM, host failure) is recovered by restarting from the
last *committed* checkpoint transaction.  Guarantees:

* **atomic visibility** — a checkpoint is one AFT transaction over all
  state leaves (params, optimizer moments, step, data cursor, RNG); readers
  (restarts, evaluators, serving) can never observe a torn mixture of steps;
* **exactly-once step accounting** — the save transaction's UUID is derived
  from (run_id, step): a crashed-then-retried save commits once; the data
  pipeline is a pure function of the committed step, so no sample is
  skipped or double-counted across restarts;
* **elasticity** — checkpints are stored as full (unsharded) leaves, so a
  restart may resume on a different device count / mesh shape.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import AftCheckpointer, CheckpointNotFound
from repro.models import Model
from repro.train.data import SyntheticLM
from repro.train.optim import Optimizer


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    seed: int = 0
    # failure injection (tests / demos): crash the *process state* right
    # after this step's update, before or during its checkpoint
    crash_after_step: Optional[int] = None
    crash_during_save: bool = False


class CrashInjected(Exception):
    pass


class Trainer:
    def __init__(self, model: Model, optimizer: Optimizer, data: SyntheticLM,
                 checkpointer: Optional[AftCheckpointer],
                 config: TrainerConfig = TrainerConfig()):
        self.model = model
        self.opt = optimizer
        self.data = data
        self.ckpt = checkpointer
        self.config = config
        self.history: List[Dict[str, float]] = []

        def train_step(params, opt_state, step, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            params, opt_state = optimizer.update(grads, opt_state, params,
                                                 step)
            return params, opt_state, dict(metrics, loss=loss)

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------ lifecycle
    def init_state(self):
        params = self.model.init_params(jax.random.key(self.config.seed))
        opt_state = self.opt.init(params)
        return {"params": params, "opt": opt_state}, 0

    def restore_or_init(self):
        if self.ckpt is None:
            return self.init_state()
        like, _ = self.init_state()   # structure template (cheap at test scale)
        try:
            step, tree, extra = self.ckpt.restore(like=like)
            return tree, int(extra.get("next_step", step + 1))
        except CheckpointNotFound:
            return like, 0

    def save(self, step: int, state) -> None:
        if self.ckpt is None:
            return
        failpoint = None
        if (self.config.crash_during_save
                and self.config.crash_after_step == step):
            calls = {"n": 0}

            def failpoint(path, ci):  # noqa: F811
                calls["n"] += 1
                if calls["n"] >= 3:
                    raise CrashInjected(f"mid-save crash at step {step}")

        self.ckpt.save(step, state, extra={"next_step": step + 1},
                       failpoint=failpoint)

    # ----------------------------------------------------------------- run
    def run(self, steps: Optional[int] = None) -> List[Dict[str, float]]:
        """Run until ``total_steps`` (or ``steps`` more), checkpointing every
        ``ckpt_every``.  Raises ``CrashInjected`` for failure-injection tests
        — the caller restarts by constructing a fresh Trainer and calling
        ``run`` again; recovery happens in ``restore_or_init``."""
        cfg = self.config
        state, start = self.restore_or_init()
        end = cfg.total_steps if steps is None else min(
            cfg.total_steps, start + steps)
        t0 = time.time()
        for step in range(start, end):
            batch = self.data.batch_at(step)
            params, opt, metrics = self._step_fn(
                state["params"], state["opt"], jnp.int32(step), batch)
            state = {"params": params, "opt": opt}
            if step % cfg.log_every == 0 or step == end - 1:
                rec = {k: float(v) for k, v in metrics.items()}
                rec["step"] = step
                rec["wall_s"] = round(time.time() - t0, 3)
                self.history.append(rec)
            if (cfg.crash_after_step == step
                    and not cfg.crash_during_save):
                raise CrashInjected(f"crash after step {step}")
            is_last = step == end - 1
            if (step + 1) % cfg.ckpt_every == 0 or is_last:
                self.save(step, state)
        return self.history
