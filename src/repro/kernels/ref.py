"""Pure-jnp oracles for the Pallas kernels (CPU ground truth)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def attention_ref(
    q: jax.Array,                 # (B, H, Sq, D)
    k: jax.Array,                 # (B, KVH, Sk, D)
    v: jax.Array,                 # (B, KVH, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_valid: Optional[int] = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bngqd,bnkd->bngqk", qg, kf) / math.sqrt(d)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    if kv_valid is not None:
        mask &= k_pos < kv_valid
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax of all-NEG_INF is uniform; zero them to
    # match the kernel's exact-0 convention
    any_valid = mask.any(axis=1)
    o = jnp.einsum("bngqk,bnkd->bngqd", p, v.astype(jnp.float32))
    o = jnp.where(any_valid[None, None, None, :, None], o, 0.0)
    return o.reshape(b, h, sq, d).astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,       # (B, S, H, P) pre-scaled (x · Δt)
    da: jax.Array,      # (B, S, H)    log decay (Δt · a)
    b_mat: jax.Array,   # (B, S, N)
    c_mat: jax.Array,   # (B, S, N)
):
    """Sequential recurrence oracle.  Returns (y, final_state)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    x = x.astype(jnp.float32)
    da = da.astype(jnp.float32)
    b_mat = b_mat.astype(jnp.float32)
    c_mat = c_mat.astype(jnp.float32)

    def step(state, t):
        xt, dat, bt, ct = t
        state = state * jnp.exp(dat)[:, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (x.swapaxes(0, 1), da.swapaxes(0, 1), b_mat.swapaxes(0, 1),
          c_mat.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), state
