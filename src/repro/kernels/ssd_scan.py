"""Pallas TPU chunked-SSD (Mamba-2) scan kernel.

TPU-native adaptation of the SSD algorithm: the (batch, head) grid axes are
parallel; the chunk axis is the innermost (sequential) grid dimension, and
the inter-chunk recurrent state (P × N) lives in VMEM scratch across chunk
steps — the sequential TPU grid replaces the GPU implementation's
inter-block state-passing kernel.  Within a chunk everything is dense
MXU-shaped matmuls:

    y_intra = (L ⊙ (C Bᵀ)) · X            (chunk × chunk quadratic part)
    y_inter = diag(exp(csum)) · C · state   (contribution of entering state)
    state'  = exp(total)·state + Σ_k B_k (decay_k X_k)ᵀ

Inputs are the pre-scaled tensors produced by the Mamba-2 block projection
(see ``repro.models.ssm``): x·Δt, Δt·a (log-decay), B, C.  The final state
is emitted as a second output (written every chunk step; the last write is
the final state), which prefill uses to seed decoding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, st_ref, state_scr, *,
                chunk: int):
    """One (b, h, ic) grid step.

    x_ref: (1, chunk, 1, P) pre-scaled inputs (x·Δt); da_ref: (1, chunk, 1);
    b_ref/c_ref: (1, chunk, N); y_ref: (1, chunk, 1, P);
    st_ref: (1, 1, P, N) final-state output; state_scr: (P, N) f32 VMEM.
    """
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)            # (chunk, P)
    da = da_ref[0, :, 0].astype(jnp.float32)             # (chunk,)
    bm = b_ref[0].astype(jnp.float32)                    # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)                    # (chunk, N)

    csum = jnp.cumsum(da)                                # inclusive
    total = csum[-1]

    # L[q, k] = exp(csum_q − csum_k) for q ≥ k (decay from k to q)
    seg = csum[:, None] - csum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(qi >= ki, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot_general(lmat * scores, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: entering state contribution + state update
    state = state_scr[...]                               # (P, N)
    decay_from_start = jnp.exp(csum)                     # (chunk,)
    y_inter = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y_intra + y_inter * decay_from_start[:, None]

    decay_to_end = jnp.exp(total - csum)                 # (chunk,)
    xw = x * decay_to_end[:, None]                       # (chunk, P)
    new_contrib = jax.lax.dot_general(xw, bm, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(total) + new_contrib

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,       # (B, S, H, P) pre-scaled inputs (x · Δt)
    da: jax.Array,      # (B, S, H)    per-step log decay (Δt · a)
    b_mat: jax.Array,   # (B, S, N)
    c_mat: jax.Array,   # (B, S, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
):
    """Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    grid = (bsz, h, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, da, b_mat, c_mat)
    return y, st
