"""Pallas TPU flash-attention kernel (GQA + causal + sliding window + logit
softcap).

TPU-native adaptation (not a CUDA port): the kernel tiles Q into
``block_q``-row VMEM blocks and streams K/V ``block_k``-column blocks from
HBM, keeping the online-softmax running statistics (m, l) and the output
accumulator in VMEM scratch across the innermost grid dimension — the TPU
grid executes sequentially minor-most-first, which substitutes for the CUDA
thread-block reduction.  Matmul tiles are MXU-shaped (block_q/block_k
multiples of 128 by default; the head dim rides the lane dimension).

Covers 8/10 assigned archs (every attention block); validated in
``interpret=True`` mode on CPU against ``ref.py`` (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, softcap: float, kv_valid: int):
    """One (b, h, iq, ik) grid step.

    q_ref: (1, 1, block_q, D); k_ref/v_ref: (1, 1, block_k, D).
    Scratch m/l: (block_q, 1) f32; acc: (block_q, D) f32 — carried over ik.
    """
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < kv_valid
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (block_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (block_q, block_k)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)   # fully-masked rows → 0, not NaN
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret", "kv_valid"),
)
def flash_attention(
    q: jax.Array,                 # (B, H, Sq, D)
    k: jax.Array,                 # (B, KVH, Sk, D)
    v: jax.Array,                 # (B, KVH, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
    kv_valid: Optional[int] = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    valid = kv_valid if kv_valid is not None else sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk

    grid = (b, h, sq_p // block_q, sk_p // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), block_q=block_q,
        block_k=block_k, causal=causal, window=window, softcap=softcap,
        kv_valid=valid)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
