"""Jitted public wrappers for the Pallas kernels.

``use_pallas`` in an ``ArchConfig`` routes the model's attention / SSD
compute through these.  On CPU (this container) the kernels execute in
``interpret=True`` mode; on real TPUs ``interpret=False`` compiles Mosaic.

The attention wrapper exposes a custom VJP whose backward pass recomputes
through the pure-jnp reference — flash-style forward memory behavior with a
numerically-identical backward (kernelizing the backward is a further perf
iteration; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .ref import attention_ref, ssd_scan_ref
from .ssd_scan import ssd_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6))
def attention(q, k, v, causal: bool = True, window: int = 0,
              softcap: float = 0.0, interpret: Optional[bool] = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, interpret=interp)


def _attn_fwd(q, k, v, causal, window, softcap, interpret):
    return attention(q, k, v, causal, window, softcap, interpret), (q, k, v)


def _attn_bwd(causal, window, softcap, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window, softcap=softcap),
        q, k, v)
    return vjp(g)


attention.defvjp(_attn_fwd, _attn_bwd)


def ssd(x, da, b_mat, c_mat, *, chunk: int = 256,
        interpret: Optional[bool] = None):
    """Chunked SSD scan: (y (B,S,H,P) f32, final_state (B,H,P,N) f32)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return ssd_scan(x, da, b_mat, c_mat, chunk=chunk, interpret=interp)
