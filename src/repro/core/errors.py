"""Exception types for the AFT shim."""

from __future__ import annotations


class AftError(Exception):
    """Base class for shim errors."""


class UnknownTransaction(AftError):
    """Operation referenced a transaction this node does not know."""


class TransactionNotRunning(AftError):
    """Operation on a transaction that already committed or aborted."""


class ReadAbortError(AftError):
    """Algorithm 1 found no valid version (§3.6): versions of the key exist
    but none can join the transaction's Atomic Readset — equivalent to
    reading from a fixed snapshot where the key is absent.  Clients abort
    and retry the whole logical request."""


class NodeFailed(AftError):
    """Injected/simulated node failure — requests to a dead node fail."""
