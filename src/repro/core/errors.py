"""Exception types for the AFT shim."""

from __future__ import annotations


class AftError(Exception):
    """Base class for shim errors."""


class UnknownTransaction(AftError):
    """Operation referenced a transaction this node does not know."""


class TransactionNotRunning(AftError):
    """Operation on a transaction that already committed or aborted."""


class ReadAbortError(AftError):
    """Algorithm 1 found no valid version (§3.6): versions of the key exist
    but none can join the transaction's Atomic Readset — equivalent to
    reading from a fixed snapshot where the key is absent.  Clients abort
    and retry the whole logical request."""


class NodeFailed(AftError):
    """Injected/simulated node failure — requests to a dead node fail."""


class ReadOnlyTransaction(AftError):
    """Write attempted inside a transaction declared ``read_only=True``.
    The read-only lane skips version writes, the commit record and the
    ``u/`` index entirely, so a buffered write could never become durable —
    raising at ``put`` time surfaces the mis-declaration immediately."""


class SnapshotUnavailable(AftError):
    """Bounded-staleness snapshot read could not be served: the gossiped
    read watermark lags behind ``now`` by more than the caller's declared
    staleness bound (e.g. the multicast plane is partitioned or a peer's
    horizon has stalled).  Callers retry, widen the bound, or fall back to
    a transactional read."""
