"""Local metadata garbage collection agent (§5.1).

Each node runs a background GC process that periodically sweeps the committed
transaction metadata cache: a transaction is dropped locally when Algorithm 2
says it is superseded **and** no currently-executing transaction on this node
has read from its write set.  Dropped transactions are remembered in the
node's locally-deleted log, which the global GC (fault manager, §5.2)
aggregates before deleting actual version bytes.

The agent also performs the §3.3.1 duty of aborting RUNNING transactions that
outlived the client timeout (their function died mid-request).
"""

from __future__ import annotations

import threading
from typing import List, Optional

from .ids import TxnId
from .node import AftNode


class LocalGcAgent:
    def __init__(self, node: AftNode):
        self.node = node
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def step(self) -> List[TxnId]:
        if not self.node.alive:
            return []
        self.node.sweep_timed_out_transactions()
        return self.node.gc_sweep_local()

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                try:
                    self.step()
                except Exception:
                    if not self.node.alive:
                        return
                self._stop.wait(self.node.config.gc_interval_s)

        self._thread = threading.Thread(
            target=loop, name=f"gc-{self.node.node_id}", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
