"""Local metadata garbage collection agent (§5.1) + finished-workflow sweep.

Each node runs a background GC process that periodically sweeps the committed
transaction metadata cache: a transaction is dropped locally when Algorithm 2
says it is superseded **and** no currently-executing transaction on this node
has read from its write set.  Dropped transactions are remembered in the
node's locally-deleted log, which the global GC (fault manager, §5.2)
aggregates before deleting actual version bytes.

The agent also performs the §3.3.1 duty of aborting RUNNING transactions that
outlived the client timeout (their function died mid-request).

Beyond the paper, the agent folds **workflow memo records** into the sweep.
The workflow layer (``repro/workflow``) persists per-step memo records under
the reserved ``.wf/<uuid>/<step>`` keys so a retried DAG resumes exactly-once
(§3.3.1 extended to steps).  Each memo key is written once, so Algorithm 2
never supersedes its transaction — without help, a long-running workflow
pool's ``.wf/`` and ``u/`` footprint grows forever.  When a workflow is
declared finished (a durable ``w/<uuid>`` marker, written by
``WorkflowPool`` / ``WorkflowExecutor``), ``gc_finished_workflows`` deletes:

* the memo version bytes (``d/.wf/<uuid>/...``),
* the commit records of *pure-memo* transactions (``TxnScope.WORKFLOW``
  memo commits, whose write set is entirely ``.wf/<uuid>/`` keys),
* the ``u/<uuid>.step.*`` / ``u/<uuid>.memo.*`` idempotence-index entries,

while purging the same transactions from this node's metadata cache.
Mixed-write-set records (``TxnScope.STEP``, where the memo rides inside the
step's transaction next to real data keys) keep their commit record — the
real keys still need their cowritten metadata — and lose only the memo bytes
and the index entry.  Unfinished workflows (no marker) are never touched, so
an in-flight retry can always find its memos.

The marker itself is NOT deleted here: every node's agent must get a chance
to purge its own metadata cache (memo commits were multicast to all of
them), and the storage keys may already be gone by the time a slower peer
looks — which is why the cache purge (``AftNode.purge_workflow_metadata``)
works from the node's local uuid → tid map, not from storage.  The fault
manager retires markers after ``workflow_marker_ttl_s`` (§5.2's global role
extended to workflow lifecycle).  See ``docs/WORKFLOWS.md``.
"""

from __future__ import annotations

import threading
from typing import List, Optional, Set

from .ids import TxnId
from .node import AftNode
from .records import (
    DATA_PREFIX,
    TransactionRecord,
    WF_FINISH_PREFIX,
    WF_MEMO_TXN_INFIX,
    WF_STEP_TXN_INFIX,
    WORKFLOW_MEMO_PREFIX,
    uuid_key,
)


class LocalGcAgent:
    def __init__(self, node: AftNode, *, workflow_gc_batch: int = 64):
        self.node = node
        # workflows reclaimed per step() — bounds the sweep's storage traffic
        self.workflow_gc_batch = workflow_gc_batch
        self.workflows_reclaimed = 0
        self.memo_keys_deleted = 0
        # markers this agent has already processed; markers persist until the
        # fault manager's TTL sweep, and re-sweeping one is wasted listings
        self._swept_markers: Set[str] = set()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def step(self) -> List[TxnId]:
        if not self.node.alive:
            return []
        self.node.sweep_timed_out_transactions()
        removed = self.node.gc_sweep_local()
        self.gc_finished_workflows()
        return removed

    # ----------------------------------------------- finished-workflow sweep
    def gc_finished_workflows(self, max_workflows: Optional[int] = None) -> int:
        """Reclaim memo state of workflows bearing a ``w/`` finish marker.

        Returns the number of workflows processed this call.  Safe to run
        concurrently on many nodes: storage deletes are idempotent, and each
        node's cache purge works from its own local view.
        """
        storage = self.node.storage
        limit = max_workflows or self.workflow_gc_batch
        markers = storage.list_keys(WF_FINISH_PREFIX)
        self._swept_markers &= set(markers)  # TTL-retired markers drop out
        if not markers:
            return 0
        # cache purge runs against EVERY live marker each pass (one local
        # scan), not just unswept ones: a memo commit can arrive via
        # multicast after this node's storage sweep already happened
        self.node.purge_workflow_metadata(
            {m[len(WF_FINISH_PREFIX):] for m in markers}
        )
        todo = [m for m in markers if m not in self._swept_markers][:limit]
        for marker in todo:
            wf_uuid = marker[len(WF_FINISH_PREFIX):]
            self.memo_keys_deleted += self._reclaim_workflow(wf_uuid)
            self._swept_markers.add(marker)
        self.workflows_reclaimed += len(todo)
        return len(todo)

    def _reclaim_workflow(self, wf_uuid: str) -> int:
        storage = self.node.storage
        namespace = f"{WORKFLOW_MEMO_PREFIX}{wf_uuid}/"
        doomed = set()
        # A workflow's derived transaction UUIDs are "<uuid>.memo.<step>" /
        # "<uuid>.step.<step>" (§3.3.1), so the ``u/`` index doubles as its
        # transaction directory.  Listing by the full infix (never the bare
        # "<uuid>." prefix) plus the write-set namespace check below keeps a
        # *different* workflow whose user-supplied UUID textually extends
        # this one (e.g. "job.1" vs "job.1.5") out of the blast radius.  The
        # workflow's own commit (``u/<wf_uuid>``) is never matched: its
        # record carries the DAG's real write set and stays until ordinary
        # supersedence GC claims it.
        for infix in (WF_MEMO_TXN_INFIX, WF_STEP_TXN_INFIX):
            for u_key in storage.list_keys(uuid_key(wf_uuid + infix)):
                ptr = storage.get(u_key)
                if ptr is None:
                    continue  # visibility lag or a racing peer; retried later
                commit_k = ptr.decode()
                raw = storage.get(commit_k)
                if raw is None:
                    continue  # crashed / in-flight commit — don't touch
                record = TransactionRecord.decode(raw)
                memo_writes = [
                    k for k in record.write_set if k.startswith(namespace)
                ]
                if not memo_writes:
                    continue  # not this workflow's transaction
                doomed.update(record.storage_key_for(k) for k in memo_writes)
                if len(memo_writes) == len(record.write_set):
                    # pure memo transaction: the commit record exists only to
                    # make the memo durable — it goes too
                    doomed.add(commit_k)
                doomed.add(u_key)
        # straggler versions under the reserved prefix (e.g. spilled memo
        # buffers from crashed attempts)
        doomed.update(storage.list_keys(f"{DATA_PREFIX}{namespace}"))
        if doomed:
            storage.delete_batch(sorted(doomed))
        return len(doomed)

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                try:
                    self.step()
                except Exception:
                    if not self.node.alive:
                        return
                self._stop.wait(self.node.config.gc_interval_s)

        self._thread = threading.Thread(
            target=loop, name=f"gc-{self.node.node_id}", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
