"""Local metadata garbage collection agent (§5.1) + finished-workflow sweep.

Each node runs a background GC process that periodically sweeps the committed
transaction metadata cache: a transaction is dropped locally when Algorithm 2
says it is superseded **and** no currently-executing transaction on this node
has read from its write set.  Dropped transactions are remembered in the
node's locally-deleted log, which the global GC (fault manager, §5.2)
aggregates before deleting actual version bytes.

The agent also performs the §3.3.1 duty of aborting RUNNING transactions that
outlived the client timeout (their function died mid-request).

Beyond the paper, the agent folds **workflow memo records** into the sweep.
The workflow layer (``repro/workflow``) persists per-step memo records under
the reserved ``.wf/<uuid>/<step>`` keys so a retried DAG resumes exactly-once
(§3.3.1 extended to steps).  Each memo key is written once, so Algorithm 2
never supersedes its transaction — without help, a long-running workflow
pool's ``.wf/`` and ``u/`` footprint grows forever.  When a workflow is
declared finished (a durable ``w/<uuid>`` marker, written by
``WorkflowPool`` / ``WorkflowExecutor``), ``gc_finished_workflows`` deletes:

* the memo version bytes (``d/.wf/<uuid>/...``),
* the commit records of *pure-memo* transactions (``TxnScope.WORKFLOW``
  memo commits, whose write set is entirely ``.wf/<uuid>/`` keys),
* the ``u/<uuid>.step.*`` / ``u/<uuid>.memo.*`` idempotence-index entries,

while purging the same transactions from this node's metadata cache.
Mixed-write-set records (``TxnScope.STEP``, where the memo rides inside the
step's transaction next to real data keys) keep their commit record — the
real keys still need their cowritten metadata — and lose only the memo bytes
and the index entry.  Unfinished workflows (no marker) are never touched, so
an in-flight retry can always find its memos.

A workflow that was chain-triggered (``repro/workflow/chain.py``) carries
its trigger-queue provenance in the marker payload; the sweep then also
reclaims the consumed ``q/`` entry, its claim versions, and the claim/
enqueue bookkeeping transactions — the queue footprint plateaus with the
memo footprint.

The marker itself is NOT deleted here: every node's agent must get a chance
to purge its own metadata cache (memo commits were multicast to all of
them), and the storage keys may already be gone by the time a slower peer
looks — which is why the cache purge (``AftNode.purge_workflow_metadata``)
works from the node's local uuid → tid map, not from storage.  After a full
pass this agent ACKS each consumed marker on its node; the fault manager
retires a marker only once it is older than ``workflow_marker_ttl_s`` AND
every live node has acked it (with ``workflow_marker_max_ttl_s`` as the
liveness backstop) — §5.2's global role extended to workflow lifecycle.
See ``docs/WORKFLOWS.md``.
"""

from __future__ import annotations

import json
import logging
import threading
from typing import List, Optional, Set

from .ids import TxnId
from .node import AftNode
from .records import (
    DATA_PREFIX,
    TRIGGER_PREFIX,
    TransactionRecord,
    WF_CHAIN_INFIX,
    WF_FINISH_PREFIX,
    WF_MEMO_TXN_INFIX,
    WF_STEP_TXN_INFIX,
    WORKFLOW_MEMO_PREFIX,
    claim_txn_uuid,
    commit_key,
    enqueue_txn_uuid,
    lookup_committed_record,
    trigger_key,
    uuid_key,
)

log = logging.getLogger("repro.gc")


class LocalGcAgent:
    def __init__(self, node: AftNode, *, workflow_gc_batch: int = 64):
        self.node = node
        # workflows reclaimed per step() — bounds the sweep's storage traffic
        self.workflow_gc_batch = workflow_gc_batch
        self.workflows_reclaimed = 0
        self.memo_keys_deleted = 0
        # keys whose delete flush failed and were therefore left in storage
        # for a later pass — reported, never silently dropped
        self.gc_skipped_keys = 0
        self._c_skipped = node.registry.counter("gc_skipped_keys")
        # deletes enqueued on the node's storage I/O pipeline this pass
        # (coalesced into shared delete_batch flushes off this thread, so
        # the sweep's round trips never serialize with foreground commits);
        # drained before gc_finished_workflows returns
        self._delete_futures: List = []
        # markers this agent has already processed; markers persist until the
        # fault manager's TTL sweep, and re-sweeping one is wasted listings
        self._swept_markers: Set[str] = set()
        # per-marker chain-provenance resolution cache (incl. negative
        # results) so a provenance-less (quarantined) chain marker does not
        # rescan the whole q/ version space on every pass of its lifetime
        self._chain_probe: dict = {}
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def step(self) -> List[TxnId]:
        if not self.node.alive:
            return []
        self.node.sweep_timed_out_transactions()
        removed = self.node.gc_sweep_local()
        self.gc_finished_workflows()
        return removed

    # ----------------------------------------------- finished-workflow sweep
    def gc_finished_workflows(self, max_workflows: Optional[int] = None) -> int:
        """Reclaim memo state of workflows bearing a ``w/`` finish marker.

        Returns the number of workflows processed this call.  Safe to run
        concurrently on many nodes: storage deletes are idempotent, and each
        node's cache purge works from its own local view.  Storage deletes
        flow through the node's I/O pipeline (coalesced ``delete_batch``
        flushes on the pipeline's workers) and are drained before this call
        returns, so callers still observe a settled store.
        """
        storage = self.node.storage
        limit = max_workflows or self.workflow_gc_batch
        markers = storage.list_keys(WF_FINISH_PREFIX)
        self._swept_markers &= set(markers)  # retired markers drop out
        live_uuids = {m[len(WF_FINISH_PREFIX):] for m in markers}
        self.node.retain_marker_acks(live_uuids)
        if not markers:
            return 0
        # cache purge runs against EVERY live marker each pass (one local
        # scan), not just unswept ones: a memo commit can arrive via
        # multicast after this node's storage sweep already happened
        self.node.purge_workflow_metadata(live_uuids)
        todo = [m for m in markers if m not in self._swept_markers][:limit]
        for marker in todo:
            wf_uuid = marker[len(WF_FINISH_PREFIX):]
            self.memo_keys_deleted += self._reclaim_workflow(wf_uuid)
            self._swept_markers.add(marker)
        # Chain reclamation runs for EVERY live chain marker each pass, not
        # just unswept ones: a consumer's claim can commit concurrently with
        # the first sweep (its list snapshot predating the claim), and a
        # one-shot sweep would leak that claim's versions + bookkeeping
        # forever.  Re-sweeping is idempotent and cheap once empty.
        self._chain_probe = {
            m: v for m, v in self._chain_probe.items() if m in live_uuids
        }
        for marker in markers:
            if marker not in self._swept_markers:
                continue  # its turn comes in a later batch
            wf_uuid = marker[len(WF_FINISH_PREFIX):]
            if wf_uuid in self._chain_probe:
                chain = self._chain_probe[wf_uuid]
            else:
                # the {queue, entry} provenance normally rides the marker
                # payload; a quarantined (bit-rotted) marker lost it, so
                # fall back to locating the entry by the child uuid it IS.
                # Either way the resolution (incl. "not a chain child") is
                # cached for the marker's remaining lifetime.
                chain = self._marker_chain_info(storage.get(marker))
                if chain is None and WF_CHAIN_INFIX in wf_uuid:
                    chain = self._find_entry_for_child(wf_uuid)
                self._chain_probe[wf_uuid] = chain
            if chain is not None:
                self.memo_keys_deleted += self._reclaim_chain_entry(
                    chain["queue"], chain["entry"]
                )
        # settle the pipelined deletes BEFORE acking: an ack is the promise
        # that this node's sweep is durably done, and the fault manager may
        # retire the marker the moment the last ack lands.  If ANY delete
        # flush failed, un-sweep this pass's markers and withhold every ack
        # — acking anyway would let the marker retire with doomed keys
        # still in storage, orphaning them forever (deletes are idempotent,
        # so the next pass simply redoes the sweep).
        skipped = self._drain_deletes()
        if skipped:
            self.gc_skipped_keys += skipped
            self._c_skipped.inc(skipped)
            log.warning(
                "gc sweep on %s: delete flush failed, %d key(s) left in "
                "storage; un-sweeping %d marker(s) for retry next pass",
                self.node.node_id, skipped, len(todo),
            )
            self._swept_markers -= set(todo)
            return 0
        # ack AFTER the storage sweep + cache purge: the fault manager
        # retires a marker only once every live node has acked it, closing
        # the retire-before-sweep race that orphaned memo records
        for marker in markers:
            if marker in self._swept_markers:
                self.node.ack_workflow_marker(marker[len(WF_FINISH_PREFIX):])
        self.workflows_reclaimed += len(todo)
        return len(todo)

    # -------------------------------------------------- pipelined deletes
    def _delete_keys(self, keys) -> None:
        """Route a sweep's doomed keys through the node's I/O pipeline when
        one already exists (coalesced, off-thread); falls back to a direct
        ``delete_batch`` otherwise.  The sweep never CREATES the pipeline:
        a purely synchronous deployment keeps its exact pre-pipeline
        storage traffic (prefetching activates with the pipeline)."""
        if not keys:
            return
        pipeline = self.node.io_pipeline(create=False)
        if pipeline is None:
            self.node.storage.delete_batch(keys)
            return
        self._delete_futures.append((pipeline.submit_deletes(keys),
                                     len(keys)))

    def _drain_deletes(self) -> int:
        """Wait out this pass's delete flushes; returns the number of keys
        whose flush failed (0 ⇔ everything landed)."""
        futures, self._delete_futures = self._delete_futures, []
        skipped = 0
        for fut, nkeys in futures:
            try:
                fut.result()
            except Exception:
                skipped += nkeys  # idempotent; caller re-sweeps next pass
        return skipped

    def _find_entry_for_child(self, wf_uuid: str) -> Optional[dict]:
        """Locate a finished chain child's queue entry without marker
        provenance: the entry id IS the child uuid, so one listing of the
        ``q/`` version space recovers {queue, entry}.  Queue and entry ids
        are validated slash-free, so the match is unambiguous."""
        prefix = f"{DATA_PREFIX}{TRIGGER_PREFIX}"
        needle = f"/{wf_uuid}/"
        for skey in self.node.storage.list_keys(prefix):
            queue, sep, _ = skey[len(prefix):].partition(needle)
            if sep and "/" not in queue:
                return {"queue": queue, "entry": wf_uuid}
        return None

    @staticmethod
    def _marker_chain_info(raw: Optional[bytes]) -> Optional[dict]:
        if raw is None:
            return None
        try:
            chain = json.loads(raw).get("chain")
        except Exception:
            return None  # quarantined/unparsable marker: memo sweep only
        if (
            isinstance(chain, dict)
            and isinstance(chain.get("queue"), str)
            and isinstance(chain.get("entry"), str)
        ):
            return chain
        return None

    def _reclaim_chain_entry(self, queue: str, entry_id: str) -> int:
        """Reclaim a consumed trigger-queue entry (chaining, workflow/chain.py).

        Deletes every version under the entry's logical prefix — the entry
        itself, its ``/claim``, stray spills — plus the claim/enqueue
        bookkeeping transactions' commit records and ``u/`` entries (their
        write sets live entirely under ``q/``, so like pure-memo commits
        they exist only to make the handoff durable).  A WORKFLOW-scope
        parent's commit record is untouched: it carries the DAG's real
        write set; only the entry's version bytes go (the STEP-scope memo
        rule applied to queue entries)."""
        storage = self.node.storage
        doomed = set(
            storage.list_keys(f"{DATA_PREFIX}{trigger_key(queue, entry_id)}/")
        )
        for uuid in (claim_txn_uuid(entry_id), enqueue_txn_uuid(entry_id)):
            record = lookup_committed_record(storage, uuid)
            if record is None:
                continue
            if record.write_set and all(
                k.startswith(TRIGGER_PREFIX) for k in record.write_set
            ):
                doomed.add(commit_key(record.tid))
                doomed.add(uuid_key(uuid))
        self._delete_keys(sorted(doomed))
        return len(doomed)

    def _reclaim_workflow(self, wf_uuid: str) -> int:
        storage = self.node.storage
        namespace = f"{WORKFLOW_MEMO_PREFIX}{wf_uuid}/"
        doomed = set()
        # A workflow's derived transaction UUIDs are "<uuid>.memo.<step>" /
        # "<uuid>.step.<step>" (§3.3.1), so the ``u/`` index doubles as its
        # transaction directory.  Listing by the full infix (never the bare
        # "<uuid>." prefix) plus the write-set namespace check below keeps a
        # *different* workflow whose user-supplied UUID textually extends
        # this one (e.g. "job.1" vs "job.1.5") out of the blast radius.  The
        # workflow's own commit (``u/<wf_uuid>``) is never matched: its
        # record carries the DAG's real write set and stays until ordinary
        # supersedence GC claims it.
        for infix in (WF_MEMO_TXN_INFIX, WF_STEP_TXN_INFIX):
            for u_key in storage.list_keys(uuid_key(wf_uuid + infix)):
                ptr = storage.get(u_key)
                if ptr is None:
                    continue  # visibility lag or a racing peer; retried later
                commit_k = ptr.decode()
                raw = storage.get(commit_k)
                if raw is None:
                    continue  # crashed / in-flight commit — don't touch
                record = TransactionRecord.decode(raw)
                memo_writes = [
                    k for k in record.write_set if k.startswith(namespace)
                ]
                if not memo_writes:
                    continue  # not this workflow's transaction
                doomed.update(record.storage_key_for(k) for k in memo_writes)
                if len(memo_writes) == len(record.write_set):
                    # pure memo transaction: the commit record exists only to
                    # make the memo durable — it goes too
                    doomed.add(commit_k)
                doomed.add(u_key)
        # straggler versions under the reserved prefix (e.g. spilled memo
        # buffers from crashed attempts)
        doomed.update(storage.list_keys(f"{DATA_PREFIX}{namespace}"))
        self._delete_keys(sorted(doomed))
        return len(doomed)

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                try:
                    self.step()
                except Exception:
                    if not self.node.alive:
                        return
                self._stop.wait(self.node.config.gc_interval_s)

        self._thread = threading.Thread(
            target=loop, name=f"gc-{self.node.node_id}", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
