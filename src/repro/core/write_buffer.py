"""Atomic Write Buffer (§3.3).

Sequesters every update of an in-flight transaction; nothing reaches the
storage engine's *visible* namespace until commit.  When the buffer saturates
(large update sets — e.g. a trillion-parameter checkpoint commit), it
proactively spills intermediary data to uuid-derived storage keys; the
write-ordering protocol guarantees spilled bytes stay invisible until the
commit record is persisted, and orphaned spills (transaction never committed)
are swept by the fault manager's orphan GC (§5).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from ..storage.base import StorageEngine
from .ids import TxnId
from .records import data_key, spill_key


@dataclass
class _PendingWrite:
    value: Optional[bytes]          # None ⇒ spilled to storage
    storage_key: Optional[str] = None  # set iff spilled


class TransactionWriteBuffer:
    """Per-transaction buffered write set with saturation spill."""

    def __init__(
        self,
        uuid: str,
        storage: StorageEngine,
        max_bytes: int = 256 * 1024 * 1024,
    ) -> None:
        self.uuid = uuid
        self.storage = storage
        self.max_bytes = max_bytes
        self._writes: Dict[str, _PendingWrite] = {}
        self._bytes = 0
        self._spill_seq = 0
        self._spilled_keys: List[str] = []
        self._lock = threading.Lock()

    # -- API used by AftNode -------------------------------------------------
    def put(self, key: str, value: bytes) -> None:
        with self._lock:
            prev = self._writes.get(key)
            if prev is not None and prev.value is not None:
                self._bytes -= len(prev.value)
            self._writes[key] = _PendingWrite(value=value)
            self._bytes += len(value)
            if self._bytes > self.max_bytes:
                self._spill_locked()

    def get(self, key: str) -> Tuple[bool, Optional[bytes]]:
        """Read-your-writes lookup (§3.5): returns (hit, value)."""
        with self._lock:
            pending = self._writes.get(key)
            if pending is None:
                return False, None
            if pending.value is not None:
                return True, pending.value
        # spilled: fetch back from storage outside the lock
        assert pending.storage_key is not None
        value = self.storage.get(pending.storage_key)
        if value is None:
            raise RuntimeError(
                f"spilled write {pending.storage_key!r} missing from storage; "
                "engine violated durability contract"
            )
        return True, value

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._writes.keys())

    def __len__(self) -> int:
        with self._lock:
            return len(self._writes)

    @property
    def buffered_bytes(self) -> int:
        return self._bytes

    @property
    def spilled_storage_keys(self) -> List[str]:
        with self._lock:
            return list(self._spilled_keys)

    # -- spill ---------------------------------------------------------------
    def _spill_locked(self) -> None:
        """Write all currently-buffered values to storage at spill keys."""
        batch: Dict[str, bytes] = {}
        for key, pending in self._writes.items():
            if pending.value is None:
                continue
            skey = spill_key(key, self.uuid, self._spill_seq)
            self._spill_seq += 1
            batch[skey] = pending.value
            self._writes[key] = _PendingWrite(value=None, storage_key=skey)
            self._spilled_keys.append(skey)
        self._bytes = 0
        if batch:
            self.storage.put_batch(batch)

    def spill(self) -> None:
        with self._lock:
            self._spill_locked()

    # -- commit support -------------------------------------------------------
    def finalize(self, tid: TxnId) -> Tuple[Dict[str, bytes], Dict[str, str]]:
        """Resolve the buffer into (fresh writes to persist, key → storage key).

        Buffered values are destined for canonical ``d/<key>/<tid>`` keys;
        spilled values stay where they are and the commit record's storage-key
        map points at them (§3.3: the record, not key naming, is the source of
        truth for locating version bytes).
        """
        with self._lock:
            to_write: Dict[str, bytes] = {}
            storage_keys: Dict[str, str] = {}
            for key, pending in self._writes.items():
                if pending.value is not None:
                    skey = data_key(key, tid)
                    to_write[skey] = pending.value
                    storage_keys[key] = skey
                else:
                    assert pending.storage_key is not None
                    storage_keys[key] = pending.storage_key
            return to_write, storage_keys

    def discard(self) -> List[str]:
        """Abort (§3.3): drop buffered updates; report spilled keys so the
        caller can delete them from storage (nothing was ever visible)."""
        with self._lock:
            spilled = list(self._spilled_keys)
            self._writes.clear()
            self._spilled_keys.clear()
            self._bytes = 0
            return spilled
