"""Cluster deployment harness (§4.3, §6.7).

Plays the role Kubernetes plays in the paper: membership, a pluggable
request router (``core/routing.py`` — stateless round-robin by default,
exactly the paper's §6 load balancer), a standby-node pool for fast
replacement, and the wiring between nodes, the multicast bus, local GC
agents, and the fault manager.  Membership is an explicit lifecycle
(:class:`NodeLifecycle`: JOINING → LIVE → DRAINING → RETIRED) driven by
``join_node``/``drain_node``/``advance_lifecycle``; autoscaling policy
(§4.3 leaves it out of scope) is the :class:`~repro.core.fault_manager.
Autoscaler`, a beyond-paper extension watching the obs metrics view.

``AftClient`` is the application-facing handle: a logical request (possibly
spanning many FaaS functions / trainer hosts) opens a session pinned to one
AFT node (§3.1: "each transaction sends all operations to a single AFT node")
and drives the Table-1 API through it.  ``start_transaction`` accepts an
optional :class:`PlacementHint` (declared read set / workflow uuid) that
locality-aware routers use to place the session near cached data.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Union

from ..storage.base import StorageEngine
from .errors import NodeFailed
from .fault_manager import FaultManager, FaultManagerConfig
from .gc import LocalGcAgent
from .ids import TxnId
from .multicast import MulticastAgent, MulticastBus
from .node import AftNode, AftNodeConfig
from .routing import PlacementHint, Router, make_router


class NodeLifecycle(Enum):
    """Explicit membership lifecycle (elastic cluster).

    ``JOINING``  — wired into the bus/ring at a ramping arc weight; warm-up
                   handoff streams the inherited arcs' commit-set metadata
                   from the prior owners before the weight reaches 1.0;
    ``LIVE``     — full ring weight, full GC-ack responsibilities;
    ``DRAINING`` — ring weight 0 (no *new* sessions), finishing in-flight
                   sessions; still a bus/watermark peer so its commits keep
                   announcing; excluded from the GC marker-ack quorum (its
                   agent is on the way out and must not stall retirement);
    ``RETIRED``  — out of membership: bus inbox unregistered, ring arcs
                   redistributed, peers' watermark floors no longer wait on
                   it, marker acks no longer require it.
    """

    JOINING = "joining"
    LIVE = "live"
    DRAINING = "draining"
    RETIRED = "retired"


@dataclass
class ClusterConfig:
    num_nodes: int = 1
    standby_nodes: int = 0
    node: AftNodeConfig = field(default_factory=AftNodeConfig)
    fault_manager: FaultManagerConfig = field(default_factory=FaultManagerConfig)
    # §6.7: replacement nodes pay a cold-start (container download + metadata
    # cache warm-up).  Simulated; scaled by the storage time_scale in benches.
    replacement_delay_s: float = 0.0
    start_background_threads: bool = True
    # placement policy: a core/routing.py policy name ("round_robin",
    # "consistent_hash", "cache_aware") or a Router instance; None keeps the
    # paper's stateless round-robin LB, decision-for-decision.
    routing: Union[str, Router, None] = None
    # --- elastic membership (join/drain lifecycle) ----------------------
    # a JOINING node enters the ring at this arc weight and ramps by
    # join_ramp_step per advance_lifecycle() tick until it reaches 1.0
    # (→ LIVE); ring policies without weights go LIVE on the first tick
    join_initial_weight: float = 0.25
    join_ramp_step: float = 0.25
    # stream the inherited arcs' commit-set records + uuid→tid metadata
    # from the prior owners before a joiner takes traffic
    warmup_handoff: bool = True
    # cap per-donor handoff volume (records)
    warmup_handoff_limit: int = 10_000
    # blocking drain (scale_to shrink / drain_node(wait=True)): how long to
    # wait for in-flight sessions before retiring anyway (the session
    # holders then fall back to the §3.3.1 retry machinery)
    drain_timeout_s: float = 10.0
    # commit-time per-record fan-out (§4 eager push).  Off = announcements
    # ride the periodic batched multicast round only — same guarantees,
    # higher metadata latency, O(1) instead of O(peers) work per commit
    # (the knob large elastic clusters turn when commit rate × peer count
    # outgrows the announcement budget)
    multicast_eager_push: bool = True


class AftCluster:
    def __init__(self, storage: StorageEngine, config: Optional[ClusterConfig] = None):
        self.storage = storage
        self.config = config or ClusterConfig()
        self.bus = MulticastBus()
        self.nodes: List[AftNode] = []
        self.agents: Dict[str, MulticastAgent] = {}
        self.gc_agents: Dict[str, LocalGcAgent] = {}
        self.standbys: List[AftNode] = []
        self.router = make_router(self.config.routing)
        self._node_seq = 0
        self._lock = threading.RLock()
        # explicit membership lifecycle (elastic cluster): node_id → state
        self.lifecycle: Dict[str, NodeLifecycle] = {}
        # (event, node) callbacks fired on lifecycle transitions — the hook
        # the gossip planes (core/gossip.py Digest/MetricsPlane) use to
        # register/unregister peers in step with ring updates
        self._membership_listeners: List[
            Callable[[str, AftNode], None]] = []
        self.fault_manager = FaultManager(
            storage,
            self.bus,
            membership=self.all_nodes,  # incl. dead: heartbeat detection
            config=self.config.fault_manager,
            on_node_failure=self._replace_node,
            # GC marker-ack quorum: LIVE/JOINING members only — DRAINING
            # and RETIRED nodes must never stall marker retirement
            ack_membership=self.gc_ack_nodes,
        )
        for _ in range(self.config.num_nodes):
            self._add_node()
        for _ in range(self.config.standby_nodes):
            self.standbys.append(self._make_node(bootstrap=False))
        if self.config.start_background_threads:
            self.start()

    # ------------------------------------------------------------- topology
    def _make_node(self, bootstrap: bool = True) -> AftNode:
        with self._lock:
            node_id = f"aft-{self._node_seq}"
            self._node_seq += 1
        cfg = AftNodeConfig(**{**self.config.node.__dict__, "node_id": node_id})
        return AftNode(self.storage, cfg, bootstrap=bootstrap)

    def _wire_node(
        self,
        node: AftNode,
        lifecycle: NodeLifecycle = NodeLifecycle.LIVE,
        weight: float = 1.0,
    ) -> None:
        """Membership admission: bus inbox (via the agent constructor), GC
        agent, membership list, lifecycle state, and ring arcs change
        together — the inbox exists *before* the ring update can route a
        session to the node, so an eager push can never hit a missing
        queue."""
        agent = MulticastAgent(
            node, self.bus, peers=self.live_node_ids,
            eager_push=self.config.multicast_eager_push,
        )
        gc_agent = LocalGcAgent(node)
        with self._lock:
            self.nodes.append(node)
            self.agents[node.node_id] = agent
            self.gc_agents[node.node_id] = gc_agent
            self.lifecycle[node.node_id] = lifecycle
        if weight != 1.0 or self.router.weight_of(node.node_id) != 1.0:
            self.router.set_weight(node.node_id, weight)
        self._sync_router()
        if self.config.start_background_threads:
            agent.start()
            gc_agent.start()
        self._notify("join" if lifecycle is NodeLifecycle.JOINING else "live",
                     node)

    def _add_node(self) -> AftNode:
        node = self._make_node()
        self._wire_node(node)
        return node

    def _replace_node(self, dead: AftNode) -> None:
        """§6.7 recovery path: detach the dead node, promote a standby (or
        cold-start a new one), warm its metadata cache, join the cluster."""
        with self._lock:
            if dead in self.nodes:
                self.nodes.remove(dead)
            agent = self.agents.pop(dead.node_id, None)
            gc_agent = self.gc_agents.pop(dead.node_id, None)
            standby = self.standbys.pop(0) if self.standbys else None
            self.lifecycle[dead.node_id] = NodeLifecycle.RETIRED
        # resync BEFORE the replacement delay: during the cold-start window
        # the router must already have forgotten the dead node's ring arc
        self.router.forget_node(dead.node_id)
        self._sync_router()
        if agent is not None:
            agent.stop()
        if gc_agent is not None:
            gc_agent.stop()
        self._forget_peer_everywhere(dead.node_id)
        self._notify("retired", dead)
        if self.config.replacement_delay_s > 0:
            time.sleep(self.config.replacement_delay_s)  # container download
        node = standby if standby is not None else self._make_node(bootstrap=False)
        node.bootstrap()  # metadata cache warm-up from the Commit Set (§3.1)
        self._wire_node(node)

    # ------------------------------------------------------------ membership
    def all_nodes(self) -> List[AftNode]:
        with self._lock:
            return list(self.nodes)

    def live_nodes(self) -> List[AftNode]:
        with self._lock:
            return [n for n in self.nodes if n.alive]

    def live_node_ids(self) -> List[str]:
        return [n.node_id for n in self.live_nodes()]

    def routable_nodes(self) -> List[AftNode]:
        """Live nodes eligible for NEW sessions: DRAINING members keep
        serving their in-flight sessions (and stay bus/watermark peers) but
        take no new placements, under every routing policy."""
        with self._lock:
            out = [
                n for n in self.nodes
                if n.alive
                and self.lifecycle.get(n.node_id) is not NodeLifecycle.DRAINING
            ]
        return out or self.live_nodes()  # all-draining: serve rather than fail

    def gc_ack_nodes(self) -> List[AftNode]:
        """The GC marker-ack quorum (``FaultManager.sweep_finished_markers``):
        LIVE and JOINING members only.  A DRAINING node's GC agent is on the
        way out and a RETIRED/dead one is gone — requiring their acks would
        stall marker retirement forever (the historical scale-down bug)."""
        with self._lock:
            return [
                n for n in self.nodes
                if n.alive
                and self.lifecycle.get(n.node_id)
                in (NodeLifecycle.LIVE, NodeLifecycle.JOINING)
            ]

    def lifecycle_of(self, node: AftNode) -> NodeLifecycle:
        with self._lock:
            return self.lifecycle.get(node.node_id, NodeLifecycle.RETIRED)

    # -- membership listeners (gossip planes, tests) ------------------------
    def add_membership_listener(
        self, fn: Callable[[str, AftNode], None]
    ) -> None:
        """``fn(event, node)`` fires on lifecycle transitions: ``join``,
        ``live``, ``draining``, ``retired``.  Fired after the cluster's own
        state (ring, bus, agents) reflects the transition, so a listener
        registering metrics-plane peers sees a consistent view."""
        with self._lock:
            self._membership_listeners.append(fn)

    def _notify(self, event: str, node: AftNode) -> None:
        with self._lock:
            listeners = list(self._membership_listeners)
        for fn in listeners:
            try:
                fn(event, node)
            except Exception:
                pass  # listeners are observers, never correctness hooks

    # -------------------------------------------- elastic lifecycle: join
    def join_node(self, *, ramp: bool = True) -> AftNode:
        """Grow the cluster by one node through the explicit lifecycle:
        wire bus + ring (JOINING, low arc weight), stream warm-up handoff
        from the prior arc owners, then ramp to LIVE.  With ``ramp=True``
        the weight ramp advances on :meth:`advance_lifecycle` ticks (the
        autoscaler's loop or ``step_all``); ``ramp=False`` joins at full
        weight immediately (still warmed up) — the fast path ``scale_to``
        uses."""
        node = self._make_node(bootstrap=False)
        weight = self.config.join_initial_weight if ramp else 1.0
        state = NodeLifecycle.JOINING if ramp else NodeLifecycle.LIVE
        self._wire_node(node, lifecycle=state, weight=weight)
        if self.config.warmup_handoff:
            self._warmup_handoff(node)
        return node

    def _warmup_handoff(self, joiner: AftNode) -> int:
        """Stream commit-set records (and thereby uuid → tid idempotence
        metadata) for the joiner's inherited arcs from every prior owner.
        With a ring policy the transferred range is exact (ring ownership
        under the *new* ring); weightless policies stream the donors' recent
        records wholesale, bounded by the handoff limit."""
        owner_id = getattr(self.router, "owner_id", None)
        if owner_id is not None:
            def owned(key: str) -> bool:
                return owner_id(key) == joiner.node_id
        else:
            def owned(key: str) -> bool:
                return True
        moved = 0
        for donor in self.live_nodes():
            if donor.node_id == joiner.node_id or not donor.alive:
                continue
            try:
                records = donor.handoff_records(
                    owned, limit=self.config.warmup_handoff_limit
                )
                if records:
                    joiner.warmup_from(records)
                    moved += len(records)
            except NodeFailed:
                continue  # donor died mid-handoff; anti-entropy heals (§4.2)
        return moved

    # ------------------------------------------- elastic lifecycle: drain
    def drain_node(self, node: AftNode, *, wait: bool = False,
                   timeout_s: Optional[float] = None) -> None:
        """Graceful scale-down: mark DRAINING (ring weight → 0, so no new
        sessions), let in-flight sessions finish, then retire.  With
        ``wait=False`` retirement happens on :meth:`advance_lifecycle`
        ticks; ``wait=True`` blocks until the node is idle (or
        ``timeout_s``), then retires — in-flight sessions surviving the
        timeout fall back to the §3.3.1 retry machinery.  This path NEVER
        reuses :meth:`kill_node`: the node stays alive, its commits keep
        announcing, and its pipeline flushes before detach."""
        with self._lock:
            if self.lifecycle.get(node.node_id) in (
                NodeLifecycle.RETIRED, NodeLifecycle.DRAINING
            ):
                if not wait:
                    return
            else:
                self.lifecycle[node.node_id] = NodeLifecycle.DRAINING
        self.router.set_weight(node.node_id, 0.0)
        self._sync_router()
        self._notify("draining", node)
        if not wait:
            return
        deadline = time.monotonic() + (
            self.config.drain_timeout_s if timeout_s is None else timeout_s
        )
        while (node.alive and node.active_transaction_count() > 0
               and time.monotonic() < deadline):
            time.sleep(0.002)
        self._retire_node(node)

    def _retire_node(self, node: AftNode) -> None:
        """Final membership exit, atomic with the ring update: the node
        leaves ``self.nodes`` (so watermark floors and GC marker acks stop
        considering it), its bus inbox unregisters, peers drop its gossip
        state, and its pipeline flushes shut."""
        with self._lock:
            if self.lifecycle.get(node.node_id) is NodeLifecycle.RETIRED:
                return
            if node in self.nodes:
                self.nodes.remove(node)
            agent = self.agents.pop(node.node_id, None)
            gc_agent = self.gc_agents.pop(node.node_id, None)
            self.lifecycle[node.node_id] = NodeLifecycle.RETIRED
        self.router.forget_node(node.node_id)
        self._sync_router()
        if agent is not None:
            if node.alive:
                agent.step()  # final flush: fresh commits reach peers + FM
            agent.stop()      # unregisters the bus inbox
        if gc_agent is not None:
            gc_agent.stop()
        self._forget_peer_everywhere(node.node_id)
        node.close_pipeline()  # graceful leave: flush + stop I/O threads
        self._notify("retired", node)

    def _forget_peer_everywhere(self, node_id: str) -> None:
        for peer_agent in list(self.agents.values()):
            peer_agent.forget_peer(node_id)

    def advance_lifecycle(self) -> None:
        """One lifecycle tick: ramp JOINING weights toward LIVE, retire
        idle DRAINING nodes.  Driven by ``step_all`` (tests), the
        autoscaler loop, or any caller pacing its own migrations."""
        with self._lock:
            entries = [
                (n, self.lifecycle.get(n.node_id)) for n in self.nodes
            ]
        for node, state in entries:
            if state is NodeLifecycle.JOINING:
                if not node.alive:
                    continue  # heartbeat path owns dead nodes
                w = self.router.weight_of(node.node_id)
                w = min(1.0, w + self.config.join_ramp_step)
                self.router.set_weight(node.node_id, w)
                self._sync_router()
                if w >= 1.0:
                    with self._lock:
                        self.lifecycle[node.node_id] = NodeLifecycle.LIVE
                    self._notify("live", node)
            elif state is NodeLifecycle.DRAINING:
                if not node.alive or node.active_transaction_count() == 0:
                    self._retire_node(node)

    def scale_to(self, n: int) -> None:
        """Elastically add/remove nodes (coordination-free: §4.3).  Growth
        joins warmed-up full-weight nodes; shrink always DRAINS — graceful
        retirement never reuses the kill path."""
        while len(self.live_nodes()) < n:
            self.join_node(ramp=False)
        while len(self.live_nodes()) > n:
            node = self.live_nodes()[-1]
            self.drain_node(node, wait=True)

    def remove_node(self, node: AftNode) -> None:
        """Immediate graceful removal (drain with no grace period) — kept
        for callers that know the node is idle; prefer :meth:`drain_node`."""
        self.drain_node(node, wait=True, timeout_s=0.0)

    def kill_node(self, index: int = 0) -> AftNode:
        """Failure injection (§6.7): hard-kill a live node.  Its agents are
        detached immediately — in particular the multicast inbox is
        unregistered, or peers' eager pushes would accumulate in a queue
        nobody will ever drain (the node stays in ``self.nodes`` so
        heartbeat detection still sees the corpse).  This is the CRASH
        path; graceful scale-down goes through :meth:`drain_node`."""
        with self._lock:
            node = self.live_nodes()[index]
            node.fail()
            agent = self.agents.pop(node.node_id, None)
            gc_agent = self.gc_agents.pop(node.node_id, None)
        self._sync_router()
        if agent is not None:
            agent.stop()  # unregisters the bus inbox
        if gc_agent is not None:
            gc_agent.stop()
        return node

    # ---------------------------------------------------------- load balance
    def _sync_router(self) -> None:
        """Membership changed (add/remove/kill/replace): rebuild routing
        state (the hash ring) from the current live set."""
        self.router.sync(self.live_nodes())

    def pick_node(self, hint: Optional[PlacementHint] = None) -> AftNode:
        """Route a new session through the configured placement policy
        (``core/routing.py``; default is the paper's §6 stateless
        round-robin LB).  Never returns a node already known dead: the
        live-list snapshot is re-validated after the policy chooses,
        closing the ``kill_node`` → ``_replace_node`` race window.
        DRAINING nodes are excluded from the candidate set (they finish
        their in-flight sessions but take no new ones)."""
        for _ in range(4):
            nodes = self.routable_nodes()
            if not nodes:
                raise NodeFailed("no live AFT nodes")
            node = self.router.route(nodes, hint)
            if node.alive:
                return node
            self._sync_router()  # raced a death the policy hadn't seen
        raise NodeFailed("routing kept selecting dead nodes")

    def client(self) -> "AftClient":
        return AftClient(self)

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        for agent in list(self.agents.values()):
            agent.start()
        for gc_agent in list(self.gc_agents.values()):
            gc_agent.start()
        self.fault_manager.start()

    def stop(self) -> None:
        self.fault_manager.stop()
        for agent in list(self.agents.values()):
            agent.stop()
        for gc_agent in list(self.gc_agents.values()):
            gc_agent.stop()
        for node in self.all_nodes():
            node.close_pipeline()

    # deterministic single-step for tests -----------------------------------
    def step_all(self) -> None:
        for agent in list(self.agents.values()):
            agent.step()
        for agent in list(self.agents.values()):
            agent.step()  # second pass delivers what the first pass sent
        for gc_agent in list(self.gc_agents.values()):
            gc_agent.step()
        self.fault_manager.step()
        self.advance_lifecycle()  # ramp JOINING, retire idle DRAINING

    def __enter__(self) -> "AftCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class AftClient:
    """Application-facing session API; one transaction ↔ one AFT node."""

    def __init__(self, cluster: AftCluster):
        self.cluster = cluster
        self._sessions: Dict[str, AftNode] = {}
        self._session_history: Dict[str, AftNode] = {}
        self._lock = threading.Lock()

    # -- Table 1 --------------------------------------------------------------
    def start_transaction(
        self,
        uuid: Optional[str] = None,
        *,
        hint: Optional[PlacementHint] = None,
        fresh: bool = False,
        read_only: bool = False,
    ) -> str:
        node: Optional[AftNode] = None
        if uuid is not None:
            # §3.3.1: a retry continues the transaction — stick to the node
            # that owns the session if it is still alive, so local
            # idempotence metadata is found without a storage scan.
            with self._lock:
                prior = self._session_history.get(uuid)
            if prior is not None and prior.alive:
                node = prior
        if node is None:
            if hint is None and uuid is not None:
                # a bare retried uuid is still a placement identity: hash-
                # keyed routers send it back to the node that served the
                # original even when this client never saw it
                hint = PlacementHint(uuid=uuid)
            node = self.cluster.pick_node(hint)
        txid = node.start_transaction(uuid, fresh=fresh,
                                      read_only=read_only)
        with self._lock:
            self._sessions[txid] = node
            self._session_history[txid] = node
        return txid

    def _node(self, txid: str) -> AftNode:
        with self._lock:
            node = self._sessions.get(txid)
        if node is None:
            raise NodeFailed(f"no session for {txid}")
        return node

    def get(self, txid: str, key: str) -> Optional[bytes]:
        return self._node(txid).get(txid, key)

    def put(self, txid: str, key: str, value: bytes) -> None:
        self._node(txid).put(txid, key, value)

    def commit_transaction(self, txid: str) -> TxnId:
        node = self._node(txid)
        tid = node.commit_transaction(txid)
        node.release_transaction(txid)
        with self._lock:
            self._sessions.pop(txid, None)
        return tid

    def commit_transaction_async(self, txid: str):
        """Commit through the node's storage I/O pipeline; returns a
        ``Future[TxnId]`` that resolves when the commit record is durable.
        The session is released on success (a failed commit keeps it, like
        the sync path's raise, so the caller can abort or retry)."""
        node = self._node(txid)
        fut = node.commit_transaction_async(txid)

        def _release(f) -> None:
            if f.exception() is None:
                node.release_transaction(txid)
                with self._lock:
                    self._sessions.pop(txid, None)

        fut.add_done_callback(_release)
        return fut

    def abort_transaction(self, txid: str) -> None:
        node = self._node(txid)
        node.abort_transaction(txid)
        node.release_transaction(txid)
        with self._lock:
            self._sessions.pop(txid, None)

    def snapshot_read(self, key: str, max_staleness_s: float, *,
                      hint: Optional[PlacementHint] = None):
        """Bounded-staleness snapshot read (no transaction): routed like a
        single-key read session, answered entirely from the chosen node's
        gossip-fed cache at its read watermark.  Returns a
        :class:`~repro.core.node.SnapshotResult`; raises
        ``SnapshotUnavailable`` when gossip lag exceeds the bound."""
        node = self.cluster.pick_node(hint or PlacementHint(keys=(key,)))
        return node.snapshot_read(key, max_staleness_s)

    def node_of(self, txid: str) -> AftNode:
        return self._node(txid)

    def committed_tid_for_uuid(self, uuid: str):
        """Cluster-wide idempotence probe (§3.3.1): has this logical
        transaction already committed anywhere?  Checks live nodes' caches
        first, then falls back to the durable Commit Set in storage."""
        for node in self.cluster.live_nodes():
            tid = node.committed_tid_for_uuid(uuid)
            if tid is not None:
                return tid
        from .records import lookup_committed_record

        record = lookup_committed_record(self.cluster.storage, uuid)
        return record.tid if record is not None else None
