"""Cluster deployment harness (§4.3, §6.7).

Plays the role Kubernetes plays in the paper: membership, a pluggable
request router (``core/routing.py`` — stateless round-robin by default,
exactly the paper's §6 load balancer), a standby-node pool for fast
replacement, and the wiring between nodes, the multicast bus, local GC
agents, and the fault manager.  Autoscaling policy is pluggable (§4.3
leaves it out of scope; we provide a simple load-based policy as a
beyond-paper extension in ``autoscale.py``).

``AftClient`` is the application-facing handle: a logical request (possibly
spanning many FaaS functions / trainer hosts) opens a session pinned to one
AFT node (§3.1: "each transaction sends all operations to a single AFT node")
and drives the Table-1 API through it.  ``start_transaction`` accepts an
optional :class:`PlacementHint` (declared read set / workflow uuid) that
locality-aware routers use to place the session near cached data.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Union

from ..storage.base import StorageEngine
from .errors import NodeFailed
from .fault_manager import FaultManager, FaultManagerConfig
from .gc import LocalGcAgent
from .ids import TxnId
from .multicast import MulticastAgent, MulticastBus
from .node import AftNode, AftNodeConfig
from .routing import PlacementHint, Router, make_router


@dataclass
class ClusterConfig:
    num_nodes: int = 1
    standby_nodes: int = 0
    node: AftNodeConfig = field(default_factory=AftNodeConfig)
    fault_manager: FaultManagerConfig = field(default_factory=FaultManagerConfig)
    # §6.7: replacement nodes pay a cold-start (container download + metadata
    # cache warm-up).  Simulated; scaled by the storage time_scale in benches.
    replacement_delay_s: float = 0.0
    start_background_threads: bool = True
    # placement policy: a core/routing.py policy name ("round_robin",
    # "consistent_hash", "cache_aware") or a Router instance; None keeps the
    # paper's stateless round-robin LB, decision-for-decision.
    routing: Union[str, Router, None] = None


class AftCluster:
    def __init__(self, storage: StorageEngine, config: Optional[ClusterConfig] = None):
        self.storage = storage
        self.config = config or ClusterConfig()
        self.bus = MulticastBus()
        self.nodes: List[AftNode] = []
        self.agents: Dict[str, MulticastAgent] = {}
        self.gc_agents: Dict[str, LocalGcAgent] = {}
        self.standbys: List[AftNode] = []
        self.router = make_router(self.config.routing)
        self._node_seq = 0
        self._lock = threading.RLock()
        self.fault_manager = FaultManager(
            storage,
            self.bus,
            membership=self.all_nodes,  # incl. dead: heartbeat detection
            config=self.config.fault_manager,
            on_node_failure=self._replace_node,
        )
        for _ in range(self.config.num_nodes):
            self._add_node()
        for _ in range(self.config.standby_nodes):
            self.standbys.append(self._make_node(bootstrap=False))
        if self.config.start_background_threads:
            self.start()

    # ------------------------------------------------------------- topology
    def _make_node(self, bootstrap: bool = True) -> AftNode:
        with self._lock:
            node_id = f"aft-{self._node_seq}"
            self._node_seq += 1
        cfg = AftNodeConfig(**{**self.config.node.__dict__, "node_id": node_id})
        return AftNode(self.storage, cfg, bootstrap=bootstrap)

    def _wire_node(self, node: AftNode) -> None:
        agent = MulticastAgent(node, self.bus, peers=self.live_node_ids)
        gc_agent = LocalGcAgent(node)
        with self._lock:
            self.nodes.append(node)
            self.agents[node.node_id] = agent
            self.gc_agents[node.node_id] = gc_agent
        self._sync_router()
        if self.config.start_background_threads:
            agent.start()
            gc_agent.start()

    def _add_node(self) -> AftNode:
        node = self._make_node()
        self._wire_node(node)
        return node

    def _replace_node(self, dead: AftNode) -> None:
        """§6.7 recovery path: detach the dead node, promote a standby (or
        cold-start a new one), warm its metadata cache, join the cluster."""
        with self._lock:
            if dead in self.nodes:
                self.nodes.remove(dead)
            agent = self.agents.pop(dead.node_id, None)
            gc_agent = self.gc_agents.pop(dead.node_id, None)
            standby = self.standbys.pop(0) if self.standbys else None
        # resync BEFORE the replacement delay: during the cold-start window
        # the router must already have forgotten the dead node's ring arc
        self._sync_router()
        if agent is not None:
            agent.stop()
        if gc_agent is not None:
            gc_agent.stop()
        if self.config.replacement_delay_s > 0:
            time.sleep(self.config.replacement_delay_s)  # container download
        node = standby if standby is not None else self._make_node(bootstrap=False)
        node.bootstrap()  # metadata cache warm-up from the Commit Set (§3.1)
        self._wire_node(node)

    # ------------------------------------------------------------ membership
    def all_nodes(self) -> List[AftNode]:
        with self._lock:
            return list(self.nodes)

    def live_nodes(self) -> List[AftNode]:
        with self._lock:
            return [n for n in self.nodes if n.alive]

    def live_node_ids(self) -> List[str]:
        return [n.node_id for n in self.live_nodes()]

    def scale_to(self, n: int) -> None:
        """Elastically add/remove nodes (coordination-free: §4.3)."""
        while len(self.live_nodes()) < n:
            self._add_node()
        while len(self.live_nodes()) > n:
            node = self.live_nodes()[-1]
            self.remove_node(node)

    def remove_node(self, node: AftNode) -> None:
        with self._lock:
            if node in self.nodes:
                self.nodes.remove(node)
            agent = self.agents.pop(node.node_id, None)
            gc_agent = self.gc_agents.pop(node.node_id, None)
        self._sync_router()
        # drain its fresh commits into the bus before detaching
        if agent is not None:
            agent.step()
            agent.stop()
        if gc_agent is not None:
            gc_agent.stop()
        node.close_pipeline()  # graceful leave: flush + stop I/O threads

    def kill_node(self, index: int = 0) -> AftNode:
        """Failure injection (§6.7): hard-kill a live node.  Its agents are
        detached immediately — in particular the multicast inbox is
        unregistered, or peers' eager pushes would accumulate in a queue
        nobody will ever drain (the node stays in ``self.nodes`` so
        heartbeat detection still sees the corpse)."""
        with self._lock:
            node = self.live_nodes()[index]
            node.fail()
            agent = self.agents.pop(node.node_id, None)
            gc_agent = self.gc_agents.pop(node.node_id, None)
        self._sync_router()
        if agent is not None:
            agent.stop()  # unregisters the bus inbox
        if gc_agent is not None:
            gc_agent.stop()
        return node

    # ---------------------------------------------------------- load balance
    def _sync_router(self) -> None:
        """Membership changed (add/remove/kill/replace): rebuild routing
        state (the hash ring) from the current live set."""
        self.router.sync(self.live_nodes())

    def pick_node(self, hint: Optional[PlacementHint] = None) -> AftNode:
        """Route a new session through the configured placement policy
        (``core/routing.py``; default is the paper's §6 stateless
        round-robin LB).  Never returns a node already known dead: the
        live-list snapshot is re-validated after the policy chooses,
        closing the ``kill_node`` → ``_replace_node`` race window."""
        for _ in range(4):
            nodes = self.live_nodes()
            if not nodes:
                raise NodeFailed("no live AFT nodes")
            node = self.router.route(nodes, hint)
            if node.alive:
                return node
            self._sync_router()  # raced a death the policy hadn't seen
        raise NodeFailed("routing kept selecting dead nodes")

    def client(self) -> "AftClient":
        return AftClient(self)

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        for agent in list(self.agents.values()):
            agent.start()
        for gc_agent in list(self.gc_agents.values()):
            gc_agent.start()
        self.fault_manager.start()

    def stop(self) -> None:
        self.fault_manager.stop()
        for agent in list(self.agents.values()):
            agent.stop()
        for gc_agent in list(self.gc_agents.values()):
            gc_agent.stop()
        for node in self.all_nodes():
            node.close_pipeline()

    # deterministic single-step for tests -----------------------------------
    def step_all(self) -> None:
        for agent in list(self.agents.values()):
            agent.step()
        for agent in list(self.agents.values()):
            agent.step()  # second pass delivers what the first pass sent
        for gc_agent in list(self.gc_agents.values()):
            gc_agent.step()
        self.fault_manager.step()

    def __enter__(self) -> "AftCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class AftClient:
    """Application-facing session API; one transaction ↔ one AFT node."""

    def __init__(self, cluster: AftCluster):
        self.cluster = cluster
        self._sessions: Dict[str, AftNode] = {}
        self._session_history: Dict[str, AftNode] = {}
        self._lock = threading.Lock()

    # -- Table 1 --------------------------------------------------------------
    def start_transaction(
        self,
        uuid: Optional[str] = None,
        *,
        hint: Optional[PlacementHint] = None,
        fresh: bool = False,
        read_only: bool = False,
    ) -> str:
        node: Optional[AftNode] = None
        if uuid is not None:
            # §3.3.1: a retry continues the transaction — stick to the node
            # that owns the session if it is still alive, so local
            # idempotence metadata is found without a storage scan.
            with self._lock:
                prior = self._session_history.get(uuid)
            if prior is not None and prior.alive:
                node = prior
        if node is None:
            if hint is None and uuid is not None:
                # a bare retried uuid is still a placement identity: hash-
                # keyed routers send it back to the node that served the
                # original even when this client never saw it
                hint = PlacementHint(uuid=uuid)
            node = self.cluster.pick_node(hint)
        txid = node.start_transaction(uuid, fresh=fresh,
                                      read_only=read_only)
        with self._lock:
            self._sessions[txid] = node
            self._session_history[txid] = node
        return txid

    def _node(self, txid: str) -> AftNode:
        with self._lock:
            node = self._sessions.get(txid)
        if node is None:
            raise NodeFailed(f"no session for {txid}")
        return node

    def get(self, txid: str, key: str) -> Optional[bytes]:
        return self._node(txid).get(txid, key)

    def put(self, txid: str, key: str, value: bytes) -> None:
        self._node(txid).put(txid, key, value)

    def commit_transaction(self, txid: str) -> TxnId:
        node = self._node(txid)
        tid = node.commit_transaction(txid)
        node.release_transaction(txid)
        with self._lock:
            self._sessions.pop(txid, None)
        return tid

    def commit_transaction_async(self, txid: str):
        """Commit through the node's storage I/O pipeline; returns a
        ``Future[TxnId]`` that resolves when the commit record is durable.
        The session is released on success (a failed commit keeps it, like
        the sync path's raise, so the caller can abort or retry)."""
        node = self._node(txid)
        fut = node.commit_transaction_async(txid)

        def _release(f) -> None:
            if f.exception() is None:
                node.release_transaction(txid)
                with self._lock:
                    self._sessions.pop(txid, None)

        fut.add_done_callback(_release)
        return fut

    def abort_transaction(self, txid: str) -> None:
        node = self._node(txid)
        node.abort_transaction(txid)
        node.release_transaction(txid)
        with self._lock:
            self._sessions.pop(txid, None)

    def snapshot_read(self, key: str, max_staleness_s: float, *,
                      hint: Optional[PlacementHint] = None):
        """Bounded-staleness snapshot read (no transaction): routed like a
        single-key read session, answered entirely from the chosen node's
        gossip-fed cache at its read watermark.  Returns a
        :class:`~repro.core.node.SnapshotResult`; raises
        ``SnapshotUnavailable`` when gossip lag exceeds the bound."""
        node = self.cluster.pick_node(hint or PlacementHint(keys=(key,)))
        return node.snapshot_read(key, max_staleness_s)

    def node_of(self, txid: str) -> AftNode:
        return self._node(txid)

    def committed_tid_for_uuid(self, uuid: str):
        """Cluster-wide idempotence probe (§3.3.1): has this logical
        transaction already committed anywhere?  Checks live nodes' caches
        first, then falls back to the durable Commit Set in storage."""
        for node in self.cluster.live_nodes():
            tid = node.committed_tid_for_uuid(uuid)
            if tid is not None:
                return tid
        from .records import lookup_committed_record

        record = lookup_committed_record(self.cluster.storage, uuid)
        return record.tid if record is not None else None
