"""On-disk / in-cache record types (§3.1, §3.3).

Storage layout
--------------
AFT never overwrites a key in place (§3.3): every key version maps to a unique
storage key derived from the writing transaction's ID, and every committed
transaction persists a *commit record* that names its write set.  The layout:

======================  =====================================================
storage key             contents
======================  =====================================================
``d/<key>/<txnid>``     the bytes of version ``<key>_<txnid>``
``t/<txnid>``           commit record: write set + (key → storage key) map
``u/<uuid>``            uuid → committed txnid index (idempotent retry lookup)
``w/<uuid>``            workflow finish marker: the workflow layer declares a
                        DAG done, licensing GC of its ``.wf/`` memo records
``q/<queue>/<seq>``     durable cross-workflow trigger queue (chaining): a
                        committed workflow's ``on_commit`` edges enqueue
                        trigger entries *inside* its own commit record, so a
                        trigger exists iff its parent committed
======================  =====================================================

The workflow layer reserves one *logical* key prefix, ``.wf/`` (so its memo
versions live at ``d/.wf/...`` storage keys): per-step memo records written
through AFT itself (see ``repro/workflow/txn.py``).  Memo keys are written
exactly once per (workflow, step), so Algorithm 2 never supersedes them —
they are instead reclaimed by the finished-workflow sweep in ``core/gc.py``
once a ``w/<uuid>`` marker exists.

``t/``-prefixed keys form the **Transaction Commit Set** (§3.1); because
``TxnId.encode`` is order-preserving, a sorted listing of ``t/`` is a
timestamp-ordered commit log, which the fault manager (§4.2) and node
bootstrap (§3.1) scan.

A version's *cowritten set* is simply its transaction's write set (§3.2):
``k_i.cowritten == T_i.writeset``, so commit records are the only metadata
needed by Algorithm 1.

Trigger-queue layout (chaining, ``repro/workflow/chain.py``)
------------------------------------------------------------
A trigger entry is an ordinary *logical* key ``q/<queue>/<seq>`` with
``<seq> = <parent_uuid>.chain.<edge>`` — deterministic, so a retried parent
commit (§3.3.1) enqueues it exactly once.  Entries carry NO delivery-order
guarantee: ``<seq>`` sorts by parent-uuid text, not commit time, and
consumers may interleave queues arbitrarily.  A consumer's *claim* is the
logical key ``q/<queue>/<seq>/claim`` written by a transaction whose UUID is
``<seq>.claim`` — also deterministic, so racing claimants collapse into one
idempotent commit.  The triggered child workflow runs under UUID ``<seq>``
itself: no matter how many times a crashed handoff is replayed, every drive
recommits the same transactions and the child's effects survive exactly
once.  Entries and claims are reclaimed by the finished-workflow sweep once
the child's ``w/<seq>`` marker exists (``core/gc.py``).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Tuple

from .ids import TxnId

# -- encode-once record fan-out ---------------------------------------------
# A committed TransactionRecord is immutable, so its wire bytes never change:
# memoizing encode() lets one serialization feed the pipeline flush, every
# multicast peer envelope, and gossip blobs.  The toggle exists for the
# hot-path benchmark's pre-PR baseline arm and as an escape hatch
# (REPRO_ENCODE_CACHE=0).  Hit/miss counters are plain ints updated without a
# lock — approximate under races, which the gauges tolerate.
_ENCODE_CACHE_ENABLED = os.environ.get("REPRO_ENCODE_CACHE", "1") != "0"
_ENCODE_STATS = {"hits": 0, "misses": 0}


def set_encode_cache(enabled: bool) -> None:
    """Enable/disable record-encode memoization (already-cached bytes keep
    being served; only new caching stops)."""
    global _ENCODE_CACHE_ENABLED
    _ENCODE_CACHE_ENABLED = bool(enabled)


def encode_cache_enabled() -> bool:
    return _ENCODE_CACHE_ENABLED


def encode_cache_stats() -> Dict[str, int]:
    return dict(_ENCODE_STATS)


def reset_encode_cache_stats() -> None:
    _ENCODE_STATS["hits"] = 0
    _ENCODE_STATS["misses"] = 0

DATA_PREFIX = "d/"
COMMIT_PREFIX = "t/"
UUID_PREFIX = "u/"
WF_FINISH_PREFIX = "w/"
# logical-key namespace reserved for workflow memo records (storage keys for
# these versions land under d/.wf/...)
WORKFLOW_MEMO_PREFIX = ".wf/"
# logical-key namespace for the durable cross-workflow trigger queue
# (storage keys for entry/claim versions land under d/q/...)
TRIGGER_PREFIX = "q/"
# derived transaction UUIDs: a workflow's per-step transactions are
# "<uuid>.step.<name>" and its memo commits "<uuid>.memo.<name>"
# (repro/workflow/txn.py); the GC sweep keys off these infixes
WF_MEMO_TXN_INFIX = ".memo."
WF_STEP_TXN_INFIX = ".step."
# chaining (repro/workflow/chain.py): a trigger entry id — which doubles as
# the child workflow's UUID — is "<parent_uuid>.chain.<edge>"; its claim
# transaction is "<entry>.claim" and a STEP/NONE-scope parent's standalone
# enqueue transaction is "<entry>.enq"
WF_CHAIN_INFIX = ".chain."
CHAIN_CLAIM_SUFFIX = ".claim"
CHAIN_ENQ_SUFFIX = ".enq"


def data_key(key: str, tid: TxnId) -> str:
    """Unique per-version storage key (§3.3: no in-place overwrites)."""
    return f"{DATA_PREFIX}{key}/{tid.encode()}"


def spill_key(key: str, uuid: str, seq: int) -> str:
    """Storage key for a pre-commit buffer spill (§3.3, saturation).

    The commit timestamp is unknown before commit, so spilled intermediary
    data lands at a uuid-derived key; the commit record's explicit
    ``key → storage key`` map keeps it addressable.  Orphans (spills whose
    transaction never committed) are swept by the fault manager's orphan GC.
    """
    return f"{DATA_PREFIX}{key}/.spill/{uuid}/{seq}"


def commit_key(tid: TxnId) -> str:
    return f"{COMMIT_PREFIX}{tid.encode()}"


def uuid_key(uuid: str) -> str:
    return f"{UUID_PREFIX}{uuid}"


def workflow_finish_key(workflow_uuid: str) -> str:
    """Marker persisted when a workflow is declared finished.

    Its presence is the GC license for the workflow's ``.wf/`` memo records
    and the ``u/`` entries of its derived (``<uuid>.step.*`` /
    ``<uuid>.memo.*``) transactions.  The caller promises no further re-drive
    of this UUID will happen — see ``docs/WORKFLOWS.md``.
    """
    return f"{WF_FINISH_PREFIX}{workflow_uuid}"


def is_workflow_memo_key(key: str) -> bool:
    return key.startswith(WORKFLOW_MEMO_PREFIX)


# -- trigger queue (cross-workflow chaining) --------------------------------

def trigger_entry_id(parent_uuid: str, edge: str) -> str:
    """Deterministic queue sequence id for one ``on_commit`` edge.  It is
    also the triggered child workflow's UUID, which is what makes replayed
    handoffs idempotent end to end (§3.3.1 lifted to chaining)."""
    return f"{parent_uuid}{WF_CHAIN_INFIX}{edge}"


def trigger_key(queue: str, entry_id: str) -> str:
    """Logical key of a trigger-queue entry (``q/<queue>/<seq>``)."""
    return f"{TRIGGER_PREFIX}{queue}/{entry_id}"


def trigger_claim_key(queue: str, entry_id: str) -> str:
    """Logical key of an entry's consumer claim."""
    return f"{TRIGGER_PREFIX}{queue}/{entry_id}/claim"


def claim_txn_uuid(entry_id: str) -> str:
    """Deterministic claim-transaction UUID: racing claimants share one
    logical transaction, so the claim commits exactly once."""
    return f"{entry_id}{CHAIN_CLAIM_SUFFIX}"


def enqueue_txn_uuid(entry_id: str) -> str:
    """Deterministic standalone-enqueue UUID (STEP-scope parents, whose DAG
    has no single commit to fold the entry into)."""
    return f"{entry_id}{CHAIN_ENQ_SUFFIX}"


@dataclass(frozen=True)
class TransactionRecord:
    """A committed transaction's durable metadata (the commit record, §3.3).

    ``write_set`` is the set of *logical* keys written; ``storage_keys`` maps
    each logical key to the storage key holding that version's bytes (usually
    ``data_key(key, tid)``, but spilled writes may live at uuid-derived keys).
    """

    tid: TxnId
    write_set: Tuple[str, ...]
    storage_keys: Dict[str, str] = field(default_factory=dict, hash=False)

    def storage_key_for(self, key: str) -> str:
        return self.storage_keys.get(key) or data_key(key, self.tid)

    def cowritten(self) -> Tuple[str, ...]:
        """cowritten(k_i) == T_i.writeset for every k in the write set."""
        return self.write_set

    # -- serialization -----------------------------------------------------
    def encode(self) -> bytes:
        # encode-once: records are immutable after commit, so the first
        # serialization is cached on the instance (frozen dataclasses still
        # carry a __dict__; fields are untouched, so eq/hash are unaffected)
        if _ENCODE_CACHE_ENABLED:
            cached = self.__dict__.get("_enc")
            if cached is not None:
                _ENCODE_STATS["hits"] += 1
                return cached
        body = {
            "t": self.tid.encode(),
            "w": sorted(self.write_set),
            # only store non-default storage keys to keep records small
            "s": {
                k: v
                for k, v in self.storage_keys.items()
                if v != data_key(k, self.tid)
            },
        }
        raw = json.dumps(body, separators=(",", ":")).encode()
        if _ENCODE_CACHE_ENABLED:
            _ENCODE_STATS["misses"] += 1
            object.__setattr__(self, "_enc", raw)
        return raw

    @staticmethod
    def decode(raw: bytes) -> "TransactionRecord":
        body = json.loads(raw)
        tid = TxnId.decode(body["t"])
        rec = TransactionRecord(
            tid=tid, write_set=tuple(body["w"]), storage_keys=dict(body.get("s", {}))
        )
        if _ENCODE_CACHE_ENABLED:
            # seed the encode cache with the wire bytes we just parsed, so a
            # record merged from a peer re-fans-out without re-serializing
            object.__setattr__(rec, "_enc", bytes(raw))
        return rec


@dataclass(frozen=True)
class VersionedValue:
    """A read result: the bytes plus the version that produced them.

    Versions are *hidden from users* (§3.2); the framework layers (checkpoint
    restore, anomaly detectors, property tests) use ``tid`` for validation.
    ``value is None`` with ``tid is None`` means the key has never been
    written (the NULL version); ``value is None`` with ``aborted=True`` means
    Algorithm 1 found no valid version (§3.6) and the transaction should
    abort/retry.
    """

    value: Optional[bytes]
    tid: Optional[TxnId]
    aborted: bool = False


def lookup_committed_record(storage, uuid: str) -> Optional["TransactionRecord"]:
    """Resolve uuid → committed record via the ``u/`` index: two point reads
    instead of a commit-set scan (§3.3.1 retry probe).  An index entry whose
    commit record is missing is a crashed (or GC'd) commit — reported as not
    committed, which is safe because the index is written before the record
    and deleted with it."""
    ptr = storage.get(uuid_key(uuid))
    if ptr is None:
        return None
    raw = storage.get(ptr.decode())
    if raw is None:
        return None
    return TransactionRecord.decode(raw)


# version-header frame: a length-prefixed binary layout replacing the old
# per-get JSON header (json.dumps on embed + json.loads on every extract).
# Byte 0 discriminates the formats: the legacy frame opens with a 4-byte
# big-endian header length whose leading byte is 0x00 for any sane header
# (< 16 MiB), while the binary frame leads with the 0xAF magic.
_META_MAGIC = 0xAF
_META_VERSION = 1


def embed_metadata(value: bytes, tid: TxnId, cowritten: Iterable[str]) -> bytes:
    """Prefix a payload with AFT metadata (binary frame).

    Used in two places: (1) AFT's own data versions, so that values are
    self-describing for recovery tooling; (2) the *plain* storage baselines of
    §6.1.2, which embed "the same metadata AFT uses—a timestamp, a UUID, and a
    cowritten key set" (~70 bytes) to let the anomaly detectors of Table 2
    observe RYW/FR violations without a shim.

    Frame: ``AF 01 | u16 len(tid) | tid | u16 n | (u16 len(key) | key)*n |
    payload`` — all lengths big-endian, strings utf-8.
    """
    parts = [bytes((_META_MAGIC, _META_VERSION))]
    tid_b = tid.encode().encode()
    parts.append(len(tid_b).to_bytes(2, "big"))
    parts.append(tid_b)
    keys = sorted(cowritten)
    parts.append(len(keys).to_bytes(2, "big"))
    for k in keys:
        kb = k.encode()
        parts.append(len(kb).to_bytes(2, "big"))
        parts.append(kb)
    parts.append(value)
    return b"".join(parts)


def extract_metadata(raw: bytes) -> Tuple[bytes, TxnId, Tuple[str, ...]]:
    if raw[:1] == bytes((_META_MAGIC,)):
        if raw[1] != _META_VERSION:
            raise ValueError(f"unknown metadata frame version {raw[1]}")
        pos = 2
        tlen = int.from_bytes(raw[pos:pos + 2], "big")
        pos += 2
        tid = TxnId.decode(raw[pos:pos + tlen].decode())
        pos += tlen
        n = int.from_bytes(raw[pos:pos + 2], "big")
        pos += 2
        keys = []
        for _ in range(n):
            klen = int.from_bytes(raw[pos:pos + 2], "big")
            pos += 2
            keys.append(raw[pos:pos + klen].decode())
            pos += klen
        return raw[pos:], tid, tuple(keys)
    # legacy JSON-header frame (values written before the binary frame)
    hlen = int.from_bytes(raw[:4], "big")
    header = json.loads(raw[4 : 4 + hlen])
    return raw[4 + hlen :], TxnId.decode(header["t"]), tuple(header["c"])
