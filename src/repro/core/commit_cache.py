"""Commit Set Cache + key version index (§3.1).

Each AFT node locally caches the IDs (and write sets) of recently committed
transactions to avoid a metadata fetch on every read, plus an index mapping
each key to the recently-created versions of that key — the two structures
Algorithm 1 consumes.  The cache is warmed at node start by scanning the
latest records of the durable Transaction Commit Set (bootstrap, §3.1) and is
pruned by the local metadata GC (§5.1).
"""

from __future__ import annotations

import threading
from bisect import bisect_left, bisect_right, insort
from typing import Callable, Dict, Iterable, List, Optional, Set

from .ids import TxnId
from .records import TransactionRecord


class CommitSetCache:
    """Thread-safe committed-transaction metadata cache.

    Invariant: a transaction appears in ``_index`` (key → sorted versions)
    iff its record is in ``_records``; Algorithm 1 may therefore resolve any
    indexed version's cowritten set locally.
    """

    def __init__(self) -> None:
        self._records: Dict[TxnId, TransactionRecord] = {}
        # key → sorted (ascending) list of committed TxnIds that wrote it
        self._index: Dict[str, List[TxnId]] = {}
        self._lock = threading.RLock()
        # monotone log of locally-known commits, for the multicast thread to
        # drain ("transactions committed recently on this node", §4)
        self._fresh: List[TransactionRecord] = []
        # key → newest timestamp ever PRUNED for that key (§5.1 GC).  The
        # snapshot lane needs this: a version resolved at a watermark is
        # only trustworthy if no pruned version could have sat between it
        # and the watermark (see AftNode.snapshot_read).
        self._pruned_max: Dict[str, int] = {}

    # -- writes --------------------------------------------------------------
    def add(self, record: TransactionRecord, *, fresh: bool = False) -> bool:
        """Merge a committed transaction's metadata.  Returns False if known."""
        with self._lock:
            if record.tid in self._records:
                return False
            self._records[record.tid] = record
            for key in record.write_set:
                insort(self._index.setdefault(key, []), record.tid)
            if fresh:
                self._fresh.append(record)
            return True

    def remove(self, tid: TxnId) -> Optional[TransactionRecord]:
        """Drop a transaction's metadata (local GC, §5.1)."""
        with self._lock:
            record = self._records.pop(tid, None)
            if record is None:
                return None
            for key in record.write_set:
                if tid.timestamp > self._pruned_max.get(key, -1):
                    self._pruned_max[key] = tid.timestamp
                versions = self._index.get(key)
                if versions is None:
                    continue
                i = bisect_left(versions, tid)
                if i < len(versions) and versions[i] == tid:
                    versions.pop(i)
                if not versions:
                    del self._index[key]
            return record

    def note_pruned(self, record: TransactionRecord) -> None:
        """Tombstone ``record``'s write-set keys in the pruned-watermark map
        without requiring the record to be indexed here — global GC phase 1
        confirming a commit this node never learned (the announcement was
        dropped and the record was superseded before repair caught up)."""
        with self._lock:
            for key in record.write_set:
                if record.tid.timestamp > self._pruned_max.get(key, -1):
                    self._pruned_max[key] = record.tid.timestamp

    def drain_fresh(self) -> List[TransactionRecord]:
        """Hand the multicast thread everything committed since last drain."""
        with self._lock:
            out, self._fresh = self._fresh, []
            return out

    # -- reads ---------------------------------------------------------------
    def get(self, tid: TxnId) -> Optional[TransactionRecord]:
        with self._lock:
            return self._records.get(tid)

    def __contains__(self, tid: TxnId) -> bool:
        with self._lock:
            return tid in self._records

    def versions_of(self, key: str) -> List[TxnId]:
        """Committed versions of ``key`` known locally, ascending."""
        with self._lock:
            return list(self._index.get(key, ()))

    def latest_version_of(self, key: str) -> Optional[TxnId]:
        with self._lock:
            versions = self._index.get(key)
            return versions[-1] if versions else None

    def pruned_max_ts(self, key: str) -> int:
        """Newest timestamp ever pruned for ``key`` (-1 if never pruned).
        Monotone; survives the pruned records themselves."""
        with self._lock:
            return self._pruned_max.get(key, -1)

    def latest_version_at(self, key: str, max_ts_ns: int) -> Optional[TxnId]:
        """Newest locally-known committed version of ``key`` with timestamp
        ≤ ``max_ts_ns`` — the snapshot-lane resolver: given a gossiped read
        watermark, the freshest version at-or-below it is the snapshot's
        answer."""
        with self._lock:
            versions = self._index.get(key)
            if not versions:
                return None
            i = bisect_right(versions, max_ts_ns,
                             key=lambda t: t.timestamp)
            return versions[i - 1] if i else None

    def all_tids(self) -> List[TxnId]:
        with self._lock:
            return list(self._records.keys())

    def snapshot_records(self) -> List[TransactionRecord]:
        with self._lock:
            return list(self._records.values())

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    # -- coarse lock for multi-structure atomic sections ---------------------
    @property
    def lock(self) -> threading.RLock:
        return self._lock


class DataCache:
    """LRU (key, version) → bytes cache (§3.1, evaluated in §6.2).

    Values are immutable once committed (versions are never overwritten), so
    the cache never needs invalidation — only eviction (capacity or GC).
    """

    def __init__(self, max_bytes: int = 64 * 1024 * 1024) -> None:
        self.max_bytes = max_bytes
        self._data: Dict[tuple, bytes] = {}
        self._order: List[tuple] = []  # LRU approximation: move-to-end
        self._size = 0
        self._lock = threading.Lock()
        # key → number of cached versions, so routers can probe "does this
        # node have ANY version of k cached?" in O(1) (core/routing.py)
        self._key_counts: Dict[str, int] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: str, tid: TxnId) -> Optional[bytes]:
        with self._lock:
            v = self._data.get((key, tid))
            if v is None:
                self.misses += 1
            else:
                self.hits += 1
            return v

    def put(self, key: str, tid: TxnId, value: bytes) -> None:
        if len(value) > self.max_bytes:
            return
        with self._lock:
            ent = (key, tid)
            if ent in self._data:
                self._size -= len(self._data[ent])
            else:
                self._order.append(ent)
                self._key_counts[key] = self._key_counts.get(key, 0) + 1
            self._data[ent] = value
            self._size += len(value)
            while self._size > self.max_bytes and self._order:
                old = self._order.pop(0)
                v = self._data.pop(old, None)
                if v is not None:
                    self._size -= len(v)
                    self._drop_key_count(old[0])

    def evict_transaction(self, record: TransactionRecord) -> None:
        """Drop any cached data written by ``record`` (GC eviction, §5.1)."""
        with self._lock:
            for key in record.write_set:
                v = self._data.pop((key, record.tid), None)
                if v is not None:
                    self._size -= len(v)
                    self._drop_key_count(key)

    def _drop_key_count(self, key: str) -> None:
        # caller holds self._lock; entry removal from _data already happened
        # (the stale _order slot for evict_transaction is harmless: pop(old,
        # None) misses and nothing double-counts)
        n = self._key_counts.get(key, 0) - 1
        if n > 0:
            self._key_counts[key] = n
        else:
            self._key_counts.pop(key, None)

    def contains_key(self, key: str) -> bool:
        """Is ANY committed version of ``key`` cached here?  O(1); used by
        cache-aware routing to score read-set affinity."""
        with self._lock:
            return key in self._key_counts

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "entries": len(self._data),
                "bytes": self._size,
            }
