"""Commit Set Cache + key version index (§3.1) — striped for the hot path.

Each AFT node locally caches the IDs (and write sets) of recently committed
transactions to avoid a metadata fetch on every read, plus an index mapping
each key to the recently-created versions of that key — the two structures
Algorithm 1 consumes.  The cache is warmed at node start by scanning the
latest records of the durable Transaction Commit Set (bootstrap, §3.1) and is
pruned by the local metadata GC (§5.1).

Locking design (the metadata hot path)
--------------------------------------
The cache is partitioned into ``stripes`` shards.  A transaction's record
lives in the stripe of ``hash(tid)``; each key's version list (and pruned
watermark) lives in the stripe of ``hash(key)``.  Read accessors take exactly
one stripe lock; mutators (``add``/``remove``/``note_pruned``) take the union
of the stripes they touch in ascending stripe order (deadlock-free), so the
invariant *"a transaction appears in the index iff its record is present"*
holds atomically at every instant — not just at quiescence.

Rules the callers must follow (enforced by the accessors below):

* readers never nest stripe locks — resolve a key's version list under
  ``lock_for_key``, then release before resolving candidate records via
  ``get`` (which takes the candidate's own stripe);
* the coarse ``global_section()`` (all stripes, ascending) is reserved for
  bootstrap warm-up and full GC sweeps;
* nested single-stripe acquisitions are legal *inside* ``global_section``
  (the locks are reentrant and already held).

Why a per-read consistent view survives striping is argued in
``atomic_read.atomic_read_select_incremental``.
"""

from __future__ import annotations

import threading
from bisect import bisect_left, bisect_right, insort
from collections import OrderedDict
from time import perf_counter
from typing import Dict, List, Optional, Sequence, Tuple

from .ids import TxnId
from .records import TransactionRecord

DEFAULT_STRIPES = 16


class _Stripe:
    """One shard: a records map keyed by TxnId-hash plus an index/pruned map
    keyed by key-hash (the two hash spaces share the stripe array)."""

    __slots__ = ("lock", "records", "index", "pruned_max",
                 "acquires", "contended", "wait_s")

    def __init__(self) -> None:
        self.lock = threading.RLock()
        self.records: Dict[TxnId, TransactionRecord] = {}
        self.index: Dict[str, List[TxnId]] = {}
        self.pruned_max: Dict[str, int] = {}
        # contention accounting (read via CommitSetCache.lock_stats)
        self.acquires = 0
        self.contended = 0
        self.wait_s = 0.0


class _Section:
    """Context manager over an ascending run of stripes (one, some, or all).

    Also exposes ``acquire``/``release`` so legacy ``cache.lock`` callers that
    treat it like a Lock keep working.
    """

    __slots__ = ("_cache", "_stripes")

    def __init__(self, cache: "CommitSetCache",
                 stripes: Sequence[_Stripe]) -> None:
        self._cache = cache
        self._stripes = stripes

    def acquire(self) -> None:
        for s in self._stripes:
            self._cache._acquire(s)

    def release(self) -> None:
        for s in reversed(self._stripes):
            s.lock.release()

    def __enter__(self) -> "_Section":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class CommitSetCache:
    """Thread-safe committed-transaction metadata cache, striped.

    Invariant: a transaction appears in the index (key → sorted versions)
    iff its record is present; Algorithm 1 may therefore resolve any
    indexed version's cowritten set locally (a candidate that resolves to
    ``None`` was pruned *after* the index was consulted — skipping it keeps
    the selection safe, see atomic_read.py).
    """

    def __init__(self, stripes: int = DEFAULT_STRIPES) -> None:
        if stripes < 1:
            raise ValueError("stripes must be >= 1")
        self._stripes: Tuple[_Stripe, ...] = tuple(
            _Stripe() for _ in range(stripes))
        self._n = stripes
        # monotone log of locally-known commits, for the multicast thread to
        # drain ("transactions committed recently on this node", §4); its own
        # lock — always acquired innermost (stripe → fresh, never the reverse)
        self._fresh_lock = threading.Lock()
        self._fresh: List[TransactionRecord] = []

    # -- stripe plumbing ----------------------------------------------------
    def _stripe_for_tid(self, tid: TxnId) -> _Stripe:
        return self._stripes[hash(tid) % self._n]

    def _stripe_for_key(self, key: str) -> _Stripe:
        return self._stripes[hash(key) % self._n]

    def _acquire(self, stripe: _Stripe) -> None:
        # fast path: uncontended (or reentrant) acquire; the slow path feeds
        # the lock-wait gauges surfaced through the obs registry
        if stripe.lock.acquire(blocking=False):
            stripe.acquires += 1
            return
        t0 = perf_counter()
        stripe.lock.acquire()
        stripe.acquires += 1
        stripe.contended += 1
        stripe.wait_s += perf_counter() - t0

    def _section_for(self, *members) -> _Section:
        """Ascending-order section over the stripes the members hash to."""
        picked: Dict[int, _Stripe] = {}
        for m in members:
            i = hash(m) % self._n
            picked[i] = self._stripes[i]
        return _Section(self, [picked[i] for i in sorted(picked)])

    def lock_for_key(self, key: str) -> _Section:
        """Single-stripe section guarding ``key``'s version list and pruned
        watermark — the Algorithm-1 read fast path."""
        return _Section(self, (self._stripe_for_key(key),))

    def global_section(self) -> _Section:
        """Coarse all-stripes section (ascending order).  Bootstrap warm-up
        and full sweeps only — never on the per-read hot path."""
        return _Section(self, self._stripes)

    @property
    def lock(self):
        """Legacy coarse lock: a context manager freezing every stripe.  The
        reference ``atomic_read_select`` oracle uses it to get the original
        one-big-lock consistent view; new code should prefer the striped
        accessors."""
        return self.global_section()

    @property
    def stripe_count(self) -> int:
        return self._n

    def lock_stats(self) -> Dict[str, float]:
        """Aggregate stripe-lock contention counters (approximate: read
        without freezing the stripes)."""
        acquires = contended = 0
        wait_s = 0.0
        for s in self._stripes:
            acquires += s.acquires
            contended += s.contended
            wait_s += s.wait_s
        return {"acquires": acquires, "contended": contended,
                "wait_ms": wait_s * 1e3}

    # -- writes --------------------------------------------------------------
    def add(self, record: TransactionRecord, *, fresh: bool = False) -> bool:
        """Merge a committed transaction's metadata.  Returns False if known.

        Takes the union of the record's tid stripe and its write-set key
        stripes so the records/index invariant is atomic with respect to
        every reader and to concurrent ``remove`` of the same tid.
        """
        tid = record.tid
        with self._section_for(tid, *record.write_set):
            records = self._stripe_for_tid(tid).records
            if tid in records:
                return False
            records[tid] = record
            for key in record.write_set:
                insort(self._stripe_for_key(key).index.setdefault(key, []),
                       tid)
            if fresh:
                with self._fresh_lock:
                    self._fresh.append(record)
            return True

    def remove(self, tid: TxnId) -> Optional[TransactionRecord]:
        """Drop a transaction's metadata (local GC, §5.1)."""
        # two-phase: peek the record (its write set names the key stripes we
        # must also hold), then re-check under the full section — the record
        # is immutable, so a tid→record binding never changes between phases
        stripe = self._stripe_for_tid(tid)
        with _Section(self, (stripe,)):
            record = stripe.records.get(tid)
        if record is None:
            return None
        with self._section_for(tid, *record.write_set):
            record = stripe.records.pop(tid, None)
            if record is None:  # lost the race to a concurrent remove
                return None
            for key in record.write_set:
                ks = self._stripe_for_key(key)
                if tid.timestamp > ks.pruned_max.get(key, -1):
                    ks.pruned_max[key] = tid.timestamp
                versions = ks.index.get(key)
                if versions is None:
                    continue
                i = bisect_left(versions, tid)
                if i < len(versions) and versions[i] == tid:
                    versions.pop(i)
                if not versions:
                    del ks.index[key]
            return record

    def note_pruned(self, record: TransactionRecord) -> None:
        """Tombstone ``record``'s write-set keys in the pruned-watermark map
        without requiring the record to be indexed here — global GC phase 1
        confirming a commit this node never learned (the announcement was
        dropped and the record was superseded before repair caught up)."""
        ts = record.tid.timestamp
        for key in record.write_set:
            ks = self._stripe_for_key(key)
            with _Section(self, (ks,)):
                if ts > ks.pruned_max.get(key, -1):
                    ks.pruned_max[key] = ts

    def drain_fresh(self) -> List[TransactionRecord]:
        """Hand the multicast thread everything committed since last drain."""
        with self._fresh_lock:
            out, self._fresh = self._fresh, []
            return out

    # -- reads ---------------------------------------------------------------
    def get(self, tid: TxnId) -> Optional[TransactionRecord]:
        stripe = self._stripe_for_tid(tid)
        with _Section(self, (stripe,)):
            return stripe.records.get(tid)

    def __contains__(self, tid: TxnId) -> bool:
        stripe = self._stripe_for_tid(tid)
        with _Section(self, (stripe,)):
            return tid in stripe.records

    def versions_of(self, key: str) -> List[TxnId]:
        """Committed versions of ``key`` known locally, ascending (a copy —
        safe to hold after the call returns)."""
        stripe = self._stripe_for_key(key)
        with _Section(self, (stripe,)):
            return list(stripe.index.get(key, ()))

    def versions_view(self, key: str) -> Sequence[TxnId]:
        """Zero-copy view of ``key``'s ascending version list.  The caller
        MUST hold ``lock_for_key(key)`` and must not retain the view past
        releasing it (Algorithm-1 slices its candidate tail under the lock
        instead of copying the whole list per read)."""
        return self._stripe_for_key(key).index.get(key, ())

    def latest_version_of(self, key: str) -> Optional[TxnId]:
        stripe = self._stripe_for_key(key)
        with _Section(self, (stripe,)):
            versions = stripe.index.get(key)
            return versions[-1] if versions else None

    def pruned_max_ts(self, key: str) -> int:
        """Newest timestamp ever pruned for ``key`` (-1 if never pruned).
        Monotone; survives the pruned records themselves."""
        stripe = self._stripe_for_key(key)
        with _Section(self, (stripe,)):
            return stripe.pruned_max.get(key, -1)

    def latest_version_at(self, key: str, max_ts_ns: int) -> Optional[TxnId]:
        """Newest locally-known committed version of ``key`` with timestamp
        ≤ ``max_ts_ns`` — the snapshot-lane resolver: given a gossiped read
        watermark, the freshest version at-or-below it is the snapshot's
        answer."""
        stripe = self._stripe_for_key(key)
        with _Section(self, (stripe,)):
            versions = stripe.index.get(key)
            if not versions:
                return None
            i = bisect_right(versions, max_ts_ns,
                             key=lambda t: t.timestamp)
            return versions[i - 1] if i else None

    def all_tids(self) -> List[TxnId]:
        """All locally-known committed tids.  Per-stripe collection without a
        global freeze — weakly consistent, which every caller (the §5.1 GC
        sweep) tolerates: a tid added or removed concurrently may or may not
        appear, exactly as with the old coarse lock released between the
        snapshot and the sweep body."""
        out: List[TxnId] = []
        for stripe in self._stripes:
            with _Section(self, (stripe,)):
                out.extend(stripe.records.keys())
        return out

    def snapshot_records(self) -> List[TransactionRecord]:
        """Weakly-consistent copy of all records (fault-manager sweeps, node
        handoff).  Same consistency note as ``all_tids``."""
        out: List[TransactionRecord] = []
        for stripe in self._stripes:
            with _Section(self, (stripe,)):
                out.extend(stripe.records.values())
        return out

    def __len__(self) -> int:
        total = 0
        for stripe in self._stripes:
            with _Section(self, (stripe,)):
                total += len(stripe.records)
        return total


class DataCache:
    """O(1) LRU (key, version) → bytes cache (§3.1, evaluated in §6.2).

    Values are immutable once committed (versions are never overwritten), so
    the cache never needs invalidation — only eviction (capacity or GC).
    Backed by an ``OrderedDict``: hits promote via ``move_to_end`` and
    eviction pops the true least-recently-used entry in O(1), replacing the
    old FIFO list whose ``pop(0)`` was O(n) and whose ``get`` never promoted.
    """

    def __init__(self, max_bytes: int = 64 * 1024 * 1024) -> None:
        self.max_bytes = max_bytes
        self._data: "OrderedDict[tuple, bytes]" = OrderedDict()
        self._size = 0
        self._lock = threading.Lock()
        # key → number of cached versions, so routers can probe "does this
        # node have ANY version of k cached?" in O(1) (core/routing.py)
        self._key_counts: Dict[str, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: str, tid: TxnId) -> Optional[bytes]:
        ent = (key, tid)
        with self._lock:
            v = self._data.get(ent)
            if v is None:
                self.misses += 1
            else:
                self.hits += 1
                self._data.move_to_end(ent)
            return v

    def put(self, key: str, tid: TxnId, value: bytes) -> None:
        if len(value) > self.max_bytes:
            return
        with self._lock:
            ent = (key, tid)
            prior = self._data.get(ent)
            if prior is not None:
                self._size -= len(prior)
                self._data.move_to_end(ent)
            else:
                self._key_counts[key] = self._key_counts.get(key, 0) + 1
            self._data[ent] = value
            self._size += len(value)
            while self._size > self.max_bytes and self._data:
                old, v = self._data.popitem(last=False)
                self._size -= len(v)
                self._drop_key_count(old[0])
                self.evictions += 1

    def evict_transaction(self, record: TransactionRecord) -> None:
        """Drop any cached data written by ``record`` (GC eviction, §5.1)."""
        with self._lock:
            for key in record.write_set:
                v = self._data.pop((key, record.tid), None)
                if v is not None:
                    self._size -= len(v)
                    self._drop_key_count(key)

    def _drop_key_count(self, key: str) -> None:
        # caller holds self._lock; entry removal from _data already happened
        n = self._key_counts.get(key, 0) - 1
        if n > 0:
            self._key_counts[key] = n
        else:
            self._key_counts.pop(key, None)

    def contains_key(self, key: str) -> bool:
        """Is ANY committed version of ``key`` cached here?  O(1); used by
        cache-aware routing to score read-set affinity."""
        with self._lock:
            return key in self._key_counts

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "entries": len(self._data),
                "bytes": self._size,
                "evictions": self.evictions,
            }
