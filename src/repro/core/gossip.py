"""TPU-native commit-digest plane (§4 multicast on the ICI).

The paper's commit-set multicast is a host-network broadcast.  When AFT
nodes are TPU hosts, the metadata plane can instead ride the interconnect:
each node packs its recently-committed transaction IDs into a fixed-size
``(k, 4)`` int32 digest — ``[ts_hi, ts_lo, hash_hi, hash_lo]`` rows — and a
single ``shard_map``-ped ``all_gather`` over the ``nodes`` mesh axis
exchanges all digests in one collective, off the transaction critical path.

A digest row is a *pointer*, not the record: the receiver resolves the full
commit record from shared storage via the timestamp-prefixed commit-log key
(IDs serialize with a zero-padded timestamp, so a prefix listing is exact),
verifies the uuid hash, and merges via the same ``merge_remote_commits``
path the host-network multicast uses.  The write-ordering protocol (§3.3)
guarantees the record is durable before its ID can appear in any digest.

Supersedence pruning (§4.1, Algorithm 2) applies before packing, exactly as
in the host-network plane.

The plane also carries a *horizon channel*: one extra ``(1, 4)`` row per
node per round publishes the node's commit horizon
(``AftNode.commit_horizon_ns``), and every receiver folds the gathered
horizons into its read watermark (``set_watermark_provider``) — the same
bounded-staleness frontier the host-network ``MulticastAgent`` gossips.
A node withholds its horizon for a round whenever the round's digest could
not carry its full fresh set (k-truncation or §4.1 pruning): a horizon must
never claim coverage of a commit whose pointer was not exchanged, so the
channel degrades to a stalled (fail-safe) watermark instead.
"""

from __future__ import annotations

import hashlib
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .ids import TxnId
from .node import AftNode
from .records import COMMIT_PREFIX, TransactionRecord, commit_key
from .supersede import is_superseded

DIGEST_WIDTH = 4

# storage namespace for published node-metrics snapshots (repro/obs):
# m/<node_id> holds the node's latest registry snapshot as JSON
METRICS_PREFIX = "m/"


def _hash64(s: str) -> int:
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(),
                          "big", signed=False)


def _split64(v: int) -> Tuple[int, int]:
    v &= (1 << 64) - 1
    return (v >> 32) & 0xFFFFFFFF, v & 0xFFFFFFFF


def _join64(hi: int, lo: int) -> int:
    return ((hi & 0xFFFFFFFF) << 32) | (lo & 0xFFFFFFFF)


def pack_digest(tids: Sequence[TxnId], k: int) -> np.ndarray:
    """(k, 4) int32 digest; zero rows pad.  Keeps the newest k txns."""
    rows = np.zeros((k, DIGEST_WIDTH), dtype=np.uint32)
    newest = sorted(tids)[-k:]
    for i, tid in enumerate(newest):
        ts_hi, ts_lo = _split64(tid.timestamp)
        h_hi, h_lo = _split64(_hash64(tid.encode()))
        rows[i] = (ts_hi, ts_lo, h_hi, h_lo)
    return rows.view(np.int32)


def unpack_digest(rows: np.ndarray) -> List[Tuple[int, int]]:
    """→ [(timestamp, uuid_hash64)] for non-empty rows."""
    rows = np.asarray(rows).view(np.uint32).reshape(-1, DIGEST_WIDTH)
    out = []
    for ts_hi, ts_lo, h_hi, h_lo in rows.tolist():
        if not (ts_hi | ts_lo | h_hi | h_lo):
            continue
        out.append((_join64(ts_hi, ts_lo), _join64(h_hi, h_lo)))
    return out


def exchange_digests(digests: np.ndarray,
                     mesh: Optional[Mesh] = None) -> np.ndarray:
    """All-gather node digests over the ``nodes`` mesh axis.

    ``digests``: (n_nodes, k, 4) int32, row i owned by node i.  Returns the
    same array made globally visible — on an n-device mesh each device
    contributes its shard and receives the gathered whole in one collective.
    """
    n = digests.shape[0]
    if mesh is None:
        ndev = len(jax.devices())
        use = 1
        for d in range(min(n, ndev), 0, -1):
            if n % d == 0:
                use = d
                break
        mesh = jax.make_mesh((use,), ("nodes",),
                             devices=jax.devices()[:use])

    @jax.jit
    def run(x):
        def body(shard):
            return jax.lax.all_gather(shard, "nodes", axis=0, tiled=True)

        return shard_map(body, mesh=mesh, in_specs=P("nodes"),
                         out_specs=P(), check_rep=False)(x)

    return np.asarray(run(jnp.asarray(digests)))


class DigestPlane:
    """Drives gossip rounds for an in-process set of AFT nodes."""

    def __init__(self, nodes: Sequence[AftNode], storage, *,
                 k: int = 128, mesh: Optional[Mesh] = None):
        self.nodes = list(nodes)
        self.storage = storage
        self.k = k
        self.mesh = mesh
        self._pending: Dict[str, List[TransactionRecord]] = {
            n.node_id: [] for n in self.nodes}
        # receiver node_id → {src node_id → newest gathered horizon}
        self.peer_horizons: Dict[str, Dict[str, int]] = {
            n.node_id: {} for n in self.nodes}
        self.stats = {"rounds": 0, "rows_sent": 0, "records_fetched": 0,
                      "pruned": 0, "horizons_withheld": 0,
                      "resolve_memo_hits": 0}
        for node in self.nodes:
            node.set_watermark_provider(self._floor_fn(node))

    # -- elastic membership --------------------------------------------------
    def add_node(self, node: AftNode) -> None:
        """Admit a (JOINING) member to the gossip plane: digest slot,
        horizon book-keeping, and watermark provider in one step — the
        node starts gating its own watermark on the full peer set
        immediately (fail-safe: unheard peers floor at -1)."""
        if any(n.node_id == node.node_id for n in self.nodes):
            return
        self.nodes.append(node)
        self._pending.setdefault(node.node_id, [])
        self.peer_horizons.setdefault(node.node_id, {})
        node.set_watermark_provider(self._floor_fn(node))

    def remove_node(self, node_or_id) -> None:
        """Retire a member: peers' watermark floors stop waiting on its
        horizon the moment it leaves ``self.nodes`` (the floor closure
        re-reads the list every round), and its gathered-horizon residue is
        dropped so a later rejoin starts clean."""
        node_id = getattr(node_or_id, "node_id", node_or_id)
        self.nodes = [n for n in self.nodes if n.node_id != node_id]
        self._pending.pop(node_id, None)
        self.peer_horizons.pop(node_id, None)
        for known in self.peer_horizons.values():
            known.pop(node_id, None)

    def membership_listener(self):
        """Adapter for ``AftCluster.add_membership_listener``: keeps the
        plane's peer set in step with lifecycle transitions."""
        def on_event(event: str, node: AftNode) -> None:
            if event in ("join", "live"):
                self.add_node(node)
            elif event == "retired":
                self.remove_node(node)
        return on_event

    def _floor_fn(self, node: AftNode):
        """Watermark floor for one node: min over the *currently live* other
        plane members' gathered horizons (-1 until heard from — fail-safe),
        or None when the node stands alone."""
        def floor() -> Optional[int]:
            others = [p for p in self.nodes
                      if p.node_id != node.node_id and p.alive]
            if not others:
                return None
            known = self.peer_horizons.get(node.node_id, {})
            return min(known.get(p.node_id, -1) for p in others)
        return floor

    def _resolve(self, ts: int, uuid_hash: int) -> Optional[TransactionRecord]:
        """Commit-log lookup by timestamp prefix + hash verification."""
        prefix = f"{COMMIT_PREFIX}{ts:020d}."
        for key in self.storage.list_keys(prefix):
            raw = self.storage.get(key)
            if raw is None:
                continue
            rec = TransactionRecord.decode(raw)
            if _hash64(rec.tid.encode()) == uuid_hash:
                return rec
        return None

    def step(self) -> int:
        """One gossip round.  Returns the number of records merged."""
        per_node: List[np.ndarray] = []
        # horizon BEFORE draining (mirrors MulticastAgent.step): commits
        # visible after this point either ride this round's digest or carry
        # timestamps above the horizon (in-flight commits cap it)
        horizons: Dict[str, Optional[int]] = {
            n.node_id: (n.commit_horizon_ns() if n.alive else None)
            for n in self.nodes}
        for node in self.nodes:
            fresh = self._pending[node.node_id]
            fresh.extend(node.drain_fresh_commits())
            kept = []
            for rec in fresh:
                if is_superseded(rec, node.cache):
                    self.stats["pruned"] += 1
                    continue
                kept.append(rec)
            self._pending[node.node_id] = []
            tids = [r.tid for r in kept]
            if len(kept) != len(fresh) or len(tids) > self.k:
                # the digest cannot carry every fresh commit this round
                # (§4.1 pruning or k-truncation): withhold the horizon so it
                # never claims a commit whose pointer was not exchanged
                horizons[node.node_id] = None
                self.stats["horizons_withheld"] += 1
            self.stats["rows_sent"] += len(tids)
            per_node.append(pack_digest(tids, self.k))
        if not per_node:
            return 0
        gathered = exchange_digests(np.stack(per_node), self.mesh)
        h_gathered = self._exchange_horizons(horizons)
        merged = 0
        # decode-once fan-in: every receiver resolves the same gathered
        # digest rows, so one storage lookup + record decode per (ts, hash)
        # serves all n receivers (the decoded record also seeds the
        # encode-once cache, so downstream re-fan-out reuses its bytes)
        resolved: Dict[Tuple[int, int], Optional[TransactionRecord]] = {}
        for i, node in enumerate(self.nodes):
            if not node.alive:
                continue
            for j, src in enumerate(self.nodes):
                if j == i:
                    continue
                for ts, h in unpack_digest(gathered[j]):
                    if (ts, h) in resolved:
                        rec = resolved[(ts, h)]
                        self.stats["resolve_memo_hits"] += 1
                    else:
                        rec = self._resolve(ts, h)
                        resolved[(ts, h)] = rec
                        if rec is not None:
                            self.stats["records_fetched"] += 1
                    if rec is None:
                        continue
                    merged += node.merge_remote_commits([rec])
                src_h = h_gathered.get(src.node_id)
                if src_h is not None:
                    mine = self.peer_horizons[node.node_id]
                    if src_h > mine.get(src.node_id, -1):
                        mine[src.node_id] = src_h
        self.stats["rounds"] += 1
        return merged

    def _exchange_horizons(
        self, horizons: Dict[str, Optional[int]]
    ) -> Dict[str, Optional[int]]:
        """All-gather the per-node commit horizons as one extra (1, 4) row
        per node — ``[h_hi, h_lo, 1, 0]`` (the marker keeps a legitimate
        horizon distinguishable from an all-zero withheld row)."""
        rows = np.zeros((len(self.nodes), 1, DIGEST_WIDTH), dtype=np.uint32)
        for i, node in enumerate(self.nodes):
            h = horizons.get(node.node_id)
            if h is None or h < 0:
                continue  # withheld: peers keep their last value
            h_hi, h_lo = _split64(h)
            rows[i, 0] = (h_hi, h_lo, 1, 0)
        gathered = exchange_digests(rows.view(np.int32), self.mesh)
        out: Dict[str, Optional[int]] = {}
        for j, node in enumerate(self.nodes):
            row = np.asarray(gathered[j]).view(np.uint32).reshape(-1)
            if int(row[2]) != 1:
                out[node.node_id] = None
                continue
            out[node.node_id] = _join64(int(row[0]), int(row[1]))
        return out


class MetricsPlane:
    """Gossip-fed cluster metrics aggregation (repro/obs) on the ICI.

    Rides the exact machinery of :class:`DigestPlane`: each round, every
    node publishes its registry snapshot as JSON under ``m/<node_id>`` and
    contributes one ``[seq_hi, seq_lo, hash_hi, hash_lo]`` int32 row; a
    single ``all_gather`` (``exchange_digests`` with k=1) makes every row
    globally visible.  A row is a *pointer*, not the payload — the snapshot
    blob itself travels through shared storage, and the gossiped hash
    verifies the fetched bytes (a mismatch means the publish raced the
    fetch; the row is skipped and the next round retries).  Stale rows
    (seq not newer than the last ingested) are skipped too, so a wedged
    node's frozen snapshot is ingested once, not every round.

    The merged view lands in the fault manager (``ingest_metrics``), which
    is where a cluster-wide observer already lives; ``views`` keeps the
    plane's own copy for driving code that has no fault manager.
    """

    def __init__(self, nodes: Sequence[AftNode], storage, *,
                 fault_manager=None, mesh: Optional[Mesh] = None):
        self.nodes = list(nodes)
        self.storage = storage
        self.fault_manager = fault_manager
        self.mesh = mesh
        self._seq = 0
        self._ingested_seq: Dict[str, int] = {}
        self.views: Dict[str, dict] = {}  # node_id → latest snapshot
        self.stats = {"rounds": 0, "published": 0, "ingested": 0,
                      "hash_mismatches": 0}

    # -- elastic membership --------------------------------------------------
    def add_node(self, node: AftNode) -> None:
        if any(n.node_id == node.node_id for n in self.nodes):
            return
        self.nodes.append(node)

    def remove_node(self, node_or_id) -> None:
        node_id = getattr(node_or_id, "node_id", node_or_id)
        self.nodes = [n for n in self.nodes if n.node_id != node_id]
        self._ingested_seq.pop(node_id, None)
        self.views.pop(node_id, None)

    def membership_listener(self):
        """Adapter for ``AftCluster.add_membership_listener``: a retired
        node's last snapshot leaves the merged view at once, so autoscaler
        signals never average in a gone member."""
        def on_event(event: str, node: AftNode) -> None:
            if event in ("join", "live"):
                self.add_node(node)
            elif event == "retired":
                self.remove_node(node)
        return on_event

    def _publish(self, node: AftNode) -> Tuple[int, int]:
        """Write the node's snapshot blob; returns (seq, hash64)."""
        snap = node.registry.snapshot()
        blob = json.dumps(snap, sort_keys=True, default=str).encode()
        self.storage.put(f"{METRICS_PREFIX}{node.node_id}", blob)
        self.stats["published"] += 1
        return self._seq, _hash64(blob.decode())

    def step(self) -> int:
        """One gossip round.  Returns the number of snapshots ingested."""
        self._seq += 1
        rows = np.zeros((len(self.nodes), 1, DIGEST_WIDTH), dtype=np.uint32)
        for i, node in enumerate(self.nodes):
            if not node.alive:
                continue  # zero row: peers skip it, like an empty digest
            seq, h = self._publish(node)
            s_hi, s_lo = _split64(seq)
            h_hi, h_lo = _split64(h)
            rows[i, 0] = (s_hi, s_lo, h_hi, h_lo)
        gathered = exchange_digests(rows.view(np.int32), self.mesh)
        ingested = 0
        fresh: Dict[str, dict] = {}
        for j, node in enumerate(self.nodes):
            for seq, h in unpack_digest(gathered[j]):
                if seq <= self._ingested_seq.get(node.node_id, 0):
                    continue
                raw = self.storage.get(f"{METRICS_PREFIX}{node.node_id}")
                if raw is None or _hash64(raw.decode()) != h:
                    self.stats["hash_mismatches"] += raw is not None
                    continue
                snap = json.loads(raw)
                self._ingested_seq[node.node_id] = seq
                self.views[node.node_id] = snap
                fresh[node.node_id] = snap
                ingested += 1
        if fresh and self.fault_manager is not None:
            self.fault_manager.ingest_metrics(fresh)
        self.stats["rounds"] += 1
        self.stats["ingested"] += ingested
        return ingested
