"""Transaction identifiers (§3.1).

A transaction is assigned a globally-unique UUID at ``StartTransaction`` time
and a commit *timestamp* (from the committing node's local clock) at
``CommitTransaction`` time.  The pair ``⟨timestamp, uuid⟩`` is the transaction's
ID.  Correctness never relies on clock synchronization: timestamps only provide
*relative freshness*, and ties are broken by lexicographic UUID comparison, so
the order is total without coordination.

IDs serialize to strings whose lexicographic order equals the ID order, which
lets sorted storage listings double as timestamp-ordered commit logs.
"""

from __future__ import annotations

import threading
import time
import uuid as _uuid
from dataclasses import dataclass, field
from functools import total_ordering
from typing import Optional

# Width of the zero-padded timestamp in the string form.  64-bit nanosecond
# timestamps need at most 20 decimal digits.
_TS_WIDTH = 20


@total_ordering
@dataclass(frozen=True)
class TxnId:
    """A committed transaction's ID: ``⟨timestamp, uuid⟩`` (§3.1)."""

    timestamp: int
    uuid: str

    # -- total order -------------------------------------------------------
    def __lt__(self, other: "TxnId") -> bool:
        if not isinstance(other, TxnId):
            return NotImplemented
        return (self.timestamp, self.uuid) < (other.timestamp, other.uuid)

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, TxnId):
            return NotImplemented
        return (self.timestamp, self.uuid) == (other.timestamp, other.uuid)

    def __hash__(self) -> int:
        return hash((self.timestamp, self.uuid))

    # -- serialization -----------------------------------------------------
    def encode(self) -> str:
        """Lexicographically order-preserving string form."""
        return f"{self.timestamp:0{_TS_WIDTH}d}.{self.uuid}"

    @staticmethod
    def decode(s: str) -> "TxnId":
        ts, _, u = s.partition(".")
        return TxnId(timestamp=int(ts), uuid=u)

    def __repr__(self) -> str:  # compact for logs
        return f"Txn({self.timestamp}.{self.uuid[:8]})"


class Clock:
    """Strictly-monotonic per-node clock.

    The paper uses each machine's local system clock; we additionally force
    strict monotonicity within a process so that two commits on the same node
    never share a timestamp (across nodes, UUIDs break ties).  A ``skew_ns``
    offset supports tests that deliberately de-synchronize node clocks to
    check that correctness holds without synchronized time.
    """

    def __init__(self, skew_ns: int = 0):
        self._last = 0
        self._skew = skew_ns
        self._lock = threading.Lock()

    def now_ns(self) -> int:
        with self._lock:
            t = time.time_ns() + self._skew
            if t <= self._last:
                t = self._last + 1
            self._last = t
            return t


def fresh_uuid() -> str:
    return _uuid.uuid4().hex


@dataclass
class TxnHandle:
    """Client-visible handle for an *in-flight* transaction.

    Before commit only the UUID exists (the timestamp is assigned at commit
    time, §3.1); the handle also remembers which node owns the session so that
    multi-function requests route every operation to a single AFT node.
    """

    uuid: str = field(default_factory=fresh_uuid)
    node_id: Optional[str] = None

    def __str__(self) -> str:
        return self.uuid
