"""Algorithm 2 — IsTransactionSuperseded (§4.1).

A transaction ``T_i`` is *locally superseded* when, for every key it wrote,
the local index already knows a strictly newer committed version.  Superseded
transactions are (a) omitted from multicast (§4.1), (b) eligible for local
metadata GC (§5.1), and (c) candidates for global data GC (§5.2).

Supersedence can be decided without coordination because each node's known
version set for any key only *grows* (commits are never retracted): once a
transaction is superseded at a node, it stays superseded there.
"""

from __future__ import annotations

from typing import Iterable, List

from .commit_cache import CommitSetCache
from .ids import TxnId
from .records import TransactionRecord


def is_superseded(record: TransactionRecord, cache: CommitSetCache) -> bool:
    """Algorithm 2 over the node's key-version index."""
    for key in record.write_set:
        latest = cache.latest_version_of(key)
        # ``latest`` can only be ≥ record.tid if the record is indexed; if the
        # record was already pruned locally, a missing key entry means we
        # cannot prove supersedence — be conservative.
        if latest is None or latest <= record.tid:
            return False
    return True


def superseded_subset(
    records: Iterable[TransactionRecord], cache: CommitSetCache
) -> List[TransactionRecord]:
    return [r for r in records if is_superseded(r, cache)]
