"""AftNode — the per-node transaction manager (§3).

Implements the Table-1 API (Start/Get/Put/Commit/Abort) with:

* the write-ordering commit protocol (§3.3): buffer → persist versions →
  persist commit record → acknowledge → make visible;
* Algorithm 1 reads (§3.4) over the local Commit Set Cache / key version
  index, yielding dynamically-constructed Atomic Readsets;
* read-your-writes (which bypasses Algorithm 1, §3.5) and repeatable reads
  (a corollary of Theorem 1 — the default path *re-runs* Algorithm 1 and the
  property tests assert the corollary emerges; ``fast_repeatable_read`` turns
  on the short-circuit);
* idempotent commits keyed by the transaction UUID (§3.3.1) so retries give
  exactly-once semantics;
* hooks for the distributed layer (§4): fresh-commit draining for multicast,
  remote-commit merging with supersedence filtering, local metadata GC and
  the locally-deleted log the global GC consumes (§5).

Every public method is thread-safe; a node serves many concurrent client
sessions (FaaS functions, trainer hosts, serving replicas).
"""

from __future__ import annotations

import threading
import time
import warnings
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Deque, Dict, Iterable, List, Optional, Set, Tuple

from ..obs import trace as obs_trace
from ..obs.registry import Registry
from ..storage.base import StorageEngine
from ..storage.pipeline import PipelineConfig, StorageIOPipeline
from .atomic_read import (
    ReadSelection,
    ReadStatus,
    SessionReadState,
    atomic_read_select,
    atomic_read_select_incremental,
)
from .commit_cache import CommitSetCache, DataCache
from .errors import (
    NodeFailed,
    ReadAbortError,
    ReadOnlyTransaction,
    SnapshotUnavailable,
    TransactionNotRunning,
    UnknownTransaction,
)
from .ids import Clock, TxnHandle, TxnId, fresh_uuid
from .records import (
    CHAIN_CLAIM_SUFFIX,
    CHAIN_ENQ_SUFFIX,
    COMMIT_PREFIX,
    TRIGGER_PREFIX,
    TransactionRecord,
    WF_MEMO_TXN_INFIX,
    WF_STEP_TXN_INFIX,
    WORKFLOW_MEMO_PREFIX,
    commit_key,
    data_key,
    encode_cache_stats,
    lookup_committed_record,
    uuid_key,
)
from .supersede import is_superseded
from .write_buffer import TransactionWriteBuffer


@dataclass
class AftNodeConfig:
    node_id: str = "aft-0"
    data_cache_bytes: int = 64 * 1024 * 1024
    enable_data_cache: bool = True
    write_buffer_max_bytes: int = 256 * 1024 * 1024
    multicast_interval_s: float = 1.0     # §4: "every 1 second"
    gc_interval_s: float = 1.0
    txn_timeout_s: float = 60.0           # §3.3.1 abort-after-timeout
    bootstrap_scan_limit: int = 10_000    # "latest records" warmed at start
    fast_repeatable_read: bool = False    # short-circuit vs re-running Alg. 1
    verify_uuid_on_retry: bool = True     # §3.3.1 cross-node retry safety:
                                          # scan the Commit Set before
                                          # committing an unfamiliar retried
                                          # UUID (rare path only)
    storage_read_retries: int = 3
    storage_read_retry_s: float = 0.02    # scaled by the engine's time_scale
    # --- metadata hot path ------------------------------------------------
    # lock striping of the CommitSetCache (1 = the old single global lock)
    cache_stripes: int = 16
    # per-session incremental Algorithm-1 lower bounds: O(candidates) per
    # read instead of rescanning the whole read set under the coarse lock
    # (False = the retained reference oracle, used as the benchmark baseline)
    incremental_reads: bool = True
    min_gc_age_s: float = 0.0             # §5.2.1 mitigation knob
    clock_skew_ns: int = 0                # tests: protocols don't need sync
    # --- asynchronous storage I/O pipeline (storage/pipeline.py) ---------
    # The pipeline is created lazily, on first async use (async commit, GC
    # deletes): purely synchronous workloads never pay for its threads and
    # behave byte-for-byte as before.
    enable_io_pipeline: bool = True
    io_workers: int = 4                   # read/probe/task threads per node
    flush_max_items: int = 25             # BatchWriteItem-style page size
    flush_linger_ms: float = 8.0          # coalescing window, engine-ms
    flush_concurrency: int = 2            # flushes on the wire at once
    # prefetch the rest of a commit record's write set when one of its keys
    # is read (Algorithm-1 readsets are built from cowritten sets, so the
    # sibling keys are the likeliest next reads); active only once the
    # pipeline exists
    prefetch_cowritten: bool = True


class TxnState(Enum):
    RUNNING = "running"
    COMMITTED = "committed"
    ABORTED = "aborted"


_stats_deprecation_warned = False


class NodeStats(dict):
    """Counter map that is also callable.

    Dict access (``node.stats["commits"]``) keeps the historical counter
    surface; calling it (``node.stats()``) returns a *thread-safe snapshot*
    with derived gauges — open sessions, in-flight ops, data-cache hit
    rate — taken under the node lock.  Benchmark reports and legacy tests
    are the remaining consumers; routing policies (``core/routing.py``)
    read ``node.registry.snapshot()`` directly.

    Deprecation shim: the snapshot is now assembled by the node's metrics
    registry (``node.registry``, ``repro/obs/registry.py``); calling
    ``node.stats()`` still returns the same key set, but new code should
    read ``node.registry.snapshot()`` (which additionally carries the
    commit-phase latency histograms)."""

    def __init__(self, counters: Dict[str, int], snapshot_fn) -> None:
        super().__init__(counters)
        self._snapshot_fn = snapshot_fn

    def __call__(self) -> Dict[str, float]:
        global _stats_deprecation_warned
        if not _stats_deprecation_warned:
            _stats_deprecation_warned = True
            warnings.warn(
                "AftNode.stats() is a deprecated read path; use "
                "node.registry.snapshot() (repro.obs.registry) instead",
                DeprecationWarning,
                stacklevel=2,
            )
        return self._snapshot_fn()


@dataclass
class TransactionContext:
    uuid: str
    buffer: TransactionWriteBuffer
    read_set: Dict[str, TxnId] = field(default_factory=dict)
    state: TxnState = TxnState.RUNNING
    started_at: float = field(default_factory=time.monotonic)
    committed_tid: Optional[TxnId] = None
    is_retry: bool = False  # client reopened with a prior UUID (§3.3.1)
    # declared read-only lane: reads stay fully Algorithm-1 atomic, but the
    # commit skips version writes, the commit record AND the u/ index — a
    # buffered write is a contract violation (put raises)
    read_only: bool = False
    # a commit reached storage (version flush issued): from here on an
    # abort may be racing a commit that actually LANDED (the lost-ack
    # window), so cleanup must not delete spilled bytes a durable commit
    # record may reference — the orphan GC, which checks commit state,
    # reclaims them instead
    commit_attempted: bool = False
    # an in-flight async commit (commit_transaction_async): concurrent
    # committers of one session share it instead of double-committing
    commit_future: Optional[Future] = None
    # incremental Algorithm-1 state: key → newest cowriting tid among prior
    # reads (case-1 lower bounds), folded in as reads join the read set
    read_state: SessionReadState = field(default_factory=SessionReadState)
    # guards read_set (and read_state): one session may be driven by many
    # parallel branches of a workflow DAG (the buffer has its own lock)
    lock: threading.Lock = field(default_factory=threading.Lock)

    def read_set_snapshot(self) -> Dict[str, TxnId]:
        with self.lock:
            return dict(self.read_set)


@dataclass(frozen=True)
class SnapshotResult:
    """Outcome of a bounded-staleness snapshot read (``snapshot_read``):
    the freshest committed version at-or-below the gossiped read watermark.
    ``tid is None`` ⇔ no committed version of the key existed at the
    watermark (``value`` is then ``None`` too)."""

    value: Optional[bytes]
    tid: Optional[TxnId]
    watermark_ns: int
    lag_ns: int


class AftNode:
    def __init__(
        self,
        storage: StorageEngine,
        config: Optional[AftNodeConfig] = None,
        *,
        bootstrap: bool = True,
        registry: Optional[Registry] = None,
    ) -> None:
        self.storage = storage
        self.config = config or AftNodeConfig()
        self.node_id = self.config.node_id
        # unified metrics registry (repro/obs): each node owns one unless the
        # caller shares theirs; legacy stats dicts attach as live views
        self.registry = registry or Registry(
            name=self.node_id,
            time_scale=getattr(storage, "time_scale", 1.0),
        )
        self.clock = Clock(skew_ns=self.config.clock_skew_ns)
        self.cache = CommitSetCache(stripes=max(1, self.config.cache_stripes))
        self.data_cache = DataCache(self.config.data_cache_bytes)
        self._txns: Dict[str, TransactionContext] = {}
        self._committed_uuids: Dict[str, TxnId] = {}
        self._locally_deleted: Set[TxnId] = set()
        # w/<uuid> finish markers this node's GC agent has fully consumed
        # (storage sweep + own-cache purge); the fault manager gates marker
        # retirement on every live node having acked (core/fault_manager.py)
        self._acked_markers: Set[str] = set()
        self._lock = threading.RLock()
        self._alive = True
        self._inflight_ops = 0  # get/put/commit currently executing
        # gossip-plane hooks (core/multicast.py wires these): the commit
        # listener eagerly pushes each freshly-visible record to peers; the
        # watermark provider supplies the min-over-peers horizon floor
        self._commit_listener: Optional[
            Callable[[TransactionRecord], None]] = None
        self._watermark_provider: Optional[
            Callable[[], Optional[int]]] = None
        # uuid → minted commit timestamp of commits between tid assignment
        # and visibility; the commit horizon is capped strictly below the
        # earliest of these, so a horizon announcement can never cover a
        # commit whose record is not yet durable
        self._inflight_commit_ts: Dict[str, int] = {}
        # asynchronous I/O pipeline: created lazily on first async use, so
        # synchronous workloads never start its threads
        self._pipeline: Optional[StorageIOPipeline] = None
        # commit-latency samples (seconds) for the legacy stats() snapshot
        # (routing reads the registry's commit.total histogram instead).
        # _lat_lock guards iteration-vs-append: sorting a deque while a
        # committer appends raises "deque mutated during iteration".
        self._commit_lat: Deque[float] = deque(maxlen=1024)
        self._lat_lock = threading.Lock()
        self._prefetched_tids: Set[TxnId] = set()
        self.stats: NodeStats = NodeStats(
            {
                "reads": 0,
                "read_cache_hits": 0,
                "ryw_hits": 0,
                "writes": 0,
                "commits": 0,
                "async_commits": 0,
                "probe_cache_hits": 0,
                "snapshot_reads": 0,
                "snapshot_unavailable": 0,
                "prefetched_keys": 0,
                "aborts": 0,
                "staleness_aborts": 0,
                "warmup_records_in": 0,
                "handoff_records_out": 0,
                "remote_merges": 0,
                "remote_skipped_superseded": 0,
                "gc_removed": 0,
            },
            self._stats_snapshot,
        )
        # registry wiring: counters stay the live dict above (writers keep
        # doing ``stats["x"] += 1``), derived gauges come from a provider,
        # and the commit path decomposes into phase histograms (ISSUE 6)
        self.registry.attach_counters(self.stats)
        self.registry.attach_provider(self._gauges)
        self._h_commit = self.registry.histogram("commit.total")
        self._h_version_flush = self.registry.histogram("commit.version_flush")
        self._h_probe = self.registry.histogram("commit.probe")
        self._h_record_write = self.registry.histogram("commit.record_write")
        # Algorithm-1 selection time per read (metadata-only: the storage
        # fetch is excluded) — the hot-path benchmark's headline histogram
        self._h_read_resolve = self.registry.histogram("read.resolve")
        if bootstrap:
            self.bootstrap()

    # ------------------------------------------------------------------ util
    def _check_alive(self) -> None:
        if not self._alive:
            raise NodeFailed(f"node {self.node_id} is down")

    def fail(self) -> None:
        """Simulate a node crash: all in-flight transactions are lost (§3.3.1);
        committed data survives in storage by the write-ordering protocol."""
        with self._lock:
            self._alive = False
            self._txns.clear()

    @property
    def alive(self) -> bool:
        return self._alive

    def _ctx(self, txid: str) -> TransactionContext:
        with self._lock:
            ctx = self._txns.get(txid)
        if ctx is None:
            raise UnknownTransaction(txid)
        return ctx

    def _op_begin(self) -> None:
        with self._lock:
            self._inflight_ops += 1

    def _op_end(self) -> None:
        with self._lock:
            self._inflight_ops -= 1

    # --------------------------------------------------------- I/O pipeline
    def io_pipeline(self, create: bool = True) -> Optional[StorageIOPipeline]:
        """The node's asynchronous storage pipeline, created on first use
        (``None`` when ``enable_io_pipeline`` is off).  ``create=False``
        returns the pipeline only if async work already started it —
        opportunistic users (GC sweeps) use that so a purely synchronous
        deployment never grows pipeline threads or prefetch traffic."""
        if not self.config.enable_io_pipeline:
            return None
        with self._lock:
            if self._pipeline is None:
                if not create:
                    return None
                self._pipeline = StorageIOPipeline(
                    self.storage,
                    PipelineConfig(
                        io_workers=self.config.io_workers,
                        flush_max_items=self.config.flush_max_items,
                        flush_linger_ms=self.config.flush_linger_ms,
                        flush_concurrency=self.config.flush_concurrency,
                        name=f"io-{self.node_id}",
                    ),
                    registry=self.registry,
                )
            return self._pipeline

    def drain_pipeline(self, timeout: Optional[float] = None) -> None:
        """Block until every enqueued pipeline write/delete has landed (a
        no-op without a pipeline).  Drivers call this at shutdown so
        fire-and-forget work (offloaded memo saves) is durable before the
        process moves on."""
        with self._lock:
            pipe = self._pipeline
        if pipe is not None:
            pipe.drain(timeout)

    def close_pipeline(self) -> None:
        """Tear down the pipeline's threads (cluster shutdown / node
        removal).  A crashed node (:meth:`fail`) deliberately does NOT close
        it: in-flight flushes may still land, which is exactly the §3.3
        partial-durability window the protocol tolerates."""
        with self._lock:
            pipe, self._pipeline = self._pipeline, None
        if pipe is not None:
            pipe.close()

    def _storage_time_scale(self) -> float:
        """Latency compression of a simulated engine (1.0 for real ones);
        wall-clock protocol waits must shrink with the ops they pace."""
        return getattr(self.storage, "time_scale", 1.0)

    def _gauges(self) -> Dict[str, float]:
        """Derived gauges, sampled by the registry at snapshot time."""
        with self._lock:
            snap: Dict[str, float] = {}
            snap["open_sessions"] = sum(
                1 for c in self._txns.values() if c.state is TxnState.RUNNING
            )
            snap["inflight_ops"] = self._inflight_ops
            snap["metadata_records"] = len(self.cache)
            snap["alive"] = 1 if self._alive else 0
        dc = self.data_cache.stats()
        snap["data_cache_hits"] = dc["hits"]
        snap["data_cache_misses"] = dc["misses"]
        snap["data_cache_entries"] = dc["entries"]
        snap["data_cache_bytes"] = dc["bytes"]
        lookups = dc["hits"] + dc["misses"]
        snap["data_cache_hit_rate"] = dc["hits"] / lookups if lookups else 0.0
        snap["data_cache_evictions"] = dc["evictions"]
        # commit-set-cache stripe-lock contention (per node)
        ls = self.cache.lock_stats()
        snap["cache_lock_acquires"] = ls["acquires"]
        snap["cache_lock_contended"] = ls["contended"]
        snap["cache_lock_wait_ms"] = ls["wait_ms"]
        # record encode-once cache (process-wide counters: every node in
        # this process shares the module-level memoization accounting)
        enc = encode_cache_stats()
        snap["record_encode_hits"] = enc["hits"]
        snap["record_encode_misses"] = enc["misses"]
        pipe = self._pipeline
        if pipe is not None:
            for k, v in pipe.stats().items():
                snap[f"io_{k}"] = v
        # watermark lag: how far the snapshot lane trails real time (0 on a
        # peerless node).  Outside the locked block — commit_horizon_ns
        # takes the node lock itself and the provider may take cluster locks.
        if self._alive:
            try:
                snap["read_watermark_lag_ms"] = max(
                    0, self.clock.now_ns() - self.read_watermark_ns()) / 1e6
            except Exception:
                pass  # provider racing a membership change; gauge is best-effort
        return snap

    def _stats_snapshot(self) -> Dict[str, float]:
        """Thread-safe point-in-time view: counters + derived gauges.
        This is ``node.stats()`` — see :class:`NodeStats`.  The snapshot is
        read through the metrics registry (counters and gauges are attached
        there); histogram summaries are flattened back to the historical
        ``commit_p50_ms``/``commit_p99_ms`` keys."""
        snap: Dict[str, float] = {
            k: v for k, v in self.registry.snapshot().items()
            if not isinstance(v, dict)
        }
        with self._lat_lock:
            lat = sorted(self._commit_lat)
        if lat:
            snap["commit_p50_ms"] = lat[len(lat) // 2] * 1e3
            snap["commit_p99_ms"] = lat[min(len(lat) - 1,
                                            int(len(lat) * 0.99))] * 1e3
        return snap

    # ------------------------------- gossip plane: horizons & the watermark
    def set_commit_listener(
        self, fn: Optional[Callable[[TransactionRecord], None]]
    ) -> None:
        """Install the eager-push hook: called with each commit's record the
        moment it becomes visible (§3.3 step 3).  Best-effort — exceptions
        are swallowed (the fault manager's anti-entropy heals lost pushes)."""
        self._commit_listener = fn

    def set_watermark_provider(
        self, fn: Optional[Callable[[], Optional[int]]]
    ) -> None:
        """Install the peer-horizon floor: a callable returning the minimum
        commit horizon gossiped by live peers, or ``None`` when the node has
        no peers (its own horizon then stands alone)."""
        self._watermark_provider = fn

    def commit_horizon_ns(self) -> int:
        """Timestamp h such that every transaction this node has committed
        (or will ever commit) with timestamp ≤ h is durably recorded: the
        clock now, capped strictly below the earliest in-flight commit's
        minted timestamp.  Sound because tids are minted and registered
        in-flight atomically under the node lock against a strictly
        monotonic clock."""
        with self._lock:
            now = self.clock.now_ns()
            if self._inflight_commit_ts:
                return min(now, min(self._inflight_commit_ts.values()) - 1)
            return now

    def read_watermark_ns(self) -> int:
        """The snapshot lane's staleness frontier: every commit anywhere in
        the cluster with timestamp ≤ the watermark has been durably recorded
        AND announced to this node (contiguity-gated horizon tracking in
        ``core/multicast.py`` is what upgrades "durable" to "announced")."""
        own = self.commit_horizon_ns()
        provider = self._watermark_provider
        if provider is None:
            return own
        floor = provider()
        if floor is None:
            return own
        return min(own, floor)

    def snapshot_read(
        self, key: str, max_staleness_s: float
    ) -> SnapshotResult:
        """Bounded-staleness snapshot read: resolve the freshest committed
        version of ``key`` at-or-below the gossiped read watermark, without
        a transaction and without any storage probe for rivals.  Raises
        :class:`SnapshotUnavailable` when the watermark trails ``now`` by
        more than the declared bound (gossip stalled/partitioned) — the
        lane degrades to unavailability, never to out-of-bound staleness."""
        self._check_alive()
        self.stats["snapshot_reads"] += 1
        wm = self.read_watermark_ns()
        lag_ns = max(0, self.clock.now_ns() - wm)
        bound_ns = int(max_staleness_s * 1e9)
        if lag_ns > bound_ns:
            self.stats["snapshot_unavailable"] += 1
            raise SnapshotUnavailable(
                f"read watermark lags {lag_ns / 1e6:.1f} ms > declared "
                f"bound {bound_ns / 1e6:.1f} ms for {key!r}"
            )
        tid = self.cache.latest_version_at(key, wm)
        # GC fence: §5.1 pruning removes superseded versions from the cache
        # (and their data from storage), so a resolution is only complete if
        # every version ever pruned for this key is at-or-below what we
        # resolved — otherwise a pruned version may have sat inside
        # (resolved, wm] and the answer would be silently stale.  The
        # newest version of a key is never superseded, hence never pruned:
        # once the watermark covers it this fence always passes.
        pruned = self.cache.pruned_max_ts(key)
        if pruned > (tid.timestamp if tid is not None else -1):
            self.stats["snapshot_unavailable"] += 1
            raise SnapshotUnavailable(
                f"local GC pruned versions of {key!r} up to ts {pruned} "
                f"past the resolution at watermark {wm} — cannot prove the "
                f"snapshot complete"
            )
        # a superseded version's DATA can be reclaimed by the §5.2 global
        # GC before this node's local prune runs (record still cached, so
        # the fence above cannot see it) — an unreadable version degrades
        # to unavailability, never to serving a different version
        try:
            value = self._fetch(key, tid) if tid is not None else None
        except ReadAbortError as exc:
            self.stats["snapshot_unavailable"] += 1
            raise SnapshotUnavailable(
                f"resolved version of {key!r} at watermark {wm} was "
                f"reclaimed by GC before it could be served: {exc}"
            ) from exc
        tracer = obs_trace.get_tracer()
        if tracer.enabled:
            # replayed by the offline checker's snapshot-bound invariant
            tracer.emit(
                "snap",
                key=key,
                tid=tid.encode() if tid is not None else None,
                wm=wm,
                lag_ns=lag_ns,
                bound_ns=bound_ns,
            )
        return SnapshotResult(value=value, tid=tid,
                              watermark_ns=wm, lag_ns=lag_ns)

    def _register_inflight(self, uuid: str, ts_ns: int) -> None:
        with self._lock:
            self._inflight_commit_ts[uuid] = ts_ns

    def _clear_inflight(self, uuid: str) -> None:
        with self._lock:
            self._inflight_commit_ts.pop(uuid, None)

    def _mint_tid(self, ctx: TransactionContext) -> TxnId:
        """Assign the commit timestamp and register it in-flight in ONE
        locked step, so no horizon computed in between can cover it."""
        with self._lock:
            tid = TxnId(self.clock.now_ns(), ctx.uuid)
            self._inflight_commit_ts[ctx.uuid] = tid.timestamp
            return tid

    # ------------------------------------------------------------- bootstrap
    def bootstrap(self) -> int:
        """Warm the metadata cache from the durable Transaction Commit Set
        (§3.1).  Called at node start / recovery; returns records loaded."""
        keys = self.storage.list_keys(COMMIT_PREFIX)
        keys = keys[-self.config.bootstrap_scan_limit :]
        loaded = 0
        if not keys:
            return 0
        raws = self.storage.get_batch(keys)
        # coarse all-stripes section: warm-up is the one bulk-load where a
        # single frozen view beats striped fine-grained locking (§3.1)
        with self.cache.global_section():
            for k in keys:
                raw = raws.get(k)
                if raw is None:
                    continue
                record = TransactionRecord.decode(raw)
                if self.cache.add(record):
                    self._committed_uuids[record.tid.uuid] = record.tid
                    loaded += 1
        return loaded

    # ------------------------------------------------------------- Table 1
    def start_transaction(
        self, uuid: Optional[str] = None, *, fresh: bool = False,
        read_only: bool = False,
    ) -> str:
        """StartTransaction() → txid.  A retried request may pass its old
        UUID to continue/recommit the same logical transaction (§3.3.1).
        ``fresh=True`` declares a *supplied* UUID newly minted — the caller
        generated it this attempt and nobody else can know it — so the
        commit path skips the §3.3.1 already-committed probe (one storage
        read per commit).  Workflow drivers pass it on the first attempt of
        locally-generated workflow UUIDs; anything deterministic or
        re-driven (retries, chain children, explicit resumes) must not.
        ``read_only=True`` declares the transaction will never write: reads
        stay fully Algorithm-1 atomic, ``put`` raises, and the commit is
        local-only — no version flush, no commit record, no ``u/`` index,
        no §3.3.1 probe (there is no durable effect to deduplicate)."""
        self._check_alive()
        is_retry = uuid is not None and not fresh
        uuid = uuid or fresh_uuid()
        with self._lock:
            if uuid not in self._txns or self._txns[uuid].state is not TxnState.RUNNING:
                self._txns[uuid] = TransactionContext(
                    uuid=uuid,
                    buffer=TransactionWriteBuffer(
                        uuid, self.storage, self.config.write_buffer_max_bytes
                    ),
                    is_retry=is_retry,
                    read_only=read_only,
                )
        return uuid

    def put(self, txid: str, key: str, value: bytes) -> None:
        self._check_alive()
        ctx = self._ctx(txid)
        if ctx.state is not TxnState.RUNNING:
            raise TransactionNotRunning(txid)
        if ctx.read_only:
            raise ReadOnlyTransaction(
                f"transaction {txid} was declared read_only; its commit "
                "would never persist this write"
            )
        self._op_begin()
        try:
            ctx.buffer.put(key, value)
        finally:
            self._op_end()
        self.stats["writes"] += 1

    def get(self, txid: str, key: str) -> Optional[bytes]:
        """Get(txid, key) → value.  Raises ReadAbortError when Algorithm 1
        finds no valid version (§3.6)."""
        value, _tid = self.get_versioned(txid, key)
        return value

    def get_versioned(self, txid: str, key: str) -> Tuple[Optional[bytes], Optional[TxnId]]:
        self._check_alive()
        ctx = self._ctx(txid)
        if ctx.state is not TxnState.RUNNING:
            raise TransactionNotRunning(txid)
        self.stats["reads"] += 1
        self._op_begin()
        try:
            # (1) read-your-writes takes precedence (§3.5) — buffered versions
            # have no commit timestamp yet, so they live outside Algorithm 1.
            hit, value = ctx.buffer.get(key)
            if hit:
                self.stats["ryw_hits"] += 1
                return value, None

            # (2) repeatable-read short-circuit (optional; Corollary 1.1 proves
            # Algorithm 1 returns the same version anyway).
            if self.config.fast_repeatable_read:
                with ctx.lock:
                    prior = ctx.read_set.get(key)
                if prior is not None:
                    return self._fetch(key, prior), prior

            # (3) Algorithm 1 — selection and read-set insertion are ONE atomic
            # step per session: parallel DAG branches selecting against stale
            # snapshots could otherwise each pass Definition 1 individually yet
            # insert disjoint keys that are jointly fractured (e.g. m@old and
            # k@T with T cowriting {m, k}).  Lock order is ctx.lock → cache
            # stripe locks (inside the select); nothing takes them in reverse.
            # The storage fetch stays outside the lock.
            with ctx.lock:
                t_sel = time.perf_counter()
                if self.config.incremental_reads:
                    sel, rec = atomic_read_select_incremental(
                        key, ctx.read_set, self.cache, ctx.read_state)
                else:  # retained coarse-lock reference oracle
                    sel = atomic_read_select(key, ctx.read_set, self.cache)
                    rec = (self.cache.get(sel.tid)
                           if sel.tid is not None else None)
                self._h_read_resolve.observe_s(time.perf_counter() - t_sel)
                if sel.status is ReadStatus.NOT_FOUND:
                    return None, None
                if sel.status is ReadStatus.NO_VALID_VERSION:
                    self.stats["staleness_aborts"] += 1
                    raise ReadAbortError(
                        f"no version of {key!r} joins the atomic readset of {txid}"
                    )
                assert sel.tid is not None
                ctx.read_set[key] = sel.tid  # line 24: R_new = R ∪ {k_target}
                ctx.read_state.note_read(rec)  # fold case-1 bounds in once
                chosen = sel.tid
            value = self._fetch(key, chosen)
            tracer = obs_trace.get_tracer()
            if tracer.enabled:
                # the offline checker (repro/obs/checker.py) replays these
                # to re-derive Definition-1 read atomicity from the log alone
                tracer.emit(
                    "read",
                    txn=ctx.uuid,
                    trace=obs_trace.txn_trace_id(ctx.uuid),
                    key=key,
                    tid=chosen.encode(),
                    cow=list(rec.write_set) if rec is not None else [key],
                )
            return value, chosen
        finally:
            self._op_end()

    def claim_queue_entry(
        self, txid: str, entry_key: str, claim_key: str, claim_payload: bytes
    ) -> Tuple[Optional[bytes], Optional[bytes], bool]:
        """Trigger-queue claim: SELECT the entry + any prior claim and INSERT
        this session's claim, as Algorithm-1 reads and a buffered write on
        ONE session (chaining, ``repro/workflow/chain.py``).

        The atomicity story is the per-session lock: claim transactions use
        the *deterministic* UUID ``<entry>.claim``, so two consumers racing
        for the same entry land in the SAME transaction context here
        (``start_transaction`` reuses a RUNNING uuid) and their select+insert
        steps serialize on ``ctx.lock`` inside ``get``/``put``.  Read-your-
        writes then surfaces a sharer's buffered claim as ``prior``, and the
        eventual commit is idempotent (§3.3.1) — across nodes, the durable
        ``u/<entry>.claim`` probe resolves the race instead.

        Returns ``(entry_bytes, prior_claim_bytes, prior_is_buffered)``; the
        claim is buffered only when the entry exists and no prior claim was
        visible.  ``prior_is_buffered`` distinguishes a co-located sharer's
        not-yet-committed claim (surfaced by read-your-writes; the caller
        must leave the shared context alone — aborting it would kill the
        sharer's in-flight commit) from a durably committed one (safe to
        abort this context: a racing sharer's commit still resolves through
        the §3.3.1 already-committed probe).  Claims are an ownership
        *hint*: correctness of chaining never depends on them (the child
        UUID is deterministic), so a lost race costs a redundant —
        idempotent — drive, never a duplicate effect.
        """
        entry = self.get(txid, entry_key)
        if entry is None:
            return None, None, False
        prior, prior_tid = self.get_versioned(txid, claim_key)
        if prior is None:
            self.put(txid, claim_key, claim_payload)
            return entry, None, False
        # a buffered (tid-less) prior means a sharer of this very context
        # wrote it between our two reads — it is theirs to commit
        return entry, prior, prior_tid is None

    def abort_transaction(self, txid: str) -> None:
        self._check_alive()
        ctx = self._ctx(txid)
        if ctx.state is not TxnState.RUNNING:
            return
        spilled = ctx.buffer.discard()
        ctx.state = TxnState.ABORTED
        self.stats["aborts"] += 1
        # Best-effort spill cleanup is safe ONLY for never-attempted
        # commits.  Once a commit reached storage, "commit failed" may
        # really be "commit landed, ack lost" — its durable record then
        # references the spilled keys, and deleting them would destroy
        # committed data.  The fault manager's orphan GC (which verifies
        # commit state) reclaims genuinely orphaned spills instead.
        if spilled and not ctx.commit_attempted:
            try:
                pipe = self._pipeline
                if pipe is not None:  # off the caller's thread, coalesced
                    pipe.submit_deletes(spilled)
                else:
                    self.storage.delete_batch(spilled)
            except Exception:
                pass  # orphan GC (fault manager) is the backstop

    def commit_transaction(self, txid: str) -> TxnId:
        """CommitTransaction(txid): persist updates, then the commit record,
        only then acknowledge + make visible (§3.3).  Idempotent per UUID."""
        self._check_alive()
        self._op_begin()
        t0 = time.perf_counter()
        try:
            return self._commit_transaction(txid)
        finally:
            dt = time.perf_counter() - t0
            with self._lat_lock:
                self._commit_lat.append(dt)
            self._h_commit.observe_s(dt)
            self._op_end()

    def _probe_already_committed(self, ctx: TransactionContext) -> Optional[TxnId]:
        """§3.3.1 idempotence check shared by both commit paths."""
        with self._lock:
            already = self._committed_uuids.get(ctx.uuid)
        if (already is not None and ctx.is_retry
                and self.config.verify_uuid_on_retry):
            # the gossip-fed commit-set cache answered a probe that would
            # otherwise have cost two storage point reads (§3.3.1 via §4)
            self.stats["probe_cache_hits"] += 1
        if already is None and ctx.is_retry and self.config.verify_uuid_on_retry:
            # A retried request landed on a node that has not yet heard (via
            # multicast/fault manager) whether the original commit succeeded.
            # The Commit Set in storage is the source of truth; the ``u/``
            # uuid → commit-key index makes the probe two point reads instead
            # of a full commit-set scan (§3.3.1, §4.2).  Workflow sessions
            # hit this path on *every* commit (deterministic UUIDs), so it
            # must be cheap.  An index entry without its commit record is a
            # crashed commit — treated as never committed, which is safe
            # because the index is written before the record.
            record = lookup_committed_record(self.storage, ctx.uuid)
            if record is not None:
                self.cache.add(record)
                with self._lock:
                    self._committed_uuids[ctx.uuid] = record.tid
                already = record.tid
        return already

    def _commit_transaction(self, txid: str) -> TxnId:
        ctx = self._ctx(txid)
        if ctx.read_only:
            return self._commit_read_only(ctx)
        already = self._probe_already_committed(ctx)
        if already is not None:  # §3.3.1 retry of a committed transaction
            ctx.state = TxnState.COMMITTED
            ctx.committed_tid = already
            return already
        if ctx.state is not TxnState.RUNNING:
            raise TransactionNotRunning(txid)

        tid = self._mint_tid(ctx)
        try:
            to_write, storage_keys = ctx.buffer.finalize(tid)
            write_set = tuple(sorted(storage_keys.keys()))

            if write_set:
                # step 1: persist all data versions (batched when the engine
                # supports it — AFT batches by default, §6.1.1), plus the
                # uuid → commit-key index used by the §3.3.1 retry probe.  The
                # index lands BEFORE the commit record: index ∧ record ⇔
                # committed, so a crash between the two reads as "not committed".
                to_write[uuid_key(ctx.uuid)] = commit_key(tid).encode()
                ctx.commit_attempted = True
                tracer = obs_trace.get_tracer()
                t_vf = time.perf_counter()
                self.storage.put_batch(to_write)
                self._h_version_flush.observe_s(time.perf_counter() - t_vf)
                if tracer.enabled:
                    tracer.emit("order", uuid=ctx.uuid, stage="versions")
                # step 2: persist the commit record — the *linearization point*
                # for durability; a crash before this line loses the txn (client
                # retries), a crash after it is a committed txn (§3.3.1).
                record = TransactionRecord(
                    tid=tid, write_set=write_set, storage_keys=dict(storage_keys)
                )
                # the record event is sequenced BEFORE the put: a remote
                # reader can observe the durable record the instant storage
                # acks it, i.e. before any post-put emission here could run —
                # which would invert trace order against the reader's read
                # event and trip the offline read-durability check on a
                # perfectly-ordered commit.  Nothing can serve the version in
                # the emit→durable window (the cache is populated only in
                # _commit_make_visible, and storage cannot return an
                # unwritten record), so sequencing at submit loses nothing.
                if tracer.enabled:
                    tracer.emit("order", uuid=ctx.uuid, stage="record",
                                writes=len(write_set), tid=tid.encode(),
                                keys=list(write_set))
                t_rec = time.perf_counter()
                self.storage.put(commit_key(tid), record.encode())
                self._h_record_write.observe_s(time.perf_counter() - t_rec)
                self._commit_make_visible(ctx, tid, record, to_write, storage_keys)
            else:
                # empty write set: nothing to persist or announce.
                with self._lock:
                    self._committed_uuids[ctx.uuid] = tid
                ctx.state = TxnState.COMMITTED
                ctx.committed_tid = tid
                self.stats["commits"] += 1
        finally:
            self._clear_inflight(ctx.uuid)
        return tid

    def _commit_read_only(self, ctx: TransactionContext) -> TxnId:
        """Commit the declared read-only lane: assign a local tid and flip
        state — nothing durable exists, so there is nothing to probe, flush,
        record or announce.  Deliberately does NOT touch ``_committed_uuids``:
        recording a uuid with no durable record would wrongly satisfy a
        later non-read-only retry's §3.3.1 idempotence check."""
        if ctx.state is not TxnState.RUNNING:
            if ctx.state is TxnState.COMMITTED and ctx.committed_tid is not None:
                return ctx.committed_tid  # idempotent re-commit
            raise TransactionNotRunning(ctx.uuid)
        tid = TxnId(self.clock.now_ns(), ctx.uuid)
        ctx.state = TxnState.COMMITTED
        ctx.committed_tid = tid
        self.stats["commits"] += 1
        return tid

    def _commit_make_visible(
        self, ctx: TransactionContext, tid: TxnId, record: TransactionRecord,
        to_write: Dict[str, bytes], storage_keys: Dict[str, str],
    ) -> None:
        """Step 3 of §3.3 — acknowledge + make visible locally — shared by
        the synchronous and pipelined commit paths so visibility semantics
        can never diverge between them."""
        with self._lock:
            self.cache.add(record, fresh=True)
            self._committed_uuids[ctx.uuid] = tid
        if self.config.enable_data_cache:
            for key, skey in storage_keys.items():
                raw = to_write.get(skey)
                if raw is not None:
                    self.data_cache.put(key, tid, raw)
        ctx.state = TxnState.COMMITTED
        ctx.committed_tid = tid
        self.stats["commits"] += 1
        tracer = obs_trace.get_tracer()
        if tracer.enabled:
            tracer.emit("order", uuid=ctx.uuid, stage="visible",
                        tid=tid.encode(),
                        trace=obs_trace.txn_trace_id(ctx.uuid))
        # eager gossip push BEFORE clearing the in-flight cap: a horizon
        # computed in between must not cover a commit whose announcement has
        # not yet been sequenced (core/multicast.py soundness argument)
        listener = self._commit_listener
        if listener is not None:
            try:
                listener(record)
            except Exception:
                pass  # best-effort; §4.2 anti-entropy heals lost pushes
        self._clear_inflight(ctx.uuid)

    # ---------------------------------------------------------- async commit
    def commit_transaction_async(self, txid: str) -> "Future[TxnId]":
        """CommitTransaction, pipelined: the whole §3.3 sequence runs on the
        storage I/O pipeline and the returned future resolves to the TxnId
        once the commit record is durable (or fails with the commit's
        error).  Semantics are identical to :meth:`commit_transaction` —
        same idempotence, same write ordering — but the *caller* never
        blocks on storage, and concurrent committers' version writes
        coalesce into shared group-commit flushes.

        Ordering is a barrier **per transaction**, not per op: the version
        bytes and the ``u/`` uuid index flush first (possibly sharing
        batches with other transactions), and only when that group's future
        resolves is the commit record submitted — so the record can never
        be durable before its versions, no matter how flushes interleave.
        Concurrent async commits of one session share a single future."""
        self._check_alive()
        ctx = self._ctx(txid)
        if ctx.read_only:  # local-only commit: nothing to pipeline
            fut_ro: "Future[TxnId]" = Future()
            try:
                fut_ro.set_result(self.commit_transaction(txid))
            except BaseException as exc:  # noqa: BLE001 - delivered via future
                fut_ro.set_exception(exc)
            return fut_ro
        pipeline = self.io_pipeline()
        if pipeline is None:  # pipeline disabled: degrade to the sync path
            fut: "Future[TxnId]" = Future()
            try:
                fut.set_result(self.commit_transaction(txid))
            except BaseException as exc:  # noqa: BLE001 - delivered via future
                fut.set_exception(exc)
            return fut
        with self._lock:
            if ctx.commit_future is not None and not ctx.commit_future.done():
                return ctx.commit_future
            result: "Future[TxnId]" = Future()
            ctx.commit_future = result
        self.stats["async_commits"] += 1
        self._op_begin()
        t0 = time.perf_counter()

        def settle(tid: Optional[TxnId] = None,
                   exc: Optional[BaseException] = None) -> None:
            self._clear_inflight(ctx.uuid)
            dt = time.perf_counter() - t0
            with self._lat_lock:
                self._commit_lat.append(dt)
            self._h_commit.observe_s(dt)
            self._op_end()
            if exc is not None:
                result.set_exception(exc)
            else:
                result.set_result(tid)

        try:
            # cheap local idempotence check on the caller's thread; the
            # expensive §3.3.1 storage probe (retried UUIDs only) runs as a
            # pipeline task CONCURRENTLY with the version flush below — it
            # only has to answer before the commit *record* is written.
            with self._lock:
                local_already = self._committed_uuids.get(ctx.uuid)
            if local_already is not None:
                if ctx.is_retry and self.config.verify_uuid_on_retry:
                    # gossip-fed cache answered instead of the storage probe
                    self.stats["probe_cache_hits"] += 1
                ctx.state = TxnState.COMMITTED
                ctx.committed_tid = local_already
                settle(local_already)
                return result
            if ctx.state is not TxnState.RUNNING:
                raise TransactionNotRunning(txid)
            tid = self._mint_tid(ctx)
            to_write, storage_keys = ctx.buffer.finalize(tid)
            write_set = tuple(sorted(storage_keys.keys()))
            need_probe = ctx.is_retry and self.config.verify_uuid_on_retry
            if not write_set:  # read-only: nothing to persist
                def finish_read_only() -> None:
                    try:
                        already = self._probe_already_committed(ctx)
                        final = already if already is not None else tid
                        if already is None:
                            with self._lock:
                                self._committed_uuids[ctx.uuid] = tid
                            self.stats["commits"] += 1
                        ctx.state = TxnState.COMMITTED
                        ctx.committed_tid = final
                        settle(final)
                    except BaseException as e:  # noqa: BLE001
                        settle(exc=e)

                if need_probe:
                    pipeline.submit_task(finish_read_only)
                else:
                    finish_read_only()
                return result
            # The u/ index is an in-place OVERWRITE, not a fresh version
            # key, so for retried UUIDs it must NOT ride the version flush:
            # repointing u/<uuid> at this (possibly never-recorded) tid
            # while the probe is still in flight could durably dangle the
            # index — and a later probe (fresh node, post-restart) would
            # read index-without-record as "not committed" and recommit a
            # DUPLICATE.  Fresh UUIDs have no prior index entry to damage,
            # so theirs coalesces into the version flush as before; retried
            # ones write it after the probe concludes, still before the
            # record (the §3.3.1 index ∧ record ⇔ committed contract).
            if not need_probe:
                to_write[uuid_key(ctx.uuid)] = commit_key(tid).encode()
            record = TransactionRecord(
                tid=tid, write_set=write_set, storage_keys=dict(storage_keys)
            )

            # mutable cell: advance() stamps the record-write submit time,
            # after_record reads it (the closures share this commit's scope)
            t_rec = [0.0]

            def after_record(f: Future) -> None:
                exc = f.exception()
                if exc is not None:
                    settle(exc=exc)
                    return
                try:
                    self._h_record_write.observe_s(
                        time.perf_counter() - t_rec[0])
                    self._commit_make_visible(
                        ctx, tid, record, to_write, storage_keys
                    )
                    settle(tid)
                except BaseException as e:  # noqa: BLE001
                    settle(exc=e)

            # join point: versions durable AND probe answered.  Writing the
            # versions of an already-committed retry is harmless (they are
            # invisible orphans, swept like any crashed attempt's); its u/
            # index repoint and commit RECORD are what §3.3.1 forbids — so
            # those two (and only those two) wait on the probe.
            join_state = {"versions": None, "probe": None}
            join_lock = threading.Lock()

            def advance() -> None:
                with join_lock:
                    if join_state["versions"] is None or join_state["probe"] is None:
                        return  # the other leg is still in flight
                    versions_exc, probe_out = join_state["versions"][0], join_state["probe"]
                    join_state["versions"] = join_state["probe"] = None  # fire once
                if versions_exc is not None:
                    settle(exc=versions_exc)
                    return
                probe_exc, already = probe_out
                if probe_exc is not None:
                    settle(exc=probe_exc)
                    return
                if already is not None:  # §3.3.1: a rival commit won; ours
                    ctx.state = TxnState.COMMITTED      # becomes orphans
                    ctx.committed_tid = already         # (u/ left untouched)
                    settle(already)
                    return
                try:
                    # §3.3 crash window: the versions + u/ index are
                    # durable here, but a node that died meanwhile never
                    # writes its commit record — the retry recommits.
                    self._check_alive()
                    # step 2: the commit record, ordered strictly after
                    # THIS transaction's version flush and index write (the
                    # put still coalesces with other transactions' I/O).
                    # Emitted at submit, not in after_record: a reader can
                    # observe the durable record from storage before this
                    # commit's completion callback is ever scheduled, and a
                    # post-hoc emission would sequence the record event
                    # after that read — a false read-durability violation
                    # in the offline checker (see the sync path's note).
                    tracer = obs_trace.get_tracer()
                    if tracer.enabled:
                        tracer.emit("order", uuid=ctx.uuid, stage="record",
                                    writes=len(write_set), tid=tid.encode(),
                                    keys=list(write_set))
                    t_rec[0] = time.perf_counter()
                    pipeline.submit_put(
                        commit_key(tid), record.encode()
                    ).add_done_callback(after_record)
                except BaseException as e:  # noqa: BLE001
                    settle(exc=e)

            def after_versions(f: Future) -> None:
                exc = f.exception()
                if exc is None:
                    # queue wait + coalesced flush, measured from commit
                    # start: the version-flush leg of the phase breakdown
                    self._h_version_flush.observe_s(time.perf_counter() - t0)
                    tracer = obs_trace.get_tracer()
                    if tracer.enabled:
                        tracer.emit("order", uuid=ctx.uuid, stage="versions")
                with join_lock:
                    join_state["versions"] = (exc,)
                advance()

            def probe_done(out) -> None:
                if need_probe:
                    self._h_probe.observe_s(time.perf_counter() - t0)
                with join_lock:
                    join_state["probe"] = out
                advance()

            def probe_concluded(out) -> None:
                """The probe's verdict is in.  Not-committed ⇒ NOW repoint
                the u/ index (withheld from the version flush — see above)
                and complete the probe leg only once it is durable: the
                index write runs concurrent with the still-in-flight
                version flush, and the record (gated by the join) stays
                ordered after both."""
                exc, already = out
                if exc is not None or already is not None:
                    probe_done(out)
                    return
                try:
                    self._check_alive()
                    pipeline.submit_put(
                        uuid_key(ctx.uuid), commit_key(tid).encode()
                    ).add_done_callback(
                        lambda f: probe_done((f.exception(), None))
                    )
                except BaseException as e:  # noqa: BLE001
                    probe_done((e, None))

            def probe_found(record: TransactionRecord) -> None:
                self.cache.add(record)
                with self._lock:
                    self._committed_uuids[ctx.uuid] = record.tid
                probe_done((None, record.tid))

            # The §3.3.1 storage probe as a callback chain over PIPELINED
            # reads: the two point lookups (u/ index, then the record)
            # coalesce into shared batch-gets with other in-flight commits'
            # probes, and no worker thread ever blocks waiting for them.
            def on_record_raw(f: Future) -> None:
                try:
                    raw = f.result()
                    if raw is None:  # index without record: crashed commit
                        probe_concluded((None, None))
                        return
                    probe_found(TransactionRecord.decode(raw))
                except BaseException as e:  # noqa: BLE001
                    probe_done((e, None))

            def on_index_ptr(f: Future) -> None:
                try:
                    ptr = f.result()
                    if ptr is None:
                        probe_concluded((None, None))
                        return
                    pipeline.submit_get(
                        ptr.decode()
                    ).add_done_callback(on_record_raw)
                except BaseException as e:  # noqa: BLE001
                    probe_done((e, None))

            # step 1: all data versions + the uuid → commit-key index,
            # group-committed with whatever else is in flight (§6.1.1
            # batching, lifted across transactions).
            if need_probe:
                pipeline.submit_get(
                    uuid_key(ctx.uuid)
                ).add_done_callback(on_index_ptr)
            else:
                with join_lock:
                    join_state["probe"] = (None, None)
            ctx.commit_attempted = True
            pipeline.submit_puts(to_write).add_done_callback(after_versions)
        except BaseException as exc:  # noqa: BLE001
            settle(exc=exc)
        return result

    # ---------------------------------------------------------------- reads
    def _fetch(self, key: str, tid: TxnId) -> bytes:
        """Line 25: storage.get(k_target), through the data cache (§3.1)."""
        if self.config.enable_data_cache:
            cached = self.data_cache.get(key, tid)
            if cached is not None:
                self.stats["read_cache_hits"] += 1
                return cached
        record = self.cache.get(tid)
        if record is not None:
            # kick off the pipelined prefetch of the record's OTHER keys
            # before the foreground read blocks, so they fetch in parallel
            self._maybe_prefetch_cowritten(record, exclude=key)
        skey = record.storage_key_for(key) if record else data_key(key, tid)
        value = None
        # Backoff paces a *storage* race, so it scales with the engine: a
        # simulated engine compresses op latency by time_scale, and a fixed
        # wall-clock sleep here would dwarf the op it waits on.
        retry_s = self.config.storage_read_retry_s * self._storage_time_scale()
        for attempt in range(self.config.storage_read_retries):
            value = self.storage.get(skey)
            if value is not None:
                break
            # Committed metadata exists ⇒ the version bytes were durably
            # acked before the commit record (§3.3); fresh-key read-after-
            # write makes a miss here transient (or a GC race, §5.2.1).
            time.sleep(retry_s * (attempt + 1))
        if value is None:
            self.stats["staleness_aborts"] += 1
            raise ReadAbortError(
                f"version bytes for {key!r}@{tid} unreadable (GC race?)"
            )
        if self.config.enable_data_cache:
            self.data_cache.put(key, tid, value)
        return value

    def _maybe_prefetch_cowritten(
        self, record: TransactionRecord, exclude: str
    ) -> None:
        """Pipelined read-set prefetch: a transaction that reads one key of
        a committed write set tends to read the rest (Algorithm 1 builds
        Atomic Readsets *from* cowritten sets), so fan the sibling versions
        out on the I/O pipeline into the data cache while the foreground
        ``get`` is still in flight.  Fires only when the pipeline already
        exists (async users) — purely synchronous workloads keep their
        exact pre-pipeline storage traffic."""
        if (
            not self.config.prefetch_cowritten
            or not self.config.enable_data_cache
            or len(record.write_set) <= 1
        ):
            return
        pipeline = self._pipeline
        if pipeline is None:
            return
        with self._lock:
            if record.tid in self._prefetched_tids:
                return
            if len(self._prefetched_tids) > 4096:
                self._prefetched_tids.clear()
            self._prefetched_tids.add(record.tid)
        keys = [
            k for k in record.write_set
            if k != exclude and not self.data_cache.contains_key(k)
        ]

        def _install(key: str):
            def cb(f: Future) -> None:
                try:
                    value = f.result()
                except Exception:
                    return  # a prefetch is only ever a hint
                if value is not None:
                    self.data_cache.put(key, record.tid, value)
                    self.stats["prefetched_keys"] += 1
            return cb

        for k in keys:
            try:
                pipeline.submit_get(
                    record.storage_key_for(k)
                ).add_done_callback(_install(k))
            except RuntimeError:
                return  # pipeline closing; prefetch is best-effort

    # --------------------------------------------------- distributed hooks
    def drain_fresh_commits(self) -> List[TransactionRecord]:
        """Everything committed here since the last multicast round (§4)."""
        return self.cache.drain_fresh()

    def merge_remote_commits(self, records: Iterable[TransactionRecord]) -> int:
        """Merge peer/fault-manager commit announcements, skipping anything
        already superseded by local knowledge (§4.1)."""
        self._check_alive()
        merged = 0
        for record in records:
            if is_superseded(record, self.cache):
                self.stats["remote_skipped_superseded"] += 1
                # §4.1 accounting: a superseded record is not a merge — but
                # its version metadata still enters the cache, else a
                # delayed announcement could leave a watermark-covered
                # version invisible to the snapshot lane's
                # ``latest_version_at`` resolver.  Local GC prunes it like
                # any locally-superseded record (§5.1).
                if self.cache.add(record):
                    with self._lock:
                        self._committed_uuids.setdefault(
                            record.tid.uuid, record.tid)
                continue
            if self.cache.add(record):
                with self._lock:
                    self._committed_uuids.setdefault(record.tid.uuid, record.tid)
                merged += 1
        self.stats["remote_merges"] += merged
        return merged

    def committed_tid_for_uuid(self, uuid: str) -> Optional[TxnId]:
        with self._lock:
            return self._committed_uuids.get(uuid)

    # ------------------------------------- elastic membership: arc handoff
    def handoff_records(
        self, owned: Callable[[str], bool], limit: int = 10_000
    ) -> List[TransactionRecord]:
        """Warm-up handoff, donor side: the commit-set records whose write
        sets touch key ranges ``owned`` (a predicate over storage keys —
        typically "does the new ring route this key to the joiner?").  The
        prior arc owner streams these to a JOINING node *before* the node
        takes live traffic, so its Commit Set Cache and uuid → tid
        idempotence map (the in-memory view of the ``u/`` index) are warm
        for exactly the arcs it inherits — reads on the transferred range
        resolve locally instead of paying the durable bootstrap scan."""
        self._check_alive()
        out: List[TransactionRecord] = []
        for record in self.cache.snapshot_records():
            if len(out) >= limit:
                break
            if any(owned(k) for k in record.write_set):
                out.append(record)
        self.stats["handoff_records_out"] += len(out)
        return out

    def warmup_from(self, records: Iterable[TransactionRecord]) -> int:
        """Warm-up handoff, receiver side: fold a donor's streamed records
        into this node's commit-set cache and uuid → tid map (both filled by
        :meth:`merge_remote_commits`, which also tombstone-tracks anything
        already superseded)."""
        records = list(records)
        merged = self.merge_remote_commits(records)
        self.stats["warmup_records_in"] += len(records)
        return merged

    # ------------------------------------------------------------------- GC
    def _has_active_readers(self, record: TransactionRecord) -> bool:
        """§5.1: is any currently-executing transaction reading from this
        transaction's write set?"""
        with self._lock:
            active = [c for c in self._txns.values() if c.state is TxnState.RUNNING]
        for ctx in active:
            snapshot = ctx.read_set_snapshot()
            for key in record.write_set:
                if snapshot.get(key) == record.tid:
                    return True
        return False

    def gc_sweep_local(self, max_removals: int = 10_000) -> List[TxnId]:
        """Local metadata GC (§5.1): drop superseded transactions with no
        active readers, oldest first (the §5.2.1 mitigation), remembering them
        in the locally-deleted log for the global GC (§5.2)."""
        self._check_alive()
        removed: List[TxnId] = []
        now_ns = time.time_ns()
        min_age = int(self.config.min_gc_age_s * 1e9)
        for tid in sorted(self.cache.all_tids()):  # oldest first
            if len(removed) >= max_removals:
                break
            record = self.cache.get(tid)
            if record is None:
                continue
            if min_age and now_ns - tid.timestamp < min_age:
                continue
            if not is_superseded(record, self.cache):
                continue
            if self._has_active_readers(record):
                continue
            self.cache.remove(tid)
            self.data_cache.evict_transaction(record)
            with self._lock:
                self._locally_deleted.add(tid)
            removed.append(tid)
        self.stats["gc_removed"] += len(removed)
        return removed

    def forget_transaction(self, record: TransactionRecord) -> None:
        """Purge a transaction's metadata from this node entirely — cache,
        data cache, and the uuid → tid idempotence map.  Used by the
        finished-workflow sweep (§5 extended to memo records), whose
        transactions Algorithm 2 can never supersede: their keys are written
        exactly once, so supersedence-based GC would retain them forever."""
        self.cache.remove(record.tid)
        self.data_cache.evict_transaction(record)
        with self._lock:
            if self._committed_uuids.get(record.tid.uuid) == record.tid:
                del self._committed_uuids[record.tid.uuid]
            self._locally_deleted.discard(record.tid)

    def purge_workflow_metadata(self, finished_uuids: Set[str]) -> int:
        """Forget every pure-memo transaction of the given finished
        workflows from this node's *own* metadata view.

        Works entirely from local state (the uuid → tid map filled by
        commits and multicast merges), so every node can purge regardless of
        which peer won the storage-side sweep — the storage keys may already
        be gone by the time this node looks.  A transaction qualifies only
        if its UUID carries a derived infix whose base is a finished
        workflow AND its whole write set lives under that workflow's
        ``.wf/<uuid>/`` namespace; user-supplied workflow UUIDs that merely
        extend another's text (e.g. ``job.1`` vs ``job.1.5``) never
        qualify.  Chain bookkeeping transactions — the ``<entry>.claim`` /
        ``<entry>.enq`` writers of a finished triggered child, whose write
        sets live entirely under ``q/`` — are purged by the same rule.
        Returns the number of transactions forgotten."""
        if not finished_uuids:
            return 0
        with self._lock:
            candidates = list(self._committed_uuids.items())
        purged = 0
        for uuid, tid in candidates:
            namespaces = []
            for infix in (WF_MEMO_TXN_INFIX, WF_STEP_TXN_INFIX):
                head, sep, _ = uuid.rpartition(infix)
                if sep and head in finished_uuids:
                    namespaces.append(f"{WORKFLOW_MEMO_PREFIX}{head}/")
            for suffix in (CHAIN_CLAIM_SUFFIX, CHAIN_ENQ_SUFFIX):
                if uuid.endswith(suffix) and uuid[: -len(suffix)] in finished_uuids:
                    namespaces.append(TRIGGER_PREFIX)
            if not namespaces:
                continue
            record = self.cache.get(tid)
            if record is None:
                with self._lock:
                    if self._committed_uuids.get(uuid) == tid:
                        del self._committed_uuids[uuid]
                continue
            for namespace in namespaces:
                if record.write_set and all(
                    k.startswith(namespace) for k in record.write_set
                ):
                    self.forget_transaction(record)
                    purged += 1
                    break
        return purged

    # ------------------------------------------------- finish-marker acks
    def ack_workflow_marker(self, wf_uuid: str) -> None:
        """This node's GC agent fully consumed the ``w/<wf_uuid>`` marker
        (storage sweep + own-cache purge).  The fault manager retires a
        marker only once every live node has acked it — deleting earlier
        would orphan the ``.wf/`` memo records of any node that had not yet
        swept (``FaultManager.sweep_finished_markers``)."""
        with self._lock:
            self._acked_markers.add(wf_uuid)

    def workflow_marker_acked(self, wf_uuid: str) -> bool:
        with self._lock:
            return wf_uuid in self._acked_markers

    def retain_marker_acks(self, live_uuids: Set[str]) -> None:
        """Drop acks for markers that no longer exist (retired)."""
        with self._lock:
            self._acked_markers &= live_uuids

    def confirm_locally_deleted(
        self, records: Iterable[TransactionRecord]
    ) -> List[TxnId]:
        """Global GC phase 1 (§5.2): which of these have we locally deleted?
        Also opportunistically deletes any we *could* delete right now, which
        keeps the global protocol from stalling on idle nodes.

        Takes full records, not bare tids: confirming a transaction licenses
        the global GC to erase it from durable storage, so this node must
        tombstone the write-set keys in its pruned-watermark map even when it
        never learned the commit (a dropped announcement + supersedence).
        Otherwise a later ``snapshot_read`` could resolve *past* the erased
        version at a watermark that covered it — returning an answer it can
        no longer prove complete."""
        self._check_alive()
        confirmed: List[TxnId] = []
        with self._lock:
            deleted = set(self._locally_deleted)
        for proposed in records:
            tid = proposed.tid
            if tid in deleted:
                confirmed.append(tid)
                continue
            record = self.cache.get(tid)
            if record is None:
                # never knew it (dropped announcement, or this node joined
                # later): safe to confirm — no local transaction can be
                # reading it — but the snapshot fence must still learn that
                # versions of these keys up to this timestamp may vanish.
                if not self._has_active_readers_tid(tid):
                    self.cache.note_pruned(proposed)
                    confirmed.append(tid)
                continue
            if is_superseded(record, self.cache) and not self._has_active_readers(record):
                self.cache.remove(tid)
                self.data_cache.evict_transaction(record)
                with self._lock:
                    self._locally_deleted.add(tid)
                confirmed.append(tid)
        return confirmed

    def _has_active_readers_tid(self, tid: TxnId) -> bool:
        with self._lock:
            active = [c for c in self._txns.values() if c.state is TxnState.RUNNING]
        return any(tid in ctx.read_set_snapshot().values() for ctx in active)

    def forget_deleted(self, tids: Iterable[TxnId]) -> None:
        """Global GC finished deleting these; shrink the locally-deleted log."""
        with self._lock:
            self._locally_deleted.difference_update(tids)

    # ------------------------------------------------------------- liveness
    def sweep_timed_out_transactions(self) -> List[str]:
        """Abort RUNNING transactions older than the timeout (§3.3.1: a failed
        function's transaction 'will be aborted after a timeout')."""
        cutoff = time.monotonic() - self.config.txn_timeout_s
        stale: List[str] = []
        with self._lock:
            for uuid, ctx in self._txns.items():
                if ctx.state is TxnState.RUNNING and ctx.started_at < cutoff:
                    stale.append(uuid)
        for uuid in stale:
            try:
                self.abort_transaction(uuid)
            except (UnknownTransaction, NodeFailed):
                pass
        return stale

    def release_transaction(self, txid: str) -> None:
        """Drop a finished transaction's context (client session closed)."""
        with self._lock:
            ctx = self._txns.get(txid)
            if ctx is not None and ctx.state is not TxnState.RUNNING:
                del self._txns[txid]

    # ---------------------------------------------------------------- intro
    def active_transaction_count(self) -> int:
        with self._lock:
            return sum(
                1 for c in self._txns.values() if c.state is TxnState.RUNNING
            )

    def metadata_size(self) -> int:
        return len(self.cache)

    def read_set_of(self, txid: str) -> Dict[str, TxnId]:
        return self._ctx(txid).read_set_snapshot()
