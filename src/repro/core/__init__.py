"""AFT core — the paper's contribution (§3–§5), faithful.

Public surface: the Table-1 transactional KVS API via ``AftNode`` (single
node) or ``AftCluster``/``AftClient`` (distributed, §4), plus the protocol
building blocks for tests and tooling.
"""

from .anomaly import AnomalyAggregator, AnomalyCounts, TransactionObserver
from .atomic_read import (
    ReadSelection,
    ReadStatus,
    SessionReadState,
    atomic_read_select,
    atomic_read_select_incremental,
    fractured_read_witness,
    is_atomic_readset,
)
from .cluster import AftClient, AftCluster, ClusterConfig, NodeLifecycle
from .commit_cache import CommitSetCache, DataCache
from .errors import (
    AftError,
    NodeFailed,
    ReadAbortError,
    ReadOnlyTransaction,
    SnapshotUnavailable,
    TransactionNotRunning,
    UnknownTransaction,
)
from .fault_manager import (
    Autoscaler,
    AutoscalerConfig,
    FaultManager,
    FaultManagerConfig,
)
from .gc import LocalGcAgent
from .ids import Clock, TxnHandle, TxnId, fresh_uuid
from .multicast import (
    FAULT_MANAGER_ID,
    BusFaults,
    BusMessage,
    MulticastAgent,
    MulticastBus,
    decode_envelope,
    encode_envelope,
)
from .node import AftNode, AftNodeConfig, SnapshotResult, TxnState
from .records import (
    COMMIT_PREFIX,
    DATA_PREFIX,
    TransactionRecord,
    VersionedValue,
    commit_key,
    data_key,
    embed_metadata,
    encode_cache_stats,
    extract_metadata,
    set_encode_cache,
)
from .routing import (
    CacheAwareConfig,
    CacheAwareRouter,
    ConsistentHashRouter,
    PlacementHint,
    RoundRobinRouter,
    Router,
    make_router,
)
from .supersede import is_superseded, superseded_subset
from .write_buffer import TransactionWriteBuffer

__all__ = [
    "AftNode",
    "AftNodeConfig",
    "AftCluster",
    "AftClient",
    "ClusterConfig",
    "NodeLifecycle",
    "Autoscaler",
    "AutoscalerConfig",
    "TxnState",
    "TxnId",
    "TxnHandle",
    "Clock",
    "fresh_uuid",
    "TransactionRecord",
    "VersionedValue",
    "CommitSetCache",
    "DataCache",
    "TransactionWriteBuffer",
    "MulticastBus",
    "MulticastAgent",
    "BusFaults",
    "BusMessage",
    "SnapshotResult",
    "FAULT_MANAGER_ID",
    "FaultManager",
    "FaultManagerConfig",
    "LocalGcAgent",
    "atomic_read_select",
    "atomic_read_select_incremental",
    "SessionReadState",
    "ReadStatus",
    "ReadSelection",
    "is_atomic_readset",
    "fractured_read_witness",
    "is_superseded",
    "superseded_subset",
    "AnomalyAggregator",
    "AnomalyCounts",
    "TransactionObserver",
    "AftError",
    "NodeFailed",
    "ReadAbortError",
    "ReadOnlyTransaction",
    "SnapshotUnavailable",
    "TransactionNotRunning",
    "UnknownTransaction",
    "commit_key",
    "data_key",
    "embed_metadata",
    "extract_metadata",
    "set_encode_cache",
    "encode_cache_stats",
    "encode_envelope",
    "decode_envelope",
    "COMMIT_PREFIX",
    "DATA_PREFIX",
    "Router",
    "RoundRobinRouter",
    "ConsistentHashRouter",
    "CacheAwareRouter",
    "CacheAwareConfig",
    "PlacementHint",
    "make_router",
]
