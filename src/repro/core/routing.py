"""Placement-aware request routing: which AFT node serves a session/step.

The paper runs a "simple stateless load balancer" in front of the shim
nodes (§6) — round-robin, no locality.  That is the right baseline, but a
multi-node cluster leaves two kinds of performance on the table:

* **metadata/idempotence locality** — a retried request that lands on the
  node that served the original finds the §3.3.1 uuid → tid map and the
  Commit Set Cache already warm, instead of paying the durable-storage
  probe;
* **data-cache locality** — Cloudburst-style scheduling (Sreekanti et al.,
  2020): a transaction whose read set is already in some node's data cache
  is storage-bound anywhere else and cache-bound there.

This module extracts the placement decision out of ``AftCluster``/
``AftClient``/``WorkflowPool`` into pluggable policies:

* :class:`RoundRobinRouter` — the paper's stateless LB (default; hints are
  ignored, behavior is identical to the historical ``AftCluster.pick_node``);
* :class:`ConsistentHashRouter` — a virtual-node hash ring over live node
  ids.  Requests carrying the same :class:`PlacementHint` (workflow uuid or
  primary key) deterministically rehit the same node across clients and
  retries, and node death/scale moves only the dead node's arc;
* :class:`CacheAwareRouter` — scores every live node from its obs-registry
  snapshot (``node.registry.snapshot()``): declared-read-set presence in the
  data cache, the node's cache hit rate, and its current load (open sessions
  + in-flight ops).  The consistent-hash owner gets an anchor bonus so cold
  keys converge to a home node instead of scattering, but a hot node under
  load spills to its neighbours (which then cache the hot keys too).

Correctness note: placement is *pure policy*.  Any node can serve any
transaction — commit records are durable and multicast (§4), retried UUIDs
are verified against the Commit Set (§3.3.1) — so a "wrong" routing
decision costs latency, never consistency.  The one hard rule lives in
:meth:`Router.route`: never hand out a node that is already known dead
(the ``kill_node`` → ``_replace_node`` race window).
"""

from __future__ import annotations

import hashlib
import threading
from bisect import bisect_right
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .errors import NodeFailed
from .node import AftNode


@dataclass(frozen=True)
class PlacementHint:
    """What the caller knows about a request before routing it.

    ``uuid`` — the logical transaction / workflow uuid (stable across
    retries, so uuid-keyed policies re-route retries to the same node);
    ``keys`` — the declared read set, most-important key first (locality-
    keyed policies anchor on ``keys[0]`` and score the rest).
    """

    uuid: Optional[str] = None
    keys: Tuple[str, ...] = ()

    @property
    def ring_key(self) -> Optional[str]:
        """The identity a hash ring places this request by: the primary
        declared key when there is one (data locality), else the uuid
        (retry locality)."""
        return self.keys[0] if self.keys else self.uuid


def _stable_hash(s: str) -> int:
    """Deterministic across processes/runs (unlike builtin ``hash``)."""
    return int.from_bytes(
        hashlib.blake2b(s.encode("utf-8"), digest_size=8).digest(), "big"
    )


class Router:
    """A placement policy.  Stateless callers pass the current live-node
    list on every :meth:`route`; stateful policies (the hash ring) also get
    :meth:`sync` callbacks from the cluster on membership events (node
    death, replacement, scale) and self-heal lazily if an event was missed.
    """

    name = "router"

    def route(
        self, nodes: Sequence[AftNode], hint: Optional[PlacementHint] = None
    ) -> AftNode:
        raise NotImplementedError

    def sync(self, nodes: Sequence[AftNode]) -> None:
        """Membership changed; rebuild any derived state (e.g. the ring)."""

    # -- elastic-membership surface (no-ops for weightless policies) --------
    def set_weight(self, node_id: str, weight: float) -> None:
        """Scale a node's share of the key space (ring policies only): the
        cluster ramps a JOINING node up and a DRAINING node down here."""

    def weight_of(self, node_id: str) -> float:
        """Current arc weight; weightless policies are always full-share
        (the cluster's lifecycle ramp completes in one tick)."""
        return 1.0

    def forget_node(self, node_id: str) -> None:
        """A node retired: drop any per-node residue (weights, splits)."""

    # -- shared guards -------------------------------------------------------
    @staticmethod
    def _alive(nodes: Sequence[AftNode]) -> List[AftNode]:
        """Filter to nodes not already known dead.  The caller's list is a
        snapshot; a node may have been failed (``kill_node``) after it was
        taken but before we choose — re-checking here closes that window."""
        live = [n for n in nodes if n.alive]
        if not live:
            raise NodeFailed("no live AFT nodes to route to")
        return live


class RoundRobinRouter(Router):
    """The paper's stateless LB (§6).  Ignores hints; identical decision
    sequence to the historical ``AftCluster.pick_node`` counter."""

    name = "round_robin"

    def __init__(self) -> None:
        self._rr = 0
        self._lock = threading.Lock()

    def route(
        self, nodes: Sequence[AftNode], hint: Optional[PlacementHint] = None
    ) -> AftNode:
        live = self._alive(nodes)
        with self._lock:
            i = self._rr
            self._rr += 1
        return live[i % len(live)]


class ConsistentHashRouter(Router):
    """Weight-aware virtual-node hash ring keyed by ``PlacementHint.ring_key``.

    ``vnodes`` virtual points per node smooth the arc sizes; node death or
    scale moves only the affected arcs (tested: ≲ 2/n of keys move when the
    membership changes by one node).  Hints without a ring key fall back to
    round-robin — a ring is only useful when there is an identity to hash.

    Elastic membership (``core/cluster.py``) adds two mechanisms:

    * **per-node weights** — ``set_weight(node_id, w)`` scales a node's
      virtual-point count by ``w ∈ [0, 1]``.  A JOINING node ramps its
      weight up (small arcs first, so warm-up handoff streams a bounded
      key range at a time); a DRAINING node ramps down to 0 (no *new*
      sessions route there while in-flight ones finish);
    * **hot-arc splitting** — every ring-keyed routing decision reports
      load against the arc that served it (``arc_loads``).  When an arc
      runs disproportionately hot (a skewed key clustering there),
      ``split_hot_arc`` donates the hot arc's midpoint range to an
      explicit target node by inserting extra virtual points, moving
      roughly half the arc's keys without disturbing any other arc.
    """

    name = "consistent_hash"

    def __init__(self, vnodes: int = 64) -> None:
        self.vnodes = vnodes
        self._lock = threading.Lock()
        self._hashes: List[int] = []
        self._ring_ids: List[str] = []   # node_id per ring point, hash-sorted
        self._by_id: Dict[str, AftNode] = {}
        self._weights: Dict[str, float] = {}      # node_id → arc weight (0..1]
        self._last_nodes: List[AftNode] = []      # last sync'd membership
        # hot-arc split points: arc-point hash → node_id, surviving resyncs
        # while the target node stays a member
        self._splits: Dict[int, str] = {}
        # per-arc load accounting: arc-point hash → routed-request count
        self._arc_loads: Dict[int, float] = {}
        self._fallback = RoundRobinRouter()

    def sync(self, nodes: Sequence[AftNode]) -> None:
        points = []
        by_id = {}
        with self._lock:
            weights = dict(self._weights)
            splits = dict(self._splits)
        for node in nodes:
            if not node.alive:
                continue
            by_id[node.node_id] = node
            w = weights.get(node.node_id, 1.0)
            n_points = (
                max(1, int(round(self.vnodes * min(w, 1.0))))
                if w > 0.0 else 0
            )
            for v in range(n_points):
                points.append((_stable_hash(f"{node.node_id}#{v}"), node.node_id))
        # re-apply surviving hot-arc split points (drop any whose target
        # node left the membership — its keys fall back to the base ring)
        for h, nid in list(splits.items()):
            if nid in by_id:
                points.append((h, nid))
            else:
                splits.pop(h)
        points.sort()
        with self._lock:
            self._hashes = [h for h, _ in points]
            self._ring_ids = [nid for _, nid in points]
            self._by_id = by_id
            self._splits = splits
            self._last_nodes = [n for n in nodes if n.alive]
            # drop load buckets for arcs that no longer exist
            live_points = set(self._hashes)
            self._arc_loads = {
                h: v for h, v in self._arc_loads.items() if h in live_points
            }

    # -- elastic membership: weights ----------------------------------------
    def set_weight(self, node_id: str, weight: float) -> None:
        """Set a node's arc weight and rebuild the ring from the last
        synced membership.  ``weight=1.0`` (the default) is a full member;
        fractional weights shrink the node's share of the key space;
        ``0.0`` removes its arcs entirely (draining) while the node itself
        stays routable for in-flight sessions held elsewhere."""
        with self._lock:
            self._weights[node_id] = max(0.0, min(1.0, float(weight)))
            last = list(self._last_nodes)
        self.sync(last)

    def weight_of(self, node_id: str) -> float:
        with self._lock:
            return self._weights.get(node_id, 1.0)

    def forget_node(self, node_id: str) -> None:
        """Drop a retired node's weight and split-point residue."""
        with self._lock:
            self._weights.pop(node_id, None)
            self._splits = {
                h: nid for h, nid in self._splits.items() if nid != node_id
            }
            last = [n for n in self._last_nodes if n.node_id != node_id]
        self.sync(last)

    # -- elastic membership: per-arc load + hot-arc splitting ----------------
    def _note_arc_load(self, arc_hash: int, amount: float = 1.0) -> None:
        # caller holds self._lock
        self._arc_loads[arc_hash] = self._arc_loads.get(arc_hash, 0.0) + amount

    def arc_loads(self) -> Dict[int, Tuple[str, float]]:
        """Per-arc load report: arc-point hash → (owner node_id, routed
        requests since the last decay).  The autoscaler's split signal."""
        with self._lock:
            owners = dict(zip(self._hashes, self._ring_ids))
            return {
                h: (owners[h], load)
                for h, load in self._arc_loads.items()
                if h in owners
            }

    def decay_arc_loads(self, factor: float = 0.5) -> None:
        """Exponential decay so the split signal tracks *current* skew."""
        with self._lock:
            self._arc_loads = {
                h: v * factor for h, v in self._arc_loads.items() if v * factor > 0.01
            }

    def hottest_arc(self) -> Optional[Tuple[int, str, float, float]]:
        """(arc_hash, owner_id, load, mean_load) of the hottest arc, or
        None when no ring-keyed traffic has been observed.  The mean is
        taken over ALL ring arcs (unloaded arcs count as zero) — skew is
        hot-vs-ring, not hot-vs-other-hot."""
        report = self.arc_loads()
        if not report:
            return None
        h, (owner, load) = max(report.items(), key=lambda kv: kv[1][1])
        with self._lock:
            n_arcs = len(self._hashes)
        mean = sum(v for _, v in report.values()) / max(1, n_arcs)
        return h, owner, load, mean

    def split_arc(self, arc_hash: int, to_node_id: str) -> bool:
        """Split the arc ending at ``arc_hash``: insert a virtual point at
        the arc's midpoint owned by ``to_node_id``, so the lower half of the
        arc's key range moves there.  Returns False when the arc or target
        is unknown (a racing resync)."""
        with self._lock:
            if to_node_id not in self._by_id or arc_hash not in self._hashes:
                return False
            i = self._hashes.index(arc_hash)
            lo = self._hashes[i - 1] if i > 0 else self._hashes[-1]
            hi = arc_hash
            span = (hi - lo) % (1 << 64)
            if span < 2:
                return False
            mid = (lo + span // 2) % (1 << 64)
            if mid in self._hashes:
                return False
            self._splits[mid] = to_node_id
            self._arc_loads.pop(arc_hash, None)
            last = list(self._last_nodes)
        self.sync(last)
        return True

    def split_hot_arc(self, to_node_id: str, *, min_ratio: float = 2.0) -> bool:
        """Split the hottest arc into ``to_node_id`` if it carries at least
        ``min_ratio``× the mean arc load.  The autoscaler's split action."""
        hot = self.hottest_arc()
        if hot is None:
            return False
        arc_hash, owner, load, mean = hot
        if owner == to_node_id or mean <= 0 or load < min_ratio * mean:
            return False
        return self.split_arc(arc_hash, to_node_id)

    def _maybe_self_heal(self, live: Sequence[AftNode]) -> None:
        with self._lock:
            known = set(self._by_id)
        if known != {n.node_id for n in live}:
            self.sync(live)  # a membership event was missed; rebuild

    def owner_id(self, ring_key: str) -> Optional[str]:
        """Ring owner of a key among currently-synced nodes (for tests and
        the cache-aware anchor)."""
        with self._lock:
            if not self._hashes:
                return None
            i = bisect_right(self._hashes, _stable_hash(ring_key))
            return self._ring_ids[i % len(self._ring_ids)]

    def route(
        self, nodes: Sequence[AftNode], hint: Optional[PlacementHint] = None
    ) -> AftNode:
        live = self._alive(nodes)
        key = hint.ring_key if hint is not None else None
        if key is None:
            return self._fallback.route(live, hint)
        self._maybe_self_heal(live)
        live_ids = {n.node_id: n for n in live}
        with self._lock:
            ring_ids, hashes = self._ring_ids, self._hashes
            if not ring_ids:
                return self._fallback.route(live, hint)
            i = bisect_right(hashes, _stable_hash(key))
            # walk clockwise past points whose node died after the last sync
            for off in range(len(ring_ids)):
                j = (i + off) % len(ring_ids)
                node = live_ids.get(ring_ids[j])
                if node is not None and node.alive:
                    # per-key-range load report: the serving arc is the one
                    # ending at this ring point (the hot-arc split signal)
                    self._note_arc_load(hashes[j])
                    return node
        return self._fallback.route(live, hint)


@dataclass
class CacheAwareConfig:
    """Scoring weights.  Scores are dimensionless; only ratios matter.

    ``affinity_weight`` — per unit *fraction of hint keys present* in a
    node's data cache (the dominant term: a full read-set hit should beat
    anything but a badly overloaded node);
    ``hit_rate_weight`` — per unit node-lifetime data-cache hit rate (a
    weak prior that separates warm nodes from cold replacements);
    ``load_weight / load_scale`` — penalty per ``load_scale`` units of
    (open sessions + in-flight ops): the spill valve that stops a hot
    ring owner from saturating while its neighbours idle;
    ``anchor_bonus`` — added to the consistent-hash owner so *cold* keys
    converge to a home node instead of scattering on load noise.
    """

    affinity_weight: float = 3.0
    hit_rate_weight: float = 0.5
    load_weight: float = 1.0
    load_scale: float = 8.0
    anchor_bonus: float = 0.75


class CacheAwareRouter(Router):
    """Cloudburst-style locality + load scheduling over the node's obs
    registry (``node.registry.snapshot()`` — the unified metrics read path;
    the deprecated ``AftNode.stats()`` shim is no longer consulted).

    For every live node: ``score = affinity·W_a + hit_rate·W_h − load/S·W_l
    (+ anchor bonus for the ring owner)``; route to the argmax.  Without a
    hint, degrades to least-loaded.
    """

    name = "cache_aware"

    def __init__(self, config: Optional[CacheAwareConfig] = None) -> None:
        self.config = config or CacheAwareConfig()
        self._anchor = ConsistentHashRouter()

    def sync(self, nodes: Sequence[AftNode]) -> None:
        self._anchor.sync(nodes)

    def set_weight(self, node_id: str, weight: float) -> None:
        self._anchor.set_weight(node_id, weight)

    def weight_of(self, node_id: str) -> float:
        return self._anchor.weight_of(node_id)

    def forget_node(self, node_id: str) -> None:
        self._anchor.forget_node(node_id)

    def owner_id(self, ring_key: str) -> Optional[str]:
        """Ring owner under the anchor ring (warm-up handoff's ownership
        predicate routes through this)."""
        return self._anchor.owner_id(ring_key)

    def _score(self, node: AftNode, hint: Optional[PlacementHint],
               anchor_id: Optional[str]) -> float:
        cfg = self.config
        snap = node.registry.snapshot()
        affinity = 0.0
        if hint is not None and hint.keys:
            present = sum(
                1 for k in hint.keys if node.data_cache.contains_key(k)
            )
            affinity = present / len(hint.keys)
        load = snap.get("open_sessions", 0.0) + snap.get("inflight_ops", 0.0)
        score = (
            cfg.affinity_weight * affinity
            + cfg.hit_rate_weight * snap.get("data_cache_hit_rate", 0.0)
            - cfg.load_weight * (load / cfg.load_scale)
        )
        if anchor_id is not None and node.node_id == anchor_id:
            score += cfg.anchor_bonus
        return score

    def route(
        self, nodes: Sequence[AftNode], hint: Optional[PlacementHint] = None
    ) -> AftNode:
        live = self._alive(nodes)
        if len(live) == 1:
            return live[0]
        anchor_id: Optional[str] = None
        ring_key = hint.ring_key if hint is not None else None
        if ring_key is not None:
            self._anchor._maybe_self_heal(live)
            anchor_id = self._anchor.owner_id(ring_key)
        best = live[0]
        best_score = self._score(best, hint, anchor_id)
        for node in live[1:]:
            score = self._score(node, hint, anchor_id)
            if score > best_score:
                best, best_score = node, score
        return best


ROUTER_POLICIES = {
    "round_robin": RoundRobinRouter,
    "consistent_hash": ConsistentHashRouter,
    "cache_aware": CacheAwareRouter,
}


def make_router(policy: Union[str, Router, None]) -> Router:
    """Resolve a policy name (or pass through a Router instance)."""
    if policy is None:
        return RoundRobinRouter()
    if isinstance(policy, Router):
        return policy
    try:
        return ROUTER_POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown routing policy {policy!r}; options: "
            f"{sorted(ROUTER_POLICIES)}"
        ) from None
