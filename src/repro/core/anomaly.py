"""Consistency anomaly detection (Table 2 methodology, §6.1.2).

Two detectors, both driven from *observed reads* so they work identically for
AFT-shimmed and plain-storage executions:

* **Read-Your-Write (RYW) anomaly** — a transaction wrote key ``k`` and a
  later read of ``k`` within the same transaction returned a different
  version (or different bytes).
* **Fractured Read (FR) anomaly** — the transaction's accumulated read set
  violates Definition 1: it read ``k_i`` whose transaction cowrote ``l``, and
  it also read ``l_j`` with ``j < i``.  This subsumes repeatable-read
  anomalies (§3.5: re-reading a key at a different version shows up as a
  Definition-1 violation since every version cowrites itself).

For plain-storage runs the per-version metadata (timestamp, UUID, cowritten
set — ~70 bytes) is embedded in the stored values (``records.embed_metadata``),
exactly as §6.1.2 describes.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Optional, Tuple

from .atomic_read import fractured_read_witness
from .ids import TxnId


@dataclass
class AnomalyCounts:
    ryw: int = 0
    fractured: int = 0
    transactions: int = 0
    transactions_with_ryw: int = 0
    transactions_with_fr: int = 0

    def merge(self, other: "AnomalyCounts") -> None:
        self.ryw += other.ryw
        self.fractured += other.fractured
        self.transactions += other.transactions
        self.transactions_with_ryw += other.transactions_with_ryw
        self.transactions_with_fr += other.transactions_with_fr


class TransactionObserver:
    """Accumulates one transaction's observed reads/writes and scores them."""

    def __init__(self) -> None:
        self.read_versions: Dict[str, TxnId] = {}
        self.cowritten_of: Dict[TxnId, FrozenSet[str]] = {}
        self.my_writes: Dict[str, bytes] = {}
        self.ryw_anomalies = 0
        self.fr_anomalies = 0

    def observe_write(self, key: str, value: bytes) -> None:
        self.my_writes[key] = value

    def observe_read(
        self,
        key: str,
        value: Optional[bytes],
        tid: Optional[TxnId],
        cowritten: Tuple[str, ...] = (),
    ) -> None:
        # RYW check: once we wrote k, a read must return our bytes.
        if key in self.my_writes and value != self.my_writes[key]:
            self.ryw_anomalies += 1
            return  # a foreign version read after our write is not part of
            # "our" atomic readset accounting — count it once as RYW.
        if tid is None or value is None:
            return
        self.read_versions[key] = tid
        self.cowritten_of[tid] = frozenset(cowritten) | frozenset({key})
        # FR check: incremental Definition-1 validation on every read.
        witness = fractured_read_witness(self.read_versions, self.cowritten_of)
        if witness is not None:
            self.fr_anomalies += 1
            # drop the offending read so one stale read isn't counted again
            # on every subsequent read of the transaction
            del self.read_versions[key]

    def counts(self) -> AnomalyCounts:
        return AnomalyCounts(
            ryw=self.ryw_anomalies,
            fractured=self.fr_anomalies,
            transactions=1,
            transactions_with_ryw=int(self.ryw_anomalies > 0),
            transactions_with_fr=int(self.fr_anomalies > 0),
        )


class AnomalyAggregator:
    """Thread-safe workload-wide anomaly tally (one row of Table 2)."""

    def __init__(self, label: str):
        self.label = label
        self.total = AnomalyCounts()
        self._lock = threading.Lock()

    def record(self, observer: TransactionObserver) -> None:
        with self._lock:
            self.total.merge(observer.counts())

    def summary(self) -> Dict[str, int]:
        with self._lock:
            return {
                "label": self.label,
                "transactions": self.total.transactions,
                "ryw_anomalies": self.total.ryw,
                "fr_anomalies": self.total.fractured,
                "txns_with_ryw": self.total.transactions_with_ryw,
                "txns_with_fr": self.total.transactions_with_fr,
            }
