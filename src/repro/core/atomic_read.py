"""Algorithm 1 — AtomicRead (§3.4) and the Definition-1 checker.

Given a requested key ``k`` and the transaction's read set ``R`` (a map from
key to the version already read), select the newest committed version ``k_t``
such that ``R ∪ {k_t}`` is still an Atomic Readset (Definition 1):

  (1) for every ``l_i ∈ R`` with ``k ∈ l_i.cowritten``: ``t ≥ i``
      — the *lower bound*: a cowritten sibling forces us at least as new;
  (2) for every ``l ∈ k_t.cowritten`` with ``l_j ∈ R``: ``j ≥ t``
      — no candidate may have a cowritten sibling that we already read at an
      older version (we could no longer "repair" that read, §3.6).

Unlike RAMP, read sets are built *dynamically* — no pre-declared read/write
sets — at the cost of potentially staler reads and, in rare cases, an abort
when no valid version survives both constraints (§3.6).
"""

from __future__ import annotations

from bisect import bisect_left
from dataclasses import dataclass
from enum import Enum
from typing import Dict, Mapping, Optional, Tuple

from .commit_cache import CommitSetCache
from .ids import TxnId
from .records import TransactionRecord


class ReadStatus(Enum):
    OK = "ok"                       # a version was selected
    NOT_FOUND = "not_found"         # key has only the NULL version (line 8-9)
    NO_VALID_VERSION = "no_valid"   # versions exist but none satisfies Def. 1
                                    # (§3.6 staleness abort / §5.2.1 GC hole)


@dataclass(frozen=True)
class ReadSelection:
    status: ReadStatus
    tid: Optional[TxnId] = None


class SessionReadState:
    """Incremental case-1 state for one transaction (the hot-path variant).

    The reference ``atomic_read_select`` recomputes Algorithm 1's lines 3–5
    — "does any prior read's cowritten set contain ``k``?" — by rescanning
    the whole read set on *every* read: O(|R|) cache lookups per read,
    O(|R|²) per transaction.  This state maintains the same information
    incrementally: when a read joins the read set, ``note_read`` folds the
    chosen record's write set into ``lower`` (key → newest cowriting tid
    among prior reads), making each subsequent lower-bound lookup O(1).

    Equivalence with the reference (proved by the property suite in
    tests/test_atomic_read_incremental.py): every read-set entry was
    selected from the cache, so its record existed and was folded in at
    join time.  The reference re-resolves those records at *select* time
    and conservatively drops the constraint if one was pruned meanwhile;
    §5.1 GC never prunes a record read by a running transaction, so for
    live sessions the two computations see identical records.  If that
    guard were ever violated, the incremental map *retains* the constraint
    the reference would drop — the safe direction (a too-high lower bound
    can only force a fresher-or-aborted read, never a fractured one).
    """

    __slots__ = ("lower",)

    def __init__(self) -> None:
        self.lower: Dict[str, TxnId] = {}

    def note_read(self, record: Optional[TransactionRecord]) -> None:
        """Fold a just-read version's cowritten set into the lower-bound map.
        Call once, when the read joins the read set (under the session lock).
        """
        if record is None:
            return
        tid = record.tid
        lower = self.lower
        for k in record.write_set:
            cur = lower.get(k)
            if cur is None or tid > cur:
                lower[k] = tid


def atomic_read_select(
    key: str,
    read_set: Mapping[str, TxnId],
    cache: CommitSetCache,
) -> ReadSelection:
    """Lines 1–23 of Algorithm 1: choose a version; storage fetch is the
    caller's job (line 25).

    This is the *reference oracle*: it freezes the whole cache (the coarse
    all-stripes section) and rescans the full read set per read.  The hot
    path uses :func:`atomic_read_select_incremental`; this implementation is
    retained as the equivalence baseline for the property suite and as the
    ``incremental_reads=False`` escape hatch.
    """
    with cache.lock:  # one consistent view of records + index for this read
        # lines 3–5: lower bound from cowritten sets of prior reads (case 1)
        lower: Optional[TxnId] = None
        for l_key, l_tid in read_set.items():
            record = cache.get(l_tid)
            if record is None:
                # GC never removes records read by a running transaction
                # (§5.1); a miss here means the version arrived via another
                # node's session — treat conservatively as no constraint.
                continue
            if key in record.write_set and (lower is None or l_tid > lower):
                lower = l_tid

        versions = cache.versions_of(key)

        # lines 7–9: key was never written (NULL version) and nothing forces
        # a version to exist ⇒ legitimate NULL read.
        if not versions and lower is None:
            return ReadSelection(ReadStatus.NOT_FOUND)

        # line 11: candidates at least as new as the lower bound
        candidates = (
            versions if lower is None else [t for t in versions if t >= lower]
        )

        # lines 13–21: newest-first, reject candidates whose cowritten set
        # conflicts with an older prior read (case 2)
        for t in reversed(candidates):
            record = cache.get(t)
            if record is None:  # pruned concurrently; skip
                continue
            valid = True
            for l_key in record.write_set:
                prior = read_set.get(l_key)
                if prior is not None and prior < t:
                    valid = False
                    break
            if valid:
                return ReadSelection(ReadStatus.OK, t)

        # line 22–23: no valid version — abort/retry (§3.6)
        return ReadSelection(ReadStatus.NO_VALID_VERSION)


def atomic_read_select_incremental(
    key: str,
    read_set: Mapping[str, TxnId],
    cache: CommitSetCache,
    state: SessionReadState,
) -> Tuple[ReadSelection, Optional[TransactionRecord]]:
    """Algorithm 1 on the striped hot path: O(candidates) per read.

    Case 1 (lower bound) comes from ``state.lower`` — maintained
    incrementally by ``SessionReadState.note_read`` — instead of rescanning
    the read set.  Case 2 runs newest-first over only the candidate tail of
    the key's version list, sliced under the key's single stripe lock.

    Returns ``(selection, record)`` so the caller can fold the chosen
    record into the session state (and trace its cowritten set) without a
    second cache lookup.

    Per-read consistency argument (why one stripe lock is enough):

    * the candidate list is read atomically under ``key``'s stripe lock, so
      it is a true point-in-time version list for ``key``;
    * case-1 bounds come from the session-local map (stable under the
      caller's session lock) — no cross-stripe cache access;
    * candidate records are resolved *after* releasing the stripe (readers
      never nest stripe locks).  The add path inserts a record before (and
      atomically with) its index entries, so every indexed candidate had a
      live record when the list was sliced; a candidate resolving to None
      here was pruned concurrently — skipping it selects an older version
      that still satisfies Definition 1 (prunes only ever *remove* newer
      choices; the selection degrades in freshness, never in safety).  The
      coarse-lock reference behaves identically under the same race.
    """
    lower = state.lower.get(key)
    with cache.lock_for_key(key):
        versions = cache.versions_view(key)
        # NULL read (lines 7–9): no versions and nothing forces one to exist
        if not versions and lower is None:
            return ReadSelection(ReadStatus.NOT_FOUND), None
        # line 11: copy only the candidate tail (t >= lower) — usually a
        # handful of entries — instead of the whole list per read
        if lower is None:
            candidates = list(versions)
        else:
            candidates = versions[bisect_left(versions, lower):]

    # lines 13–21: newest-first case-2 rejection, outside the stripe lock
    for t in reversed(candidates):
        record = cache.get(t)
        if record is None:  # pruned concurrently; skip (see docstring)
            continue
        valid = True
        for l_key in record.write_set:
            prior = read_set.get(l_key)
            if prior is not None and prior < t:
                valid = False
                break
        if valid:
            return ReadSelection(ReadStatus.OK, t), record

    return ReadSelection(ReadStatus.NO_VALID_VERSION), None


# ---------------------------------------------------------------------------
# Definition 1 checker — used by tests, the anomaly detectors (Table 2), and
# the hypothesis property suite.  Deliberately a *separate, direct* encoding of
# the definition so it can catch bugs in the protocol implementation.
# ---------------------------------------------------------------------------

def is_atomic_readset(
    read_versions: Mapping[str, TxnId],
    cowritten_of: Mapping[TxnId, frozenset],
) -> bool:
    """Definition 1: ∀ k_i ∈ R, ∀ l ∈ k_i.cowritten, l_j ∈ R ⇒ j ≥ i.

    ``read_versions`` maps key → version read; ``cowritten_of`` maps a version
    (its TxnId) to the set of keys cowritten by that transaction.
    """
    for _k, i in read_versions.items():
        cowritten = cowritten_of.get(i)
        if cowritten is None:
            continue
        for l in cowritten:
            j = read_versions.get(l)
            if j is not None and j < i:
                return False
    return True


def fractured_read_witness(
    read_versions: Mapping[str, TxnId],
    cowritten_of: Mapping[TxnId, frozenset],
) -> Optional[str]:
    """Human-readable witness of a Definition-1 violation, or None."""
    for k, i in read_versions.items():
        cowritten = cowritten_of.get(i)
        if cowritten is None:
            continue
        for l in cowritten:
            j = read_versions.get(l)
            if j is not None and j < i:
                return (
                    f"read {k}@{i} whose txn cowrote {l}, but read {l}@{j} "
                    f"with {j} < {i}"
                )
    return None
