"""Algorithm 1 — AtomicRead (§3.4) and the Definition-1 checker.

Given a requested key ``k`` and the transaction's read set ``R`` (a map from
key to the version already read), select the newest committed version ``k_t``
such that ``R ∪ {k_t}`` is still an Atomic Readset (Definition 1):

  (1) for every ``l_i ∈ R`` with ``k ∈ l_i.cowritten``: ``t ≥ i``
      — the *lower bound*: a cowritten sibling forces us at least as new;
  (2) for every ``l ∈ k_t.cowritten`` with ``l_j ∈ R``: ``j ≥ t``
      — no candidate may have a cowritten sibling that we already read at an
      older version (we could no longer "repair" that read, §3.6).

Unlike RAMP, read sets are built *dynamically* — no pre-declared read/write
sets — at the cost of potentially staler reads and, in rare cases, an abort
when no valid version survives both constraints (§3.6).
"""

from __future__ import annotations

from dataclasses import dataclass
from enum import Enum
from typing import Dict, Mapping, Optional

from .commit_cache import CommitSetCache
from .ids import TxnId


class ReadStatus(Enum):
    OK = "ok"                       # a version was selected
    NOT_FOUND = "not_found"         # key has only the NULL version (line 8-9)
    NO_VALID_VERSION = "no_valid"   # versions exist but none satisfies Def. 1
                                    # (§3.6 staleness abort / §5.2.1 GC hole)


@dataclass(frozen=True)
class ReadSelection:
    status: ReadStatus
    tid: Optional[TxnId] = None


def atomic_read_select(
    key: str,
    read_set: Mapping[str, TxnId],
    cache: CommitSetCache,
) -> ReadSelection:
    """Lines 1–23 of Algorithm 1: choose a version; storage fetch is the
    caller's job (line 25)."""
    with cache.lock:  # one consistent view of records + index for this read
        # lines 3–5: lower bound from cowritten sets of prior reads (case 1)
        lower: Optional[TxnId] = None
        for l_key, l_tid in read_set.items():
            record = cache.get(l_tid)
            if record is None:
                # GC never removes records read by a running transaction
                # (§5.1); a miss here means the version arrived via another
                # node's session — treat conservatively as no constraint.
                continue
            if key in record.write_set and (lower is None or l_tid > lower):
                lower = l_tid

        versions = cache.versions_of(key)

        # lines 7–9: key was never written (NULL version) and nothing forces
        # a version to exist ⇒ legitimate NULL read.
        if not versions and lower is None:
            return ReadSelection(ReadStatus.NOT_FOUND)

        # line 11: candidates at least as new as the lower bound
        candidates = (
            versions if lower is None else [t for t in versions if t >= lower]
        )

        # lines 13–21: newest-first, reject candidates whose cowritten set
        # conflicts with an older prior read (case 2)
        for t in reversed(candidates):
            record = cache.get(t)
            if record is None:  # pruned concurrently; skip
                continue
            valid = True
            for l_key in record.write_set:
                prior = read_set.get(l_key)
                if prior is not None and prior < t:
                    valid = False
                    break
            if valid:
                return ReadSelection(ReadStatus.OK, t)

        # line 22–23: no valid version — abort/retry (§3.6)
        return ReadSelection(ReadStatus.NO_VALID_VERSION)


# ---------------------------------------------------------------------------
# Definition 1 checker — used by tests, the anomaly detectors (Table 2), and
# the hypothesis property suite.  Deliberately a *separate, direct* encoding of
# the definition so it can catch bugs in the protocol implementation.
# ---------------------------------------------------------------------------

def is_atomic_readset(
    read_versions: Mapping[str, TxnId],
    cowritten_of: Mapping[TxnId, frozenset],
) -> bool:
    """Definition 1: ∀ k_i ∈ R, ∀ l ∈ k_i.cowritten, l_j ∈ R ⇒ j ≥ i.

    ``read_versions`` maps key → version read; ``cowritten_of`` maps a version
    (its TxnId) to the set of keys cowritten by that transaction.
    """
    for _k, i in read_versions.items():
        cowritten = cowritten_of.get(i)
        if cowritten is None:
            continue
        for l in cowritten:
            j = read_versions.get(l)
            if j is not None and j < i:
                return False
    return True


def fractured_read_witness(
    read_versions: Mapping[str, TxnId],
    cowritten_of: Mapping[TxnId, frozenset],
) -> Optional[str]:
    """Human-readable witness of a Definition-1 violation, or None."""
    for k, i in read_versions.items():
        cowritten = cowritten_of.get(i)
        if cowritten is None:
            continue
        for l in cowritten:
            j = read_versions.get(l)
            if j is not None and j < i:
                return (
                    f"read {k}@{i} whose txn cowrote {l}, but read {l}@{j} "
                    f"with {j} < {i}"
                )
    return None
