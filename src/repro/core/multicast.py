"""Commit-set multicast with supersedence pruning (§4, §4.1).

Each node runs a background agent that pushes freshly-committed transaction
metadata to every peer — eagerly at commit time (the gossip-fed read fast
path: peers fold the records into their ``CommitSetCache`` so read-atomic
version resolution is a local lookup) — and periodically (default 1 s)
drains the fresh-commit log for the fault manager, prunes any records that
are already locally superseded (Algorithm 2 — "for highly contended
workloads … this significantly reduces the volume of metadata"), and emits
a heartbeat carrying the node's *commit horizon*.  The *unpruned* set
always goes to the fault manager (§4.2), which is what makes commit
announcements loss-proof.

Commit horizons & the read watermark
------------------------------------
Every sequenced message carries ``horizon``: a timestamp h such that every
transaction this node has committed (or will ever commit) with timestamp
≤ h was durably recorded before the message was sent — ``now`` capped below
the earliest still-in-flight commit.  A receiver only advances its view of
a peer's horizon along a *contiguous* sequence prefix: a dropped or delayed
message stalls the horizon (fail-safe — bounded-staleness snapshot reads
degrade to ``SnapshotUnavailable``, never to stale answers) until either
the gap self-heals out of the reorder buffer or the agent repairs it by
re-scanning the durable commit set (sound: every commit covered by a later
message's horizon was durable before that message was sent).  The minimum
over all live peers' horizons, combined with the node's own horizon, is the
node's *read watermark* — the snapshot lane's staleness bound
(``AftNode.snapshot_read``).

Fault injection
---------------
``MulticastBus`` accepts per-message fault knobs (``BusFaults``: drop,
delay-by-rounds, reorder, duplicate — seeded, deterministic) plus a
``fault_hook`` invoked with the named site ``multicast:send`` so the
gossip plane can be killed mid-stream by the same ``maybe_fail`` machinery
as every other subsystem.

Components expose a synchronous ``step()`` so tests and deterministic
simulations can drive rounds manually; ``start()`` runs the same step on a
daemon thread.
"""

from __future__ import annotations

import random
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from .node import AftNode
from .records import TransactionRecord
from .supersede import is_superseded

FAULT_MANAGER_ID = "fault-manager"

#: named fault site checked on every bus send (wire ``bus.fault_hook`` to
#: ``LambdaPlatform.maybe_fail`` to crash the gossip plane mid-stream)
SEND_FAULT_SITE = "multicast:send"


def encode_envelope(records: List[TransactionRecord]) -> bytes:
    """Serialize a batch of commit records into one wire envelope: a
    4-byte big-endian length prefix per record's (memoized) ``encode()``
    bytes, concatenated.  Encoded ONCE per batch by the sending agent and
    the identical bytes object is shared across every peer's message —
    the encode-once fan-out (previously each peer's send re-encoded the
    same records)."""
    parts = []
    for r in records:
        raw = r.encode()
        parts.append(len(raw).to_bytes(4, "big"))
        parts.append(raw)
    return b"".join(parts)


def decode_envelope(payload: bytes) -> Tuple[TransactionRecord, ...]:
    """Inverse of :func:`encode_envelope` (out-of-process receivers; the
    in-process bus delivers the record objects directly)."""
    out: List[TransactionRecord] = []
    pos = 0
    n = len(payload)
    while pos < n:
        rlen = int.from_bytes(payload[pos:pos + 4], "big")
        pos += 4
        out.append(TransactionRecord.decode(payload[pos:pos + rlen]))
        pos += rlen
    return tuple(out)


@dataclass
class BusFaults:
    """Seeded, per-message fault plan for the multicast fabric.

    Each knob is an independent probability, evaluated first-match-wins in
    the order drop → delay → duplicate → reorder, so e.g. ``drop_rate=1.0``
    silences the bus regardless of the other knobs.
    """

    drop_rate: float = 0.0        # message silently lost
    delay_rate: float = 0.0       # message held for ``delay_rounds`` drains
    delay_rounds: int = 1
    reorder_rate: float = 0.0     # message jumps the queue (front-insert)
    duplicate_rate: float = 0.0   # message delivered twice
    seed: int = 0


@dataclass(frozen=True)
class BusMessage:
    """One bus delivery: commit records plus the gossip-plane envelope.

    ``seq`` is the sender's per-source broadcast counter (contiguity is the
    receiver's loss detector); ``horizon`` is the sender's commit horizon at
    send time.  Unsequenced messages (``seq is None``) are the legacy
    record-stream shape the fault manager consumes.
    """

    src: str
    records: Tuple[TransactionRecord, ...] = ()
    seq: Optional[int] = None
    horizon: Optional[int] = None
    # the batch's wire image (``encode_envelope``), serialized once by the
    # sender and SHARED (same bytes object) across all peers' messages; the
    # in-process bus delivers ``records`` directly, so receivers never pay a
    # decode — ``payload`` models (and meters) what would cross the network
    payload: Optional[bytes] = None


class MulticastBus:
    """In-process message fabric between AFT nodes and the fault manager.

    Models the paper's point-to-point broadcast; the seeded ``BusFaults``
    knobs, the legacy ``drop_filter`` hook and the named ``multicast:send``
    fault site let tests exercise races (commit acknowledged → node dies
    before broadcast — the §4.2 liveness scenario) and arbitrary
    drop/delay/reorder/duplicate schedules.
    """

    def __init__(self, faults: Optional[BusFaults] = None) -> None:
        self._inboxes: Dict[str, Deque[BusMessage]] = {}
        # dst → [rounds_left, message] entries awaiting release
        self._delayed: Dict[str, List[List]] = {}
        self._lock = threading.Lock()
        self.drop_filter: Optional[Callable[[str, str], bool]] = None
        # named-site crash hook (e.g. LambdaPlatform.maybe_fail); a raise
        # propagates to the sender, modelling an agent dying mid-send
        self.fault_hook: Optional[Callable[[str], None]] = None
        self.messages_sent = 0
        self.records_sent = 0
        self.payload_bytes_sent = 0   # wire-image bytes enqueued (modeled)
        self.messages_dropped = 0
        self.messages_delayed = 0
        self.messages_reordered = 0
        self.messages_duplicated = 0
        self.faults: Optional[BusFaults] = None
        self._rng = random.Random(0)
        if faults is not None:
            self.set_faults(faults)

    def set_faults(self, faults: Optional[BusFaults]) -> None:
        """Install (and re-seed) the fault plan; ``None`` heals the bus."""
        with self._lock:
            self.faults = faults
            self._rng = random.Random(faults.seed if faults else 0)

    # -- membership ----------------------------------------------------------
    def register(self, member_id: str) -> int:
        """(Re-)register a member with an EMPTY inbox.  Returns the number
        of stale messages discarded — a replacement node must not replay its
        predecessor's backlog (it bootstraps from durable storage instead)."""
        with self._lock:
            stale = self._inboxes.get(member_id)
            delayed = self._delayed.pop(member_id, None)
            discarded = (len(stale) if stale else 0) + (
                len(delayed) if delayed else 0)
            self._inboxes[member_id] = deque()
            return discarded

    def unregister(self, member_id: str) -> None:
        with self._lock:
            self._inboxes.pop(member_id, None)
            self._delayed.pop(member_id, None)

    def members(self) -> List[str]:
        with self._lock:
            return list(self._inboxes.keys())

    def inbox_depth(self, member_id: str) -> int:
        """Queued + delayed messages for a member; 0 for unknown members
        (the orphaned-inbox regression probe)."""
        with self._lock:
            inbox = self._inboxes.get(member_id)
            delayed = self._delayed.get(member_id)
            return (len(inbox) if inbox else 0) + (
                len(delayed) if delayed else 0)

    # -- send / receive ------------------------------------------------------
    def send(
        self,
        src: str,
        dst: str,
        records: List[TransactionRecord],
        *,
        seq: Optional[int] = None,
        horizon: Optional[int] = None,
        payload: Optional[bytes] = None,
    ) -> None:
        """``payload`` is the batch's pre-serialized wire envelope
        (``encode_envelope(records)``): agents encode it once per batch and
        pass the same bytes to every peer's send."""
        if not records and seq is None:
            return  # nothing to say and no envelope to advance
        if self.fault_hook is not None:
            self.fault_hook(SEND_FAULT_SITE)  # may raise: sender dies here
        if self.drop_filter is not None and self.drop_filter(src, dst):
            return
        msg = BusMessage(src=src, records=tuple(records),
                         seq=seq, horizon=horizon, payload=payload)
        with self._lock:
            inbox = self._inboxes.get(dst)
            if inbox is None:
                return
            f = self.faults
            if f is not None:
                if f.drop_rate > 0 and self._rng.random() < f.drop_rate:
                    self.messages_dropped += 1
                    return
                if f.delay_rate > 0 and self._rng.random() < f.delay_rate:
                    self._delayed.setdefault(dst, []).append(
                        [max(1, f.delay_rounds), msg])
                    self.messages_delayed += 1
                    return
                if (f.duplicate_rate > 0
                        and self._rng.random() < f.duplicate_rate):
                    inbox.append(msg)
                    self.messages_duplicated += 1
                elif (f.reorder_rate > 0
                        and self._rng.random() < f.reorder_rate):
                    inbox.appendleft(msg)
                    self.messages_reordered += 1
                    self.messages_sent += 1
                    self.records_sent += len(records)
                    if payload is not None:
                        self.payload_bytes_sent += len(payload)
                    return
            inbox.append(msg)
            self.messages_sent += 1
            self.records_sent += len(records)
            if payload is not None:
                self.payload_bytes_sent += len(payload)

    def _release_delayed(self, member_id: str) -> None:
        # caller holds self._lock
        entries = self._delayed.get(member_id)
        if not entries:
            return
        inbox = self._inboxes.get(member_id)
        still_held: List[List] = []
        for entry in entries:
            entry[0] -= 1
            if entry[0] <= 0 and inbox is not None:
                inbox.append(entry[1])
            else:
                still_held.append(entry)
        if still_held:
            self._delayed[member_id] = still_held
        else:
            del self._delayed[member_id]

    def drain_messages(self, member_id: str) -> List[BusMessage]:
        """Drain a member's inbox (releasing due delayed messages first)."""
        with self._lock:
            self._release_delayed(member_id)
            inbox = self._inboxes.get(member_id)
            if not inbox:
                return []
            out = list(inbox)
            inbox.clear()
            return out

    def drain(self, member_id: str) -> List[Tuple[str, List[TransactionRecord]]]:
        """Legacy record-stream view of ``drain_messages`` — the shape
        ``FaultManager.ingest`` consumes (empty heartbeats filtered out)."""
        return [(m.src, list(m.records))
                for m in self.drain_messages(member_id) if m.records]


class MulticastAgent:
    """Per-node §4 agent: eagerly push each commit's metadata to peers as it
    becomes visible (the read fast path), periodically stream the unpruned
    fresh-commit log to the fault manager (§4.2), heartbeat the node's
    commit horizon, and merge everything received — tracking each peer's
    horizon along a contiguous sequence prefix to feed the node's read
    watermark."""

    def __init__(
        self,
        node: AftNode,
        bus: MulticastBus,
        peers: Callable[[], List[str]],
        *,
        eager_push: bool = True,
        gap_repair_rounds: int = 5,
    ):
        self.node = node
        self.bus = bus
        self.peers = peers  # live membership comes from the cluster manager
        self.eager_push = eager_push
        self.gap_repair_rounds = gap_repair_rounds
        self.bus.register(node.node_id)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._seq_lock = threading.Lock()
        # serializes whole §4 rounds: the background loop and foreign-thread
        # callers (retire's final flush, step_all) share receiver state
        self._step_lock = threading.Lock()
        self._seq = 0  # this node's broadcast counter (per-source contiguity)
        # receiver-side horizon tracking, all keyed by source node id
        self._next_seq: Dict[str, int] = {}
        self._pending: Dict[str, Dict[int, int]] = {}  # src → seq → horizon
        self._gap_rounds: Dict[str, int] = {}
        self.peer_horizons: Dict[str, int] = {}
        self.pruned_total = 0
        self.broadcast_total = 0
        self.eager_pushes = 0
        self.send_failures = 0
        self.gap_repairs = 0
        # encode-once accounting: envelopes serialized vs. peer sends that
        # shared them (the pre-PR behavior was one encode per peer)
        self.envelope_encodes = 0
        self.envelope_shares = 0
        node.set_watermark_provider(self._watermark_floor)
        if eager_push:
            node.set_commit_listener(self._on_commit)

    # -- eager push (commit-time fan-out) ------------------------------------
    def _on_commit(self, record: TransactionRecord) -> None:
        """Commit listener: push one freshly-visible record to every peer.
        Best-effort — a failed send is healed by the fault manager's §4.2
        anti-entropy scan, so errors are counted, never raised into the
        committing client's path.  Deliberately UNpruned: the message's
        horizon claims coverage of this commit, and a receiver's snapshot
        watermark may sit below the superseding rival's timestamp — pruning
        here would let a snapshot read miss an in-bound version.  §4.1
        pruning stays on the periodic batch path."""
        if not self.node.alive:
            return
        with self._seq_lock:
            self._seq += 1
            seq = self._seq
            horizon = self.node.commit_horizon_ns()
        # serialize the batch's wire envelope once; every peer's message
        # shares the same bytes object (encode-once fan-out)
        batch = [record]
        payload = encode_envelope(batch)
        self.envelope_encodes += 1
        sent = False
        for peer in self.peers():
            if peer == self.node.node_id:
                continue
            try:
                self.bus.send(self.node.node_id, peer, batch,
                              seq=seq, horizon=horizon, payload=payload)
                self.envelope_shares += 1
                sent = True
            except Exception:
                self.send_failures += 1
        if sent:
            self.eager_pushes += 1
            self.broadcast_total += 1

    # -- one §4 round --------------------------------------------------------
    def step(self) -> None:
        if not self.node.alive:
            return
        with self._step_lock:
            self._step()

    def _step(self) -> None:
        # horizon BEFORE draining: every commit visible after this point is
        # either in the drained batch (announced now) or has a timestamp
        # above the horizon (in-flight commits cap it) — so the claim
        # "all commits ≤ horizon are durable" rides the same message as the
        # records it covers
        horizon = self.node.commit_horizon_ns()
        fresh = self.node.drain_fresh_commits()
        if fresh:
            # fault manager always receives the unpruned set (§4.2);
            # serialized once (the record encodes are memoized, so this
            # reuses the commit-time bytes rather than re-encoding)
            try:
                self.bus.send(self.node.node_id, FAULT_MANAGER_ID,
                              list(fresh), payload=encode_envelope(fresh))
                self.envelope_encodes += 1
            except Exception:
                self.send_failures += 1
        # §4.1 pruning accounting runs every round; with eager push the
        # records already reached the peers at commit time, so the periodic
        # broadcast degrades to a horizon heartbeat
        outgoing = [r for r in fresh if not is_superseded(r, self.node.cache)]
        self.pruned_total += len(fresh) - len(outgoing)
        to_peers: List[TransactionRecord] = (
            [] if self.eager_push else outgoing)
        # one envelope per round, shared across every peer (encode-once)
        payload: Optional[bytes] = None
        if to_peers:
            payload = encode_envelope(to_peers)
            self.envelope_encodes += 1
        with self._seq_lock:
            self._seq += 1
            seq = self._seq
        for peer in self.peers():
            if peer == self.node.node_id:
                continue
            try:
                self.bus.send(self.node.node_id, peer, to_peers,
                              seq=seq, horizon=horizon, payload=payload)
                if payload is not None:
                    self.envelope_shares += 1
            except Exception:
                self.send_failures += 1
        if to_peers:
            self.broadcast_total += len(to_peers)
        # merge inbound announcements (receiver-side supersedence check is
        # inside merge_remote_commits) and fold horizons
        for msg in self.bus.drain_messages(self.node.node_id):
            if msg.records:
                try:
                    self.node.merge_remote_commits(list(msg.records))
                except Exception:
                    if not self.node.alive:
                        return
                    raise
            if msg.seq is not None and msg.horizon is not None:
                self._ingest_horizon(msg.src, msg.seq, msg.horizon)
        self._repair_gaps()

    # -- horizon tracking ----------------------------------------------------
    def _ingest_horizon(self, src: str, seq: int, horizon: int) -> None:
        nxt = self._next_seq.get(src)
        if nxt is None:
            # first contact: only the stream head is a sound baseline —
            # anything later may hide dropped announcements before it
            if seq == 1:
                self._next_seq[src] = 2
                self._adopt_horizon(src, horizon)
                self._drain_pending(src)
            else:
                self._pending.setdefault(src, {})[seq] = horizon
            return
        if seq < nxt:
            return  # duplicate / already covered
        if seq == nxt:
            self._next_seq[src] = nxt + 1
            self._adopt_horizon(src, horizon)
            self._drain_pending(src)
        else:
            self._pending.setdefault(src, {})[seq] = horizon

    def _drain_pending(self, src: str) -> None:
        pend = self._pending.get(src)
        if not pend:
            return
        nxt = self._next_seq[src]
        while nxt in pend:
            self._adopt_horizon(src, pend.pop(nxt))
            nxt += 1
        self._next_seq[src] = nxt
        if not pend:
            self._pending.pop(src, None)

    def _adopt_horizon(self, src: str, horizon: int) -> None:
        if horizon > self.peer_horizons.get(src, -1):
            self.peer_horizons[src] = horizon

    def _repair_gaps(self) -> None:
        """A persistent sequence gap (dropped message) stalls a peer's
        horizon; after ``gap_repair_rounds`` rounds, re-scan the durable
        commit set and jump past the gap.  Sound: every commit covered by
        the horizon of the newest pending message was durably recorded
        before that message was sent, so the scan observes it."""
        for src in list(self._pending.keys()):
            pend = self._pending.get(src)
            if not pend:
                self._gap_rounds.pop(src, None)
                continue
            rounds = self._gap_rounds.get(src, 0) + 1
            if rounds < self.gap_repair_rounds:
                self._gap_rounds[src] = rounds
                continue
            try:
                self.node.bootstrap()
            except Exception:
                if not self.node.alive:
                    return
                raise
            if not pend:  # drained while bootstrap() ran
                self._gap_rounds.pop(src, None)
                continue
            top = max(pend)
            self._adopt_horizon(src, pend[top])
            self._next_seq[src] = top + 1
            pend.clear()
            self._pending.pop(src, None)
            self._gap_rounds.pop(src, None)
            self.gap_repairs += 1

    def forget_peer(self, peer_id: str) -> None:
        """A peer RETIRED (graceful leave, ``core/cluster.py``): drop its
        horizon-tracking state so a sequence gap it left behind can never
        trigger a pointless full re-bootstrap, and its stale horizon can
        never be misread if a future node reuses the id.  The watermark
        floor needs no change — it re-evaluates CURRENT membership every
        call, so the retired peer already stopped gating it."""
        with self._step_lock:
            self._next_seq.pop(peer_id, None)
            self._pending.pop(peer_id, None)
            self._gap_rounds.pop(peer_id, None)
            self.peer_horizons.pop(peer_id, None)

    def _watermark_floor(self) -> Optional[int]:
        """Min of live peers' horizons, re-evaluated against CURRENT
        membership on every call (a freshly-joined peer floors the
        watermark at -1 until heard from — fail-safe).  None ⇒ no peers,
        the node's own horizon stands alone."""
        peer_ids = [p for p in self.peers() if p != self.node.node_id]
        if not peer_ids:
            return None
        return min(self.peer_horizons.get(p, -1) for p in peer_ids)

    # -- threading -----------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                self.step()
                self._stop.wait(self.node.config.multicast_interval_s)

        self._thread = threading.Thread(
            target=loop, name=f"multicast-{self.node.node_id}", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        self.bus.unregister(self.node.node_id)
