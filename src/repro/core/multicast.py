"""Commit-set multicast with supersedence pruning (§4, §4.1).

Each node runs a background agent that periodically (default 1 s) gathers the
transactions committed locally since the last round, prunes any that are
already locally superseded (Algorithm 2 — "for highly contended workloads …
this significantly reduces the volume of metadata"), and broadcasts the rest
to every peer.  The *unpruned* set always goes to the fault manager (§4.2),
which is what makes commit announcements loss-proof.

Components expose a synchronous ``step()`` so tests and deterministic
simulations can drive rounds manually; ``start()`` runs the same step on a
daemon thread.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .ids import TxnId
from .node import AftNode
from .records import TransactionRecord
from .supersede import is_superseded


class MulticastBus:
    """In-process message fabric between AFT nodes and the fault manager.

    Models the paper's point-to-point broadcast; an optional delivery delay
    and drop hook let tests exercise races (commit acknowledged → node dies
    before broadcast — the §4.2 liveness scenario).
    """

    def __init__(self) -> None:
        self._inboxes: Dict[str, "queue.SimpleQueue[Tuple[str, List[TransactionRecord]]]"] = {}
        self._lock = threading.Lock()
        self.drop_filter: Optional[Callable[[str, str], bool]] = None
        self.messages_sent = 0
        self.records_sent = 0

    def register(self, member_id: str) -> None:
        with self._lock:
            self._inboxes.setdefault(member_id, queue.SimpleQueue())

    def unregister(self, member_id: str) -> None:
        with self._lock:
            self._inboxes.pop(member_id, None)

    def members(self) -> List[str]:
        with self._lock:
            return list(self._inboxes.keys())

    def send(
        self, src: str, dst: str, records: List[TransactionRecord]
    ) -> None:
        if not records:
            return
        if self.drop_filter is not None and self.drop_filter(src, dst):
            return
        with self._lock:
            inbox = self._inboxes.get(dst)
        if inbox is None:
            return
        inbox.put((src, records))
        self.messages_sent += 1
        self.records_sent += len(records)

    def drain(self, member_id: str) -> List[Tuple[str, List[TransactionRecord]]]:
        with self._lock:
            inbox = self._inboxes.get(member_id)
        out: List[Tuple[str, List[TransactionRecord]]] = []
        if inbox is None:
            return out
        while True:
            try:
                out.append(inbox.get_nowait())
            except queue.Empty:
                return out


FAULT_MANAGER_ID = "fault-manager"


class MulticastAgent:
    """Per-node §4 background thread: broadcast fresh commits (pruned) to
    peers + (unpruned) to the fault manager; merge everything received."""

    def __init__(self, node: AftNode, bus: MulticastBus, peers: Callable[[], List[str]]):
        self.node = node
        self.bus = bus
        self.peers = peers  # live membership comes from the cluster manager
        self.bus.register(node.node_id)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.pruned_total = 0
        self.broadcast_total = 0

    # -- one §4 round --------------------------------------------------------
    def step(self) -> None:
        if not self.node.alive:
            return
        fresh = self.node.drain_fresh_commits()
        if fresh:
            # fault manager always receives the unpruned set (§4.2)
            self.bus.send(self.node.node_id, FAULT_MANAGER_ID, list(fresh))
            # peers receive the §4.1-pruned set
            outgoing = [r for r in fresh if not is_superseded(r, self.node.cache)]
            self.pruned_total += len(fresh) - len(outgoing)
            if outgoing:
                for peer in self.peers():
                    if peer != self.node.node_id:
                        self.bus.send(self.node.node_id, peer, outgoing)
                self.broadcast_total += len(outgoing)
        # merge inbound announcements (receiver-side supersedence check is
        # inside merge_remote_commits)
        for _src, records in self.bus.drain(self.node.node_id):
            try:
                self.node.merge_remote_commits(records)
            except Exception:
                if not self.node.alive:
                    return
                raise

    # -- threading -----------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                self.step()
                self._stop.wait(self.node.config.multicast_interval_s)

        self._thread = threading.Thread(
            target=loop, name=f"multicast-{self.node.node_id}", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        self.bus.unregister(self.node.node_id)
