"""Fault manager + global garbage collector (§4.2, §5.2).

A stateless process outside the request critical path that guarantees
*liveness* for the distributed protocols:

* it receives every node's committed-transaction stream **without** the
  pruning optimization;
* it periodically scans the durable Transaction Commit Set for records it
  never saw via broadcast (a node committed, acknowledged, and died before
  multicasting) and notifies all nodes — committed data can never be silently
  lost (§4.2);
* it drives the two-phase global data GC (§5.2): propose superseded
  transactions, gather *all* nodes' locally-deleted confirmations, and only
  then delete version bytes + commit records from storage, on a dedicated
  deletion executor ("we allocate separate cores for the data deletion
  process");
* it monitors node heartbeats and replaces failed nodes from a standby pool
  (§4.3/§6.7 — the Kubernetes role), and sweeps orphaned buffer spills.

Statelessness: if the fault manager itself dies, a fresh one rebuilds its
view by re-scanning the Commit Set (§4.2).
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Set

from ..obs.registry import Registry
from ..storage.base import StorageEngine
from .commit_cache import CommitSetCache
from .ids import TxnId
from .multicast import FAULT_MANAGER_ID, MulticastBus
from .node import AftNode
from .records import (
    COMMIT_PREFIX,
    DATA_PREFIX,
    TransactionRecord,
    WF_FINISH_PREFIX,
    commit_key,
    uuid_key,
)
from .supersede import is_superseded


@dataclass
class FaultManagerConfig:
    scan_interval_s: float = 1.0
    gc_interval_s: float = 1.0
    heartbeat_interval_s: float = 1.0
    heartbeat_misses: int = 3
    orphan_spill_age_s: float = 120.0
    gc_batch: int = 512
    delete_batch: int = 256
    # age before a cached record whose commit key vanished from storage is
    # dropped from the aggregate view (eventual-consistency listing slack)
    prune_grace_s: float = 5.0
    # how long a w/<uuid> finish marker outlives the workflow before the
    # fault manager MAY retire it — every node's GC agent must get a chance
    # to purge its own metadata cache (core/gc.py).  Age alone is not
    # sufficient: retirement additionally requires every live node to have
    # ACKED the marker (AftNode.ack_workflow_marker), because deleting a
    # marker some node never swept orphans that node's .wf/ memo records
    # forever (the sweep is licensed exclusively by the marker).
    workflow_marker_ttl_s: float = 30.0
    # liveness backstop: a node whose GC agent never runs must not pin
    # markers indefinitely — ADDITIONAL grace beyond workflow_marker_ttl_s
    # after which a marker retires regardless of acks, accepting the
    # bounded staleness the old TTL-only policy had.  Measured from the
    # soft TTL so that raising workflow_marker_ttl_s can never overtake the
    # backstop and silently disable ack gating.
    workflow_marker_max_ttl_s: float = 600.0


class DeletionExecutor:
    """Dedicated batched-delete worker (§5.2: separate cores for deletes)."""

    def __init__(self, storage: StorageEngine, batch: int = 256):
        self.storage = storage
        self.batch = batch
        self._pending: List[str] = []
        self._lock = threading.Lock()
        self.deleted_total = 0

    def submit(self, keys: Iterable[str]) -> None:
        with self._lock:
            self._pending.extend(keys)

    def step(self) -> int:
        with self._lock:
            chunk, self._pending = (
                self._pending[: self.batch],
                self._pending[self.batch :],
            )
        if chunk:
            self.storage.delete_batch(chunk)
            self.deleted_total += len(chunk)
        return len(chunk)

    def drain(self) -> int:
        n = 0
        while True:
            done = self.step()
            if not done:
                return n
            n += done

    def backlog(self) -> int:
        with self._lock:
            return len(self._pending)


class FaultManager:
    def __init__(
        self,
        storage: StorageEngine,
        bus: MulticastBus,
        membership: Callable[[], List[AftNode]],
        config: Optional[FaultManagerConfig] = None,
        on_node_failure: Optional[Callable[[AftNode], None]] = None,
        ack_membership: Optional[Callable[[], List[AftNode]]] = None,
    ) -> None:
        self.storage = storage
        self.bus = bus
        self.membership = membership
        # the GC marker-ack quorum may be narrower than full membership: an
        # elastic cluster passes LIVE/JOINING members only, so a DRAINING or
        # RETIRED node never stalls marker retirement (it acked its last
        # sweep before detaching, or its metadata died with it)
        self.ack_membership = ack_membership
        self.config = config or FaultManagerConfig()
        self.on_node_failure = on_node_failure
        self.bus.register(FAULT_MANAGER_ID)
        self.cache = CommitSetCache()  # aggregate (unpruned) view
        self.deleter = DeletionExecutor(storage, self.config.delete_batch)
        self._seen_commit_keys: Set[str] = set()
        self._failed_reported: Set[str] = set()
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self.stats: Dict[str, int] = {
            "recovered_commits": 0,
            "gc_deleted_txns": 0,
            "orphan_spills_deleted": 0,
            "nodes_replaced": 0,
        }
        # gossip-fed per-node registry snapshots (repro/obs): the fault
        # manager is the cluster-wide observer, so the merged metrics view
        # lives here alongside the aggregate commit view
        self._metrics_lock = threading.Lock()
        self._node_metrics: Dict[str, dict] = {}

    # ----------------------------------------------------- metrics (obs)
    def ingest_metrics(self, snapshots: Dict[str, dict]) -> None:
        """Accept per-node registry snapshots (from a ``MetricsPlane``
        gossip round, or any out-of-band push); newest wins per node."""
        with self._metrics_lock:
            self._node_metrics.update(snapshots)

    def collect_metrics(self) -> int:
        """Direct (no-gossip) refresh: snapshot every live member's
        registry in-process.  The fallback path when the jax collective
        plane isn't running — same merged view, no ICI round."""
        fresh = {
            node.node_id: node.registry.snapshot()
            for node in self.membership()
            if node.alive
        }
        self.ingest_metrics(fresh)
        return len(fresh)

    def cluster_metrics(self) -> Dict[str, dict]:
        """``{"nodes": {node_id: snapshot}, "cluster": merged}`` — the
        cluster view is :meth:`Registry.merge` over the per-node snapshots
        (counters summed, ``*_rate`` gauges averaged, histograms merged)."""
        with self._metrics_lock:
            nodes = {k: dict(v) for k, v in self._node_metrics.items()}
        return {"nodes": nodes, "cluster": Registry.merge(list(nodes.values()))}

    # ------------------------------------------------------------ ingestion
    def ingest(self) -> int:
        """Drain unpruned commit streams from all nodes."""
        n = 0
        for _src, records in self.bus.drain(FAULT_MANAGER_ID):
            for record in records:
                self.cache.add(record)
                self._seen_commit_keys.add(commit_key(record.tid))
                n += 1
        return n

    # --------------------------------------------------------- §4.2 liveness
    def scan_commit_set(self) -> int:
        """Find durable commit records never announced via broadcast and
        notify all nodes — the committed-then-died-pre-broadcast case."""
        self.ingest()
        keys = self.storage.list_keys(COMMIT_PREFIX)
        self._prune_deleted(set(keys))
        missing = [k for k in keys if k not in self._seen_commit_keys]
        if not missing:
            return 0
        raws = self.storage.get_batch(missing)
        recovered: List[TransactionRecord] = []
        for k in missing:
            raw = raws.get(k)
            if raw is None:
                continue  # deleted between list and get (GC race) — fine
            record = TransactionRecord.decode(raw)
            self.cache.add(record)
            self._seen_commit_keys.add(k)
            recovered.append(record)
        if recovered:
            for node in self.membership():
                if node.alive:
                    node.merge_remote_commits(recovered)
            self.stats["recovered_commits"] += len(recovered)
        return len(recovered)

    def _prune_deleted(self, present_commit_keys: Set[str]) -> int:
        """Drop aggregate-view records whose commit record no longer exists
        in storage — someone (global GC phase 2, or the finished-workflow
        sweep in ``core/gc.py``) durably deleted them.  Write ordering makes
        this sound: a record only enters this cache *after* its commit key
        was durably persisted (§3.3), so absent-from-storage means deleted,
        never not-yet-written.  A grace period absorbs eventually-consistent
        listing lag for fresh commits.  Without this, memo-record GC would
        bound every node's footprint but leave the fault manager's unpruned
        view growing forever."""
        cutoff_ns = time.time_ns() - int(self.config.prune_grace_s * 1e9)
        pruned = 0
        for record in self.cache.snapshot_records():
            ck = commit_key(record.tid)
            if ck in present_commit_keys or record.tid.timestamp > cutoff_ns:
                continue
            self.cache.remove(record.tid)
            self._seen_commit_keys.discard(ck)
            pruned += 1
        if pruned:
            self.stats["pruned_deleted"] = (
                self.stats.get("pruned_deleted", 0) + pruned
            )
        return pruned

    # ------------------------------------------------------------- §5.2 GC
    def gc_round(self) -> int:
        """Two-phase global data GC.  Returns transactions fully deleted."""
        self.ingest()
        nodes = [n for n in self.membership() if n.alive]
        if not nodes:
            return 0
        # phase 0: propose superseded transactions from the aggregate view
        candidates = [
            r
            for r in self.cache.snapshot_records()
            if is_superseded(r, self.cache)
        ][: self.config.gc_batch]
        if not candidates:
            return 0
        # phase 1: all nodes must confirm local deletion — "when the GC
        # process receives acknowledgements from all nodes, it deletes ..."
        # (full records travel with the proposal: a node that never learned
        # a commit still has to tombstone its keys for the snapshot fence)
        confirmed: Set[TxnId] = {r.tid for r in candidates}
        for node in nodes:
            confirmed &= set(node.confirm_locally_deleted(candidates))
            if not confirmed:
                return 0
        # phase 2: delete version bytes + commit records (batched, off-path)
        doomed = [r for r in candidates if r.tid in confirmed]
        keys: List[str] = []
        for record in doomed:
            keys.extend(record.storage_key_for(k) for k in record.write_set)
            keys.append(commit_key(record.tid))
            # the §3.3.1 uuid index travels with its commit record, else
            # every GC'd transaction leaks one u/ key forever
            keys.append(uuid_key(record.tid.uuid))
        self.deleter.submit(keys)
        for record in doomed:
            self.cache.remove(record.tid)
            self._seen_commit_keys.discard(commit_key(record.tid))
        for node in nodes:
            node.forget_deleted(confirmed)
        self.stats["gc_deleted_txns"] += len(doomed)
        return len(doomed)

    # ---------------------------------------------- finished-marker retiring
    def sweep_finished_markers(self) -> int:
        """Retire ``w/<uuid>`` workflow finish markers the cluster is done
        with.

        The marker is the GC license every node's local agent consumes
        (storage sweep + own-cache purge, ``core/gc.py``), so retirement is
        gated on BOTH: (1) age past ``workflow_marker_ttl_s``, and (2) every
        live node having acked the marker (``AftNode.ack_workflow_marker``,
        set by its ``LocalGcAgent``).  TTL alone — the historical policy —
        raced slow agents: deleting a marker no agent had consumed orphaned
        that workflow's ``.wf/`` memo records *forever*, because the marker
        is the only thing that licenses their reclamation.  Past
        ``workflow_marker_max_ttl_s`` the marker retires regardless (a node
        whose agent never runs must not pin storage), restoring the old
        bounded-staleness behavior as a liveness backstop.

        Unparsable markers are **quarantined**, not deleted: the payload is
        re-stamped with a fresh timestamp (plus a ``quarantined`` breadcrumb)
        so the marker keeps its GC-license role — agents key off the marker
        *key*, not its payload — and ages toward ack-gated retirement like
        any other.  The old treat-as-ancient rule deleted them immediately,
        which was the same orphaning race with certainty instead of chance."""
        now_ns = time.time_ns()
        cutoff_ns = now_ns - int(self.config.workflow_marker_ttl_s * 1e9)
        # the backstop is ADDITIONAL grace past the soft TTL: an absolute
        # age would let an operator who raises workflow_marker_ttl_s past
        # it silently disable ack gating (every marker old enough for the
        # ack check would already satisfy the hard cutoff)
        hard_cutoff_ns = now_ns - int(
            (
                self.config.workflow_marker_ttl_s
                + self.config.workflow_marker_max_ttl_s
            ) * 1e9
        )
        markers = self.storage.list_keys(WF_FINISH_PREFIX)
        if not markers:
            return 0
        ack_src = self.ack_membership or self.membership
        live = [n for n in ack_src() if n.alive]
        doomed: List[str] = []
        raws = self.storage.get_batch(markers)
        for marker in markers:
            raw = raws.get(marker)
            if raw is None:
                continue
            try:
                finished_at = int(json.loads(raw)["finished_at_ns"])
            except Exception:
                self.storage.put(
                    marker,
                    json.dumps(
                        {"finished_at_ns": now_ns, "quarantined": True}
                    ).encode(),
                )
                self.stats["finish_markers_quarantined"] = (
                    self.stats.get("finish_markers_quarantined", 0) + 1
                )
                continue
            if finished_at > cutoff_ns:
                continue  # too young even for ack-gated retirement
            wf_uuid = marker[len(WF_FINISH_PREFIX):]
            # an empty live set (all nodes dead mid-replacement) must NOT
            # satisfy the gate vacuously: the promoted replacement's agent
            # still needs the marker, so only the hard cutoff applies
            all_acked = bool(live) and all(
                node.workflow_marker_acked(wf_uuid) for node in live
            )
            if all_acked or finished_at <= hard_cutoff_ns:
                doomed.append(marker)
        if doomed:
            self.deleter.submit(doomed)
            self.stats["finish_markers_retired"] = (
                self.stats.get("finish_markers_retired", 0) + len(doomed)
            )
        return len(doomed)

    # ------------------------------------------------- orphaned spill sweep
    def sweep_orphan_spills(self) -> int:
        """Delete pre-commit buffer spills whose transaction never committed
        (node crashed between spill and commit record, §3.3/§5)."""
        referenced: Set[str] = set()
        for record in self.cache.snapshot_records():
            referenced.update(record.storage_keys.values())
        now_ns = time.time_ns()
        doomed: List[str] = []
        for skey in self.storage.list_keys(DATA_PREFIX):
            if "/.spill/" not in skey or skey in referenced:
                continue
            doomed.append(skey)
        if doomed:
            self.deleter.submit(doomed)
            self.stats["orphan_spills_deleted"] += len(doomed)
        return len(doomed)

    # ------------------------------------------------------------ liveness
    def check_heartbeats(self) -> List[str]:
        """Detect dead nodes and trigger replacement (§6.7)."""
        failed: List[str] = []
        for node in self.membership():
            if not node.alive and node.node_id not in self._failed_reported:
                self._failed_reported.add(node.node_id)
                failed.append(node.node_id)
                if self.on_node_failure is not None:
                    self.on_node_failure(node)
                self.stats["nodes_replaced"] += 1
        return failed

    # ------------------------------------------------------------- driving
    def step(self) -> None:
        self.ingest()
        self.scan_commit_set()
        self.gc_round()
        self.sweep_finished_markers()
        self.deleter.step()
        self.check_heartbeats()

    def start(self) -> None:
        if self._threads:
            return
        self._stop.clear()

        def control_loop() -> None:
            while not self._stop.is_set():
                try:
                    self.ingest()
                    self.scan_commit_set()
                    self.gc_round()
                    self.sweep_finished_markers()
                    self.check_heartbeats()
                except Exception:
                    pass  # stateless: next round rebuilds what it needs
                self._stop.wait(self.config.scan_interval_s)

        def delete_loop() -> None:  # the "separate core"
            while not self._stop.is_set():
                if not self.deleter.step():
                    self._stop.wait(self.config.gc_interval_s / 4 + 0.01)

        for name, target in (
            ("fault-manager", control_loop),
            ("gc-deleter", delete_loop),
        ):
            t = threading.Thread(target=target, name=name, daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)
        self._threads.clear()


# ---------------------------------------------------------------- autoscaler
@dataclass
class AutoscalerConfig:
    """Policy knobs for :class:`Autoscaler` (Cloudburst-style signals over
    the obs :class:`~repro.obs.registry.Registry`)."""

    min_nodes: int = 1
    max_nodes: int = 8
    # load signal: mean (open_sessions + inflight_ops) per routable node
    scale_up_load: float = 6.0
    scale_down_load: float = 1.0
    # latency signal: merged commit.total p99 must exceed this to scale up
    # even when the load signal alone is borderline (0 disables the gate)
    scale_up_p99_ms: float = 0.0
    # persistence: a decision needs this many CONSECUTIVE ticks past
    # threshold — one bursty sample must not flap membership
    up_ticks: int = 2
    down_ticks: int = 4
    # cooldowns (seconds) after a membership change in either direction
    up_cooldown_s: float = 0.5
    down_cooldown_s: float = 2.0
    # hot-arc splitting: split when the hottest arc carries at least this
    # multiple of the mean arc load (router must support split_hot_arc)
    split_ratio: float = 4.0
    split_cooldown_s: float = 1.0
    tick_interval_s: float = 0.25


class Autoscaler:
    """Watches the cluster's merged metrics view and issues elastic
    membership decisions: ``scale-up`` (join a ramping node), ``scale-down``
    (drain the last-joined node — never kill), and ``split`` (hot-arc
    midpoint split on the ring).

    Signals come from the obs :class:`Registry` snapshots the fault manager
    aggregates (gossip-fed, or :meth:`FaultManager.collect_metrics` direct
    refresh): per-node ``open_sessions``/``inflight_ops`` gauges for load
    and the merged ``commit.total`` histogram p99 for latency.  Decisions
    are serialized — while any node is JOINING or DRAINING the autoscaler
    only ticks :meth:`AftCluster.advance_lifecycle` and waits, so at most
    one migration is in flight at a time and warm-up handoff bandwidth is
    never split."""

    def __init__(
        self,
        cluster,  # AftCluster (untyped to avoid the import cycle)
        fm: FaultManager,
        config: Optional[AutoscalerConfig] = None,
    ) -> None:
        self.cluster = cluster
        self.fm = fm
        self.config = config or AutoscalerConfig()
        self.events: List[Dict[str, object]] = []
        self._up_streak = 0
        self._down_streak = 0
        self._last_scale_at = 0.0
        self._last_split_at = 0.0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -------------------------------------------------------------- signals
    def _load_signal(self) -> float:
        """Mean (open_sessions + inflight_ops) per routable node."""
        view = self.fm.cluster_metrics()["nodes"]
        routable = {n.node_id for n in self.cluster.routable_nodes()}
        loads = [
            snap.get("open_sessions", 0.0) + snap.get("inflight_ops", 0.0)
            for node_id, snap in view.items()
            if node_id in routable
        ]
        if not loads:
            return 0.0
        return sum(loads) / len(loads)

    def _p99_ms(self) -> float:
        merged = self.fm.cluster_metrics()["cluster"]
        hist = merged.get("commit.total")
        if isinstance(hist, dict):
            return float(hist.get("p99_ms", 0.0))
        return 0.0

    def _migration_in_flight(self) -> bool:
        from .cluster import NodeLifecycle  # late import: avoid cycle

        with self.cluster._lock:
            states = [
                self.cluster.lifecycle.get(n.node_id)
                for n in self.cluster.nodes
            ]
        return any(
            s in (NodeLifecycle.JOINING, NodeLifecycle.DRAINING)
            for s in states
        )

    def _record(self, kind: str, **detail: object) -> None:
        self.events.append({"event": kind, "at": time.monotonic(), **detail})

    # ----------------------------------------------------------------- tick
    def step(self) -> Optional[str]:
        """One policy tick.  Returns the decision taken (``"scale-up"``,
        ``"scale-down"``, ``"split"``) or ``None``."""
        cfg = self.config
        # keep in-flight migrations moving before (and instead of) deciding
        self.cluster.advance_lifecycle()
        if self._migration_in_flight():
            return None
        self.fm.collect_metrics()
        load = self._load_signal()
        p99 = self._p99_ms()
        n = len(self.cluster.live_nodes())
        now = time.monotonic()

        if load >= cfg.scale_up_load and (
            cfg.scale_up_p99_ms <= 0.0 or p99 >= cfg.scale_up_p99_ms
        ):
            self._up_streak += 1
            self._down_streak = 0
        elif load <= cfg.scale_down_load:
            self._down_streak += 1
            self._up_streak = 0
        else:
            self._up_streak = 0
            self._down_streak = 0

        if (
            self._up_streak >= cfg.up_ticks
            and n < cfg.max_nodes
            and now - self._last_scale_at >= cfg.up_cooldown_s
        ):
            node = self.cluster.join_node(ramp=True)
            self._up_streak = 0
            self._last_scale_at = now
            self._record(
                "scale-up", node=node.node_id, load=load, p99_ms=p99, nodes=n
            )
            return "scale-up"

        if (
            self._down_streak >= cfg.down_ticks
            and n > cfg.min_nodes
            and now - self._last_scale_at >= cfg.down_cooldown_s
        ):
            victim = self.cluster.live_nodes()[-1]
            self.cluster.drain_node(victim, wait=False)
            self._down_streak = 0
            self._last_scale_at = now
            self._record(
                "scale-down", node=victim.node_id, load=load, nodes=n
            )
            return "scale-down"

        # hot-arc split: rebalance without changing the node count
        split_hot = getattr(self.cluster.router, "split_hot_arc", None)
        hottest = getattr(self.cluster.router, "hottest_arc", None)
        if (
            split_hot is not None
            and hottest is not None
            and now - self._last_split_at >= cfg.split_cooldown_s
        ):
            hot = hottest()
            if hot is not None:
                arc_hash, owner, arc_load, mean = hot
                if mean > 0 and arc_load / mean >= cfg.split_ratio:
                    coldest = self._coldest_node(exclude=owner)
                    if coldest is not None and split_hot(
                        coldest, min_ratio=cfg.split_ratio
                    ):
                        self._last_split_at = now
                        self._record(
                            "split",
                            arc=arc_hash,
                            from_node=owner,
                            to_node=coldest,
                        )
                        decay = getattr(
                            self.cluster.router, "decay_arc_loads", None
                        )
                        if decay is not None:
                            decay()
                        return "split"
        return None

    def _coldest_node(self, exclude: str) -> Optional[str]:
        view = self.fm.cluster_metrics()["nodes"]
        best_id, best_load = None, None
        for node in self.cluster.routable_nodes():
            if node.node_id == exclude:
                continue
            snap = view.get(node.node_id, {})
            load = snap.get("open_sessions", 0.0) + snap.get(
                "inflight_ops", 0.0
            )
            if best_load is None or load < best_load:
                best_id, best_load = node.node_id, load
        return best_id

    # -------------------------------------------------------------- driving
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                try:
                    self.step()
                except Exception:
                    pass  # policy is advisory; next tick retries
                self._stop.wait(self.config.tick_interval_s)

        self._thread = threading.Thread(
            target=loop, name="autoscaler", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
