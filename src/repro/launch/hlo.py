"""HLO-text analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective traffic, so
we parse the optimized (post-SPMD, per-device) HLO and sum operand bytes of
every collective op, bucketed by kind.  Ops inside ``while`` bodies appear
once in the text — the roofline tool corrects trip counts via the
instrumented-scan tree (see ``models/scan.py``/``roofline.py``).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+([\w\-]+)(?:\.\d+)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    """Per-op-kind operand bytes (per-device program => per-chip traffic)."""
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def add(self, other: "CollectiveStats", factor: int = 1) -> None:
        for k, v in other.bytes_by_kind.items():
            self.bytes_by_kind[k] = self.bytes_by_kind.get(k, 0) + v * factor
        for k, v in other.count_by_kind.items():
            self.count_by_kind[k] = self.count_by_kind.get(k, 0) + v * factor

    def scaled(self, factor: float) -> "CollectiveStats":
        out = CollectiveStats()
        out.bytes_by_kind = {k: int(v * factor)
                             for k, v in self.bytes_by_kind.items()}
        out.count_by_kind = dict(self.count_by_kind)
        return out


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))      # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 1


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Per-chip operand bytes of every collective instruction.

    Operand shapes are not reliably printed for instructions inside nested
    (e.g. shard_map manual) computations, so bytes derive from the RESULT
    shape + replica-group size N:

      all-reduce          operand = result
      all-gather          operand = result / N        (the local shard)
      reduce-scatter      operand = result · N        (the unreduced input)
      all-to-all / *      operand = result            (bytes conserved)
    """
    stats = CollectiveStats()
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        result_ty, opcode = m.groups()
        base = None
        for k in COLLECTIVE_OPS:
            if opcode == k or opcode.startswith(k + "-"):
                base = k
                break
        if base is None:
            continue
        if opcode.endswith("-done"):      # start/done pairs: count start only
            continue
        result_bytes = sum(_shape_bytes(dt, dims)
                           for dt, dims in _SHAPE_RE.findall(result_ty))
        n = _group_size(line)
        if base == "all-gather":
            total = result_bytes // max(1, n)
        elif base == "reduce-scatter":
            total = result_bytes * n
        else:
            total = result_bytes
        bytes_by[base] += total
        count_by[base] += 1
    stats.bytes_by_kind = dict(bytes_by)
    stats.count_by_kind = dict(count_by)
    return stats


def flop_count(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def bytes_accessed(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("bytes accessed", 0.0))


def memory_stats(compiled) -> Dict[str, int]:
    ma = compiled.memory_analysis()
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        out[name] = int(getattr(ma, name, 0))
    return out
