"""Serving driver: batched generation with AFT-backed atomic weight refresh.

Loads the latest committed checkpoint for ``--run-id`` (written by
``repro.launch.train``) and serves batched greedy generations; the
background refresher hot-swaps weights whenever the trainer commits a newer
checkpoint — atomically, thanks to read-atomic isolation.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --workdir /tmp/aft-train --run-id train0 --requests 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.checkpoint import AftCheckpointer
from repro.core import AftCluster, ClusterConfig
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine
from repro.storage.localfs import LocalFSStorage
from repro.storage.memory import MemoryStorage

from .train import reduced_preset


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "m100"])
    ap.add_argument("--storage", default="localfs",
                    choices=["memory", "localfs"])
    ap.add_argument("--workdir", default="/tmp/aft-train")
    ap.add_argument("--run-id", default="train0")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--refresh-every", type=float, default=1.0)
    args = ap.parse_args()

    cfg, _, _ = reduced_preset(args.arch, args.preset)
    model = Model(cfg)
    storage = (MemoryStorage() if args.storage == "memory"
               else LocalFSStorage(args.workdir))
    cluster = AftCluster(storage, ClusterConfig(num_nodes=2))
    try:
        ck = AftCheckpointer(cluster.client(), run_id=args.run_id)
        eng = ServeEngine(model, ck, ServeConfig(
            max_batch=args.requests,
            max_len=args.prompt_len + args.max_new + 1,
            refresh_every_s=args.refresh_every))
        if not eng.refresh_weights():
            print("[serve] no committed checkpoint found — run "
                  "repro.launch.train first")
            return 1
        print(f"[serve] weights @ step {eng.weights_step}")
        eng.start_refresher()

        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.requests, args.prompt_len)).tolist()
        t0 = time.time()
        outs = eng.generate(prompts, args.max_new)
        dt = time.time() - t0
        toks = args.requests * args.max_new
        print(f"[serve] {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s, batch={args.requests})")
        for i, o in enumerate(outs[:4]):
            print(f"  req{i}: {o[:16]}{'...' if len(o) > 16 else ''}")
        print(f"[serve] stats: {eng.stats}")
        eng.stop()
        return 0
    finally:
        cluster.stop()


if __name__ == "__main__":
    raise SystemExit(main())
