"""End-to-end fault-tolerant training driver.

Runs a real training loop (synthetic grammar corpus) with AFT-transactional
checkpointing.  On this CPU container the default preset is a reduced
config; ``--preset m100`` selects a ~100M-parameter variant of the chosen
architecture family (same code path the production mesh would run — the
dry-run/roofline tools cover the full configs).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 100 --ckpt-every 20 --storage localfs --workdir /tmp/aft-run
  # crash/restart demo (exactly-once):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 60 --crash-at 35 && \
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 60
"""

from __future__ import annotations

import argparse
import json
import time

from repro.checkpoint import AftCheckpointer
from repro.core import AftCluster, ClusterConfig
from repro.models import Model, get_config
from repro.storage.localfs import LocalFSStorage
from repro.storage.memory import MemoryStorage
from repro.train import get_optimizer
from repro.train.data import data_for_model
from repro.train.loop import CrashInjected, Trainer, TrainerConfig


def make_storage(kind: str, workdir: str):
    if kind == "memory":
        return MemoryStorage()
    if kind == "localfs":
        return LocalFSStorage(workdir)
    raise ValueError(kind)


def reduced_preset(arch: str, preset: str):
    cfg = get_config(arch)
    if preset == "smoke":
        return cfg.reduced(), 8, 64
    if preset == "m100":
        # ~100M-param family member: wider/deeper than smoke, CPU-trainable
        return cfg.reduced(
            d_model=512, num_heads=8, num_kv_heads=4, d_ff=1408,
            vocab_size=min(cfg.vocab_size, 32000),
            pattern_repeats=max(1, min(8, 48 // max(1, len(cfg.pattern)))),
            head_dim=None, attn_q_chunk=128,
        ), 8, 256
    raise ValueError(preset)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "m100"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--storage", default="localfs",
                    choices=["memory", "localfs"])
    ap.add_argument("--workdir", default="/tmp/aft-train")
    ap.add_argument("--run-id", default="train0")
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="inject a crash after this step (restart to resume)")
    ap.add_argument("--history-out", default="")
    args = ap.parse_args()

    cfg, batch, seq = reduced_preset(args.arch, args.preset)
    if args.batch:
        batch = args.batch
    if args.seq:
        seq = args.seq
    model = Model(cfg)
    from repro.models.params import count_params

    n_params = count_params(model.param_defs())
    print(f"[train] arch={args.arch} preset={args.preset} "
          f"params={n_params/1e6:.1f}M batch={batch} seq={seq}")

    storage = make_storage(args.storage, args.workdir)
    cluster = AftCluster(storage, ClusterConfig(num_nodes=args.nodes))
    try:
        ck = AftCheckpointer(cluster.client(), run_id=args.run_id)
        data = data_for_model(cfg, global_batch=batch, seq_len=seq)
        tcfg = TrainerConfig(
            total_steps=args.steps, ckpt_every=args.ckpt_every,
            log_every=args.log_every,
            crash_after_step=args.crash_at if args.crash_at >= 0 else None)
        trainer = Trainer(model, get_optimizer(args.optimizer, lr=args.lr),
                          data, ck, tcfg)
        t0 = time.time()
        try:
            hist = trainer.run()
        except CrashInjected as e:
            print(f"[train] CRASH INJECTED: {e} — restart this command to "
                  f"resume from the last committed checkpoint "
                  f"(step {ck.latest_step()})")
            return 0
        dt = time.time() - t0
        if not hist:
            print(f"[train] nothing to do — run already complete at step "
                  f"{ck.latest_step()}")
            return 0
        print(f"[train] done: {hist[-1]} ({dt:.1f}s)")
        steps_done = hist[-1]["step"] + 1 - hist[0]["step"]
        tok_s = batch * seq * steps_done / max(dt, 1e-9)
        print(f"[train] ~{tok_s:.0f} tokens/s on this host")
        if args.history_out:
            with open(args.history_out, "w") as f:
                json.dump(hist, f, indent=1)
        return 0
    finally:
        cluster.stop()


if __name__ == "__main__":
    raise SystemExit(main())
