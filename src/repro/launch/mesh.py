"""Production meshes.

``make_production_mesh()`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real 1-CPU platform.

Target hardware model (TPU v5e-class):
  peak bf16 compute  : 197 TFLOP/s per chip
  HBM bandwidth      : 819 GB/s per chip
  ICI link bandwidth : ~50 GB/s per link (bidirectional per-axis budget)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link
DCN_BW = 12.5e9            # bytes/s / host (cross-pod, 100 Gbps)
HBM_BYTES = 16 * 2**30     # v5e HBM capacity


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist, as a (data, model) mesh with model=1."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


@dataclass(frozen=True)
class Hardware:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    dcn_bw: float = DCN_BW
    hbm_bytes: int = HBM_BYTES


V5E = Hardware()
