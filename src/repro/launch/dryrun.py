import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on
the production meshes and record memory/cost/collective statistics.

This is how the distribution config is proven coherent without hardware:
``.lower().compile()`` runs the full GSPMD partitioner; sharding mismatches,
non-divisible dimensions, and unsupported collectives all fail here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi   # 2-pod 512-chip mesh

Results append to benchmarks/results/dryrun.json (keyed arch×shape×mesh) and
are consumed by the roofline tool and EXPERIMENTS.md.
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES
from repro.launch.steps import build_cell
from repro.models import list_configs

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def run_cell(arch: str, shape: str, mesh_kind: str, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "chips": n_chips,
        "entry": cell.entry,
    }
    if cell.skipped:
        rec["status"] = "skipped"
        rec["reason"] = cell.skipped
        if verbose:
            print(f"[dryrun] {arch} × {shape} × {mesh_kind}: SKIP ({cell.skipped})")
        return rec
    try:
        lowered = cell.lower()
        compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=20)
        if verbose:
            print(f"[dryrun] {arch} × {shape} × {mesh_kind}: FAIL {rec['error']}")
        return rec

    mem = hlo.memory_stats(compiled)
    text = compiled.as_text()
    coll = hlo.collective_stats(text)
    rec.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        flops=hlo.flop_count(compiled),
        bytes_accessed=hlo.bytes_accessed(compiled),
        memory=mem,
        collective_bytes=coll.bytes_by_kind,
        collective_counts=coll.count_by_kind,
        hlo_bytes=len(text),
    )
    if verbose:
        per_dev = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
                   + mem["output_size_in_bytes"] - mem.get("alias_size_in_bytes", 0))
        print(f"[dryrun] {arch} × {shape} × {mesh_kind}: OK "
              f"({rec['compile_s']}s, args+temp+out−alias≈{per_dev/2**30:.2f} GiB/dev, "
              f"flops={rec['flops']:.3e}, coll={coll.total_bytes/2**20:.1f} MiB)")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={rec['flops']:.4e} "
              f"bytes={rec['bytes_accessed']:.4e}")
        print(f"  collectives: {coll.bytes_by_kind}")
    return rec


def load_results() -> dict:
    f = RESULTS / "dryrun.json"
    if f.exists():
        return json.loads(f.read_text())
    return {}


def save_result(rec: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    all_res = load_results()
    all_res[f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"] = rec
    (RESULTS / "dryrun.json").write_text(json.dumps(all_res, indent=1))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true",
                    help="re-run cells already in dryrun.json")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    existing = load_results()

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                key = f"{arch}|{shape}|{mesh_kind}"
                if not args.force and existing.get(key, {}).get("status") == "ok":
                    print(f"[dryrun] {key}: cached ok")
                    continue
                rec = run_cell(arch, shape, mesh_kind)
                save_result(rec)
                if rec["status"] == "error":
                    failures += 1
    print(f"[dryrun] done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
