"""Cell builder: (architecture × shape × mesh) → lowered step function.

One cell = one jitted entry point with full in/out shardings, lowered against
abstract inputs.  Used by the dry-run driver, the roofline tool, and the
real train/serve drivers (which feed concrete arrays through the same path).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Model, get_config
from repro.models.config import ArchConfig
from repro.models.params import abstract, specs
from repro.models.sharding import logical_to_spec, sharding_rules
from repro.train.optim import Optimizer, get_optimizer

from .shapes import SHAPES, ShapeSpec, cell_applicable, input_specs, resolve_rules

# per-arch optimizer for the train cell.  kimi-k2 (≈1.03T params) uses
# factored second moments: full AdamW state (8 bytes/param fp32) cannot fit a
# single 256-chip v5e pod (see EXPERIMENTS.md §Dry-run notes).
CELL_OPTIMIZER: Dict[str, str] = {
    "kimi-k2-1t-a32b": "adafactor",
    "qwen1.5-110b": "adamw-bf16",
}


@dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    mesh: Mesh
    cfg: ArchConfig
    rules: Dict[str, Any]
    entry: str                                  # train_step|prefill_step|serve_step
    fn: Callable                                # the un-jitted step
    args_abs: Tuple[Any, ...]                   # abstract args
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    skipped: str = ""                           # non-empty = inapplicable cell

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings)
        with self.mesh:
            return jitted.lower(*self.args_abs)


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _with_rules(fn, rules, mesh):
    """Re-enter the sharding-rules context at *trace* time: ``constrain``
    reads thread-local state, and jit traces the function lazily inside
    ``.lower()`` — long after ``build_cell`` returned."""
    import functools

    @functools.wraps(fn)
    def wrapped(*args):
        with sharding_rules(rules, mesh):
            return fn(*args)

    return wrapped


def _scalar(mesh: Mesh):
    return NamedSharding(mesh, P())


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               optimizer: Optional[Optimizer] = None,
               fsdp: bool = True,
               overrides: Optional[Dict[str, Any]] = None,
               rule_overrides: Optional[Dict[str, Any]] = None) -> Cell:
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides).validate()
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    axis = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axis.get("model", 1)
    dp = axis.get("data", 1) * axis.get("pod", 1)
    rules = resolve_rules(cfg, shape, tp=tp, dp=dp, fsdp=fsdp)
    if rule_overrides:
        rules.update(rule_overrides)
    if not ok:
        return Cell(arch, shape, mesh, cfg, rules, "", None, (), (), None,
                    skipped=reason)

    with sharding_rules(rules, mesh):
        model = Model(cfg)
        pdefs = model.param_defs()
        params_abs, params_spec = abstract(pdefs), specs(pdefs)
        params_sh = _ns(mesh, params_spec)
        ins = input_specs(cfg, shape)

        if shape.kind == "train":
            opt = optimizer or get_optimizer(CELL_OPTIMIZER.get(arch, "adamw"))
            sdefs = opt.state_defs(pdefs)
            opt_abs, opt_spec = abstract(sdefs), specs(sdefs)
            opt_sh = _ns(mesh, opt_spec)
            batch_abs = ins["batch"]
            batch_spec = {
                "tokens": logical_to_spec(("batch", None)),
                "labels": logical_to_spec(("batch", None)),
            }
            if "frontend" in batch_abs:
                batch_spec["frontend"] = logical_to_spec(("batch", None, None))
            batch_sh = _ns(mesh, batch_spec)
            step_abs = jax.ShapeDtypeStruct((), jnp.dtype("int32"))

            def train_step(params, opt_state, step, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, batch)
                params, opt_state = opt.update(grads, opt_state, params, step)
                metrics = dict(metrics, loss=loss)
                return params, opt_state, metrics

            metrics_sh = {k: _scalar(mesh)
                          for k in ("ce", "aux", "ppl_log", "loss")}
            return Cell(arch, shape, mesh, cfg, rules, "train_step",
                        _with_rules(train_step, rules, mesh),
                        (params_abs, opt_abs, step_abs, batch_abs),
                        (params_sh, opt_sh, _scalar(mesh), batch_sh),
                        (params_sh, opt_sh, metrics_sh))

        if shape.kind == "prefill":
            tokens_abs = ins["tokens"]
            max_len = shape.seq_len
            state_defs = model.decode_state_defs(shape.global_batch, max_len)
            state_sh = _ns(mesh, specs(state_defs))
            logits_sh = _ns(mesh, logical_to_spec(("batch", None, "vocab")))
            args = [tokens_abs]
            in_sh = [_ns(mesh, logical_to_spec(("batch", None)))]
            if "frontend" in ins:
                args.append(ins["frontend"])
                in_sh.append(_ns(mesh, logical_to_spec(("batch", None, None))))

                def prefill_step(params, tokens, frontend):
                    return model.prefill(params, tokens, max_len, frontend)
            else:
                def prefill_step(params, tokens):
                    return model.prefill(params, tokens, max_len)

            return Cell(arch, shape, mesh, cfg, rules, "prefill_step",
                        _with_rules(prefill_step, rules, mesh),
                        (params_abs, *args),
                        (params_sh, *in_sh), (logits_sh, state_sh))

        # decode
        state_abs = ins["state"]
        state_defs = model.decode_state_defs(shape.global_batch, shape.seq_len)
        state_sh = _ns(mesh, specs(state_defs))
        tokens_sh = _ns(mesh, logical_to_spec(("batch", None)))
        logits_sh = _ns(mesh, logical_to_spec(("batch", None, "vocab")))

        def serve_step(params, state, tokens, position):
            return model.decode_step(params, state, tokens, position)

        return Cell(arch, shape, mesh, cfg, rules, "serve_step",
                    _with_rules(serve_step, rules, mesh),
                    (params_abs, state_abs, ins["tokens"], ins["position"]),
                    (params_sh, state_sh, tokens_sh, _scalar(mesh)),
                    (logits_sh, state_sh))
